"""Open-stream serving with token streaming and SLO-aware admission
(DESIGN.md §11): submit requests into the live queue, watch tokens
arrive through per-request callbacks, then replay a bursty arrival
trace and compare fcfs vs slo goodput on a deterministic virtual clock.

    PYTHONPATH=src python examples/streaming_serve.py
"""
import numpy as np
import jax

from repro.configs import get_config, reduced
from repro.models import RunConfig, init_params
from repro.serve.engine import ServeEngine
from repro.serve.frontend import ServingFrontend
from repro.serve.loadgen import make_virtual_obs, replay, synth_trace


def main():
    cfg = reduced(get_config("moonshot-v1-16b-a3b"), layers=2, d_model=64,
                  vocab=256)
    params = init_params(cfg, jax.random.key(0))
    rc = RunConfig(q_chunk=16, kv_chunk=16, schedule_policy="dynamic")

    # --- 1. token streaming ------------------------------------------
    # The frontend owns an engine; submit() returns a live Request
    # handle and on_token fires the moment the step's single host sync
    # retires each token — the stream IS the closed-batch output, token
    # for token (asserted in tests/test_serve.py).
    engine = ServeEngine(cfg, params, slots=2, capacity=64, rc=rc)
    fe = ServingFrontend(engine)
    rng = np.random.default_rng(0)

    def show(req, tok):
        print(f"  rid {req.rid} token[{len(req.out) - 1}] = {tok}")

    handles = [fe.submit(rng.integers(0, cfg.vocab_size, 5), max_new=4,
                         on_token=show)
               for _ in range(3)]
    print("streaming 3 requests through 2 slots:")
    fe.drain()
    for r in handles:
        print(f"  rid {r.rid} done: {r.out} "
              f"(ttft {r.stats['lat/ttft_s'] * 1e3:.1f} ms)")

    # --- 2. SLO admission under burst load ---------------------------
    # Same seeded trace, two admission policies, virtual time (one
    # engine step = 50 virtual ms) — so the goodput gap below is exactly
    # reproducible.  slo admits by TTFT-deadline feasibility and parks
    # requests that already blew their own deadline (paged: host-side
    # table park, resumed later block-for-block).
    for admission in ("fcfs", "slo"):
        trace = synth_trace("burst", seed=0, n=16, rate=8.0,
                            vocab=cfg.vocab_size, max_new=5, slo_ttft=0.4,
                            burst_size=4, prompt_hi=40)
        clock, obs = make_virtual_obs(enabled=True)
        eng = ServeEngine(cfg, params, slots=2, capacity=64, rc=rc,
                          kv_block_size=4, prefill_chunk=4,
                          admission=admission, obs=obs)
        rec = replay(eng, trace, clock=clock, step_time=0.05, seed=0,
                     pattern="burst")
        print(f"burst x {admission:4s}: goodput {rec['goodput_rps']:.2f} "
              f"req/s, SLO attainment {rec['slo_attainment']:.0%}, "
              f"preempted {rec['preempted']}, resumed {rec['resumed']}, "
              f"TTFT p99 {rec['ttft_p99_s']:.2f} s")


if __name__ == "__main__":
    main()
