"""Quickstart: the paper's fused MoE dispatch pipeline, step by step.

Runs the five-stage pipeline (router -> permute -> fused gate+up grouped
GEMM -> down GEMM with folded combine weights -> unpermute) with the Pallas
kernels (interpret mode off-TPU), and checks all three implementations
agree with the dense loop-over-experts oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core import apply_moe, dispatch_config, init_moe_params
from repro.core.schedule import build_schedule
from repro.kernels import ops, ref


def main():
    moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=128,
                    n_shared_experts=1, block_m=16)
    d_model, tokens = 64, 256
    params = init_moe_params(jax.random.key(0), moe, d_model)
    x = jax.random.normal(jax.random.key(1), (tokens, d_model)) * 0.5

    # ---- stage by stage (paper §3.1's five launches) ----
    logits = x @ params["router"]
    weights, indices = ops.router_topk(logits, top_k=moe.top_k)     # 1 router
    print(f"router: top-{moe.top_k} of {moe.n_experts} experts; "
          f"first token -> experts {np.asarray(indices)[0]}")

    sched = build_schedule(indices, moe.n_experts, moe.block_m)
    print(f"schedule: capacity={sched.capacity} rows "
          f"({tokens}x{moe.top_k} tokens + tile padding), "
          f"{sched.capacity // moe.block_m} blocks of M={moe.block_m}, "
          f"active={int(np.asarray(sched.block_active).sum())}")

    xp = ops.permute(x, sched)                                      # 2 permute
    h = ops.fused_gate_up(xp, params["w_gate"], params["w_up"],    # 3 fused
                          sched, block_n=64, block_k=32)
    from repro.core.dispatch import combine_scale_rows
    y = ops.grouped_gemm(h, params["w_down"], sched,                # 4 down
                         row_scale=combine_scale_rows(sched, weights),
                         block_n=32, block_k=64)
    out_pallas = ops.unpermute(y, sched, None)                      # 5 unperm

    # ---- whole-layer API, every registered executor backend ----
    from repro.execution import available_executors, execute, plan_dispatch
    outs = {}
    for name in available_executors():
        y_full, aux = apply_moe(params, x[None],
                                dispatch_config(moe, executor=name))
        outs[name] = np.asarray(y_full[0])
    for name in ("xla", "pallas"):
        np.testing.assert_allclose(outs["dense"], outs[name],
                                   rtol=2e-4, atol=2e-4)
    print("executor equivalence: dense == xla == pallas  (max |delta| = "
          f"{max(np.abs(outs['dense'] - outs[n]).max() for n in ('xla', 'pallas')):.2e})")

    # the stage-by-stage pipeline above equals the routed part of the layer
    routed = {k: v for k, v in params.items() if k != "shared"}
    y_routed, _ = apply_moe(routed, x[None],
                            dispatch_config(moe, executor="pallas"))
    np.testing.assert_allclose(np.asarray(y_routed[0]),
                               np.asarray(out_pallas), rtol=2e-4, atol=2e-4)
    print("stage-by-stage pipeline == routed experts of apply_moe")

    # ---- plan/execute split: ONE plan consumed by two backends ----
    cfg = dispatch_config(moe, executor="xla")
    w = {k: params[k] for k in ("w_gate", "w_up", "w_down")}
    plan = plan_dispatch(x, params["router"], cfg)
    y_xla = execute(plan, x, w, cfg)                      # cfg's executor
    y_pal = execute(plan, x, w, cfg, executor="pallas")   # same plan, kernels
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pal),
                               rtol=2e-4, atol=2e-4)
    print(f"plan reuse: xla and pallas agree on one DispatchPlan "
          f"({plan.schedule.capacity}-row schedule built once)")
    print(f"aux: load-balance={float(aux['lb_loss']):.3f} "
          f"router-z={float(aux['router_z']):.3f}")
    print("OK")


if __name__ == "__main__":
    main()
