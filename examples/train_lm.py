"""End-to-end training driver: train an LM on the synthetic Markov corpus
with checkpointing, resume, and straggler monitoring.

Presets:
  cpu-small (default) — 2L/64d MoE model, 200 steps, ~2 min on CPU.
  100m               — ~100M-param dense config (12L/768d/50k vocab),
                        the shape a single v5e host would train; on CPU
                        expect ~hours, use --steps to bound.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 20
    PYTHONPATH=src python examples/train_lm.py --resume   # continues ckpt
"""
import argparse

import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import MoEConfig
from repro.models import RunConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import train


def build(preset: str):
    if preset == "cpu-small":
        cfg = reduced(get_config("moonshot-v1-16b-a3b"), layers=2,
                      d_model=64, vocab=64)
        cfg = cfg.replace(moe=cfg.moe and
                          cfg.moe.__class__(**{**cfg.moe.__dict__,
                                               "n_experts": 4, "top_k": 2,
                                               "first_dense_layers": 0}))
        rc = RunConfig(q_chunk=32, kv_chunk=32, loss_chunk=32)
        return cfg, rc, dict(steps=200, batch=8, seq=64,
                             opt=OptConfig(lr=1e-2, warmup_steps=10,
                                           total_steps=200,
                                           weight_decay=0.0))
    if preset == "100m":
        cfg = get_config("smollm-360m").replace(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048)                      # ~100M params
        rc = RunConfig(compute_dtype=jnp.bfloat16, q_chunk=256,
                       kv_chunk=256, loss_chunk=256, remat=True)
        return cfg, rc, dict(steps=300, batch=8, seq=1024,
                             opt=OptConfig(lr=3e-4, warmup_steps=50,
                                           total_steps=300))
    raise SystemExit(f"unknown preset {preset}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-small",
                    choices=["cpu-small", "100m"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure (demo: rerun with --resume)")
    args = ap.parse_args()

    cfg, rc, kw = build(args.preset)
    steps = args.steps or kw["steps"]
    if not args.resume:
        import shutil
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    out = train(cfg, rc, kw["opt"], steps=steps, batch=kw["batch"],
                seq=kw["seq"], ckpt_dir=args.ckpt_dir, save_every=25,
                fail_at=args.fail_at, log_every=10)
    hist = out["history"]
    print(f"\nfinal ce={hist[-1]['ce']:.4f} (start {hist[0]['ce']:.4f}); "
          f"stragglers flagged: {len(out['stragglers'])}; "
          f"resumed_from={out['resumed_from']}")


if __name__ == "__main__":
    main()
