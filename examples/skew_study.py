"""Routing-imbalance study (paper §4.7) as a runnable example.

Replaces the router with synthetic uniform / Zipf(1.2) / Zipf(2.0)
assignments (uniform 1/k gating, fixed token budget — the paper's
methodology) and reports the fixed-BLOCK_M tile-padding waste, per-expert
load shares, and EP capacity drop rates that drive the paper's Qwen2-MoE
findings.

    PYTHONPATH=src python examples/skew_study.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax
import numpy as np

from benchmarks.common import zipf_assignments
from repro.configs.paper import PAPER_CONFIGS
from repro.core.schedule import build_schedule, round_up


def main():
    T = 512
    for name in ("mixtral-8x7b", "qwen2-moe-57b"):
        pc = PAPER_CONFIGS[name]
        E, k = pc.n_experts, pc.top_k
        block_m = min(128, max(8, T * k // E))
        print(f"\n{name}: E={E} k={k} BLOCK_M={block_m} T={T}")
        for dist, alpha in (("uniform", 0.0), ("zipf-1.2", 1.2),
                            ("zipf-2.0", 2.0)):
            _, idx = zipf_assignments(jax.random.key(3), T, k, E, alpha)
            sched = build_schedule(idx, E, block_m)
            counts = np.asarray(sched.counts)
            useful = counts.sum()
            padded = int(np.asarray(sched.block_active).sum()) * block_m
            cap = round_up(max(1, int(T * k * 1.25 / E)), block_m)
            dropped = np.maximum(counts - cap, 0).sum() / useful
            print(f"  {dist:9s} top1_share={counts.max() / useful:5.1%}  "
                  f"tile_waste={padded / useful:4.2f}x  "
                  f"EP_drop@cf1.25={dropped:5.1%}")
    print("\nPaper's finding reproduced structurally: at 64 experts the "
          "fixed-BLOCK_M schedule pads hardest and EP capacity drops spike "
          "under Zipf(2.0) — the regime where Megablocks' block-sparse "
          "layout wins (paper Fig. 3). Dynamic block-to-expert assignment "
          "is the paper's proposed fix.")


if __name__ == "__main__":
    main()
