"""Routing-imbalance study (paper §4.7) as a runnable example.

Replaces the router with synthetic uniform / Zipf(1.2) / Zipf(2.0)
assignments (uniform 1/k gating, fixed token budget — the paper's
methodology) and compares the three schedule policies (repro.scheduling)
on the tile-padding waste, block occupancy, and drop rates that drive the
paper's Qwen2-MoE findings.

    PYTHONPATH=src python examples/skew_study.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax

from benchmarks.common import zipf_assignments
from repro.configs.paper import PAPER_CONFIGS
from repro.scheduling import (DEFAULT_POLICY_SWEEP, build_schedule,
                              schedule_stats)

POLICIES = DEFAULT_POLICY_SWEEP


def main():
    T = 512
    for name in ("mixtral-8x7b", "qwen2-moe-57b"):
        pc = PAPER_CONFIGS[name]
        E, k = pc.n_experts, pc.top_k
        block_m = min(128, max(8, T * k // E))
        print(f"\n{name}: E={E} k={k} BLOCK_M={block_m} T={T}")
        for dist, alpha in (("uniform", 0.0), ("zipf-1.2", 1.2),
                            ("zipf-2.0", 2.0)):
            _, idx = zipf_assignments(jax.random.key(3), T, k, E, alpha)
            stats = {policy: schedule_stats(
                build_schedule(idx, E, block_m, policy=policy, **kw))
                for policy, kw in POLICIES}
            line = [f"{policy}: waste={float(st.pad_waste):4.2f}x "
                    f"occ={float(st.occupancy):4.1%} "
                    f"drop={float(st.drop_fraction):5.1%}"
                    for policy, st in stats.items()]
            top1 = float(stats["fixed"].top1_share)   # routing skew: policy-independent
            print(f"  {dist:9s} top1_share={top1:5.1%}  " + "  ".join(line))
    print("\nPaper's finding reproduced structurally: at 64 experts the "
          "fixed-BLOCK_M schedule pads hardest under Zipf(2.0) — the regime "
          "where Megablocks' block-sparse layout wins (paper Fig. 3). The "
          "`dynamic` policy (the paper's proposed fix, scheduling/dynamic.py) "
          "recovers most of that waste by sub-tiling light experts while "
          "keeping heavy experts on full MXU tiles; `capacity_factor` trades "
          "waste for drops (GShard EP semantics).")


if __name__ == "__main__":
    main()
