"""Attach the observability bundle to a serving run (DESIGN.md §10):
metrics registry + Chrome-trace span tracer + straggler monitor, then
inspect what the engine absorbed — counters, paged-cache gauges,
per-request TTFT/TPOT, and the step-timeline trace.

    PYTHONPATH=src python examples/observability.py [--trace out.json]
"""
import argparse

import numpy as np
import jax

from repro.configs import get_config, reduced
from repro.models import RunConfig, init_params
from repro.obs import Observability, latency_summary, validate_chrome_trace
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also write the Chrome-trace JSON (load it at "
                         "chrome://tracing or ui.perfetto.dev)")
    args = ap.parse_args()

    cfg = reduced(get_config("moonshot-v1-16b-a3b"), layers=2, d_model=64,
                  vocab=256)
    params = init_params(cfg, jax.random.key(0))

    # Observability.memory() = metrics + tracer + straggler monitor on one
    # clock.  The default (no obs argument) is the NOOP bundle: same code
    # paths, null sinks, zero overhead — and bitwise-identical tokens,
    # which tests/test_obs.py asserts.
    obs = Observability.memory()
    engine = ServeEngine(cfg, params, slots=3, capacity=64, obs=obs,
                         rc=RunConfig(q_chunk=64, kv_chunk=64,
                                      schedule_policy="dynamic",
                                      moe_stats=True))

    rng = np.random.default_rng(0)
    requests = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            rng.integers(3, 9)).astype(np.int32),
                        max_new=8)
                for i in range(7)]
    done = engine.run(requests)
    assert all(r.done for r in requests)

    # 1. engine counters / paged-cache gauges, one snapshot
    snap = obs.metrics.snapshot()
    counters = {c["name"]: c["value"] for c in snap["counters"]
                if not c["labels"]}
    print(f"completed {len(done)} requests in "
          f"{counters['serve/steps']:.0f} steps "
          f"({counters['serve/step_tokens']:.0f} step-tokens)")
    print("gauges:", {g["name"]: g["value"] for g in snap["gauges"]
                      if g["name"].startswith("kv/")})

    # 2. recompile accounting: one count per distinct compiled step shape
    recompiles = {tuple(c["labels"].items()): c["value"]
                  for c in snap["counters"]
                  if c["name"] == "serve/recompiles"}
    print("recompiles by step kind:", recompiles)

    # 3. per-request latency (always on — Request.stats carries lat/*
    #    whether or not a sink is attached)
    for fam, agg in latency_summary(requests).items():
        print(f"  {fam:>13}: p50 {agg['p50'] * 1e3:7.2f} ms   "
              f"p99 {agg['p99'] * 1e3:7.2f} ms   (n={agg['n']})")

    # 4. the step timeline as a Chrome trace
    doc = obs.tracer.to_chrome_trace()
    v = validate_chrome_trace(doc, required_names=(
        "serve/admit", "serve/step", "serve/forward", "serve/host_sync"))
    print(f"trace: {v['events']} events, "
          f"{len(v['names'])} distinct span/instant names")
    if args.trace:
        print("wrote", obs.tracer.save(args.trace))
    print("OK")


if __name__ == "__main__":
    main()
