"""Serve a small MoE model with batched requests through the slot engine:
prefill + lock-step decode + slot reuse (continuous batching lite), with
the routed experts optionally quantized under a registered scheme
(`--quant`, DESIGN.md §8 — the serving deployment layout).

    PYTHONPATH=src python examples/serve_moe.py [--quant int8_expert]
"""
import argparse

import numpy as np
import jax

from repro.configs import get_config, reduced
from repro.models import RunConfig, init_params
from repro.quantization import available_schemes
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", default="int8_expert",
                    choices=available_schemes(),
                    help="expert-weight quantization scheme "
                         "(repro.quantization registry)")
    args = ap.parse_args()

    cfg = reduced(get_config("moonshot-v1-16b-a3b"), layers=2, d_model=64,
                  vocab=256)
    params = init_params(cfg, jax.random.key(0))
    # RunConfig.quant is the one selector: the engine quantizes the routed
    # experts at load; everything else (schedule policy default `dynamic`,
    # per-request telemetry) keeps the serving defaults
    engine = ServeEngine(cfg, params, slots=3, capacity=64,
                         rc=RunConfig(q_chunk=64, kv_chunk=64,
                                      schedule_policy="dynamic",
                                      quant=args.quant, moe_stats=True))

    rng = np.random.default_rng(0)
    requests = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            rng.integers(3, 9)).astype(np.int32),
                        max_new=8)
                for i in range(7)]
    print(f"serving {len(requests)} requests on {engine.slots} slots "
          f"(MoE: {cfg.moe.n_experts} experts, top-{cfg.moe.top_k}, "
          f"schedule_policy={engine.rc.schedule_policy}, "
          f"quant={engine.rc.quant})")
    done = engine.run(requests)
    assert done == requests, "run() returns completed requests in order"
    for r in requests:
        print(f"  req {r.rid}: prompt={r.prompt.tolist()} -> {r.out}")
    assert all(r.done for r in requests)
    print("OK: all requests completed with slot reuse")


if __name__ == "__main__":
    main()
