"""Partition rules: parameters, optimizer state, batches, caches, activations.

Scheme (see DESIGN.md §5):
  data axis  -> batch DP + FSDP storage sharding of every weight matrix
  model axis -> EP (experts), SP/CP (sequence on the residual stream for
                transformer archs), TP-heads (ssm/hybrid mixers), KV-cache
                sequence sharding for decode
  pod axis   -> extra DP (gradient all-reduce crosses pods)

GSPMD guarantees correctness for any divisible storage sharding; these rules
choose layouts so the *propagated* compute sharding matches the scheme.  Any
axis that does not divide a dimension is dropped (replicated) — uniform
behavior for e.g. hubert's 504-way vocab.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def dp_axes(mesh: Mesh):
    """Data-parallel axes: ('pod','data') on multi-pod, ('data',) otherwise."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return dim % size == 0


def _clean(spec, shape, mesh: Mesh) -> P:
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        out.append(ax if (ax is not None and _fits(dim, mesh, ax)) else None)
    return P(*out)


# ----------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------
_TP_COL = {"wq", "wk", "wv", "wg", "w_gate", "w_up", "wq_a", "wkv_a",
           "wq_b", "wkv_b", "in_proj", "wr", "w_lora_a", "w_lora_b"}
_TP_ROW = {"wo", "w_down", "out_proj"}


def param_specs(params, cfg: ModelConfig, mesh: Mesh,
                mode: str = "fsdp"):
    """PartitionSpec pytree matching ``params`` (works on
    ShapeDtypeStructs).

    mode="fsdp"     — training layout: every matrix storage-sharded over
                      (data, model); gathered per layer by GSPMD (ZeRO-3).
    mode="serve_tp" — decode layout (beyond-paper §Perf): dense matrices
                      feature-split over 'model' (column for up/qkv
                      projections, row for down/output — GSPMD emits the
                      one psum per block), REPLICATED over 'data', so no
                      per-step weight gathers; expert tensors keep
                      ('model', 'data') EP+FSDP storage."""
    dp = dp_axes(mesh)
    fsdp = dp[-1]                       # 'data'
    tp = mode == "serve_tp"

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1] if names else ""
        shape = leaf.shape
        stacked = int(names[0] in ("body", "shared")) if names else 0
        core = shape[stacked:]
        if len(core) == 0 or name in ("mu", "u", "w0", "a_log", "dt_bias",
                                      "d_skip", "scale", "bias", "mask_emb") \
                or len(core) == 1:
            spec = (None,) * len(core)
        elif name == "embed":
            spec = ("model", None if tp else fsdp)
        elif name == "head":
            spec = (None, "model") if tp else (fsdp, "model")
        elif name == "router":
            spec = (None, None)
        elif len(core) == 3 and cfg.is_moe \
                and core[0] == cfg.moe.n_experts:
            # routed expert stacks: dense mats, QuantTensor payload ('q')
            # and scales ('s'), or legacy _q/_s suffix-keyed leaves — all
            # expert-leading rank 3
            if name == "s" or name.endswith("_s"):    # quant scales
                spec = ("model", None, None)
            else:                               # EP ownership + FSDP
                spec = ("model", fsdp, None)
        elif name == "conv_w":
            spec = (None, "model")
        elif tp and len(core) == 2:
            if name in _TP_ROW:
                spec = ("model", None)
            elif name in _TP_COL:
                spec = (None, "model")
            else:
                spec = (None, None)
        else:                                    # generic 2D+ matrices
            spec = (fsdp, "model") + (None,) * (len(core) - 2)
        full = (None,) * stacked + spec
        return _clean(full, shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, params)


def opt_state_specs(param_spec_tree):
    """Adam moments share the param layout."""
    return {"m": param_spec_tree, "v": param_spec_tree,
            "step": P()}


# ----------------------------------------------------------------------
# Batches
# ----------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, mesh: Mesh, mode: str, global_batch: int,
                microbatched: bool = False) -> Dict[str, P]:
    dp = dp_axes(mesh)
    bdp = dp if _fits(global_batch, mesh, dp) else \
        (dp[-1:] if _fits(global_batch, mesh, dp[-1]) else ())
    b = bdp if bdp else None
    seq_ax = "model" if (cfg.family not in ("ssm", "hybrid")
                         and mode != "decode") else None
    lead = (None,) if microbatched else ()
    specs = {}
    if cfg.encoder_only:
        specs["features"] = P(*lead, b, seq_ax, None)
        specs["labels"] = P(*lead, b, seq_ax)
        specs["mask"] = P(*lead, b, seq_ax)
    else:
        specs["tokens"] = P(*lead, b, seq_ax)
    if cfg.cross_attn_every:
        specs["image_embeds"] = P(*lead, b, None, None)
    return specs


# ----------------------------------------------------------------------
# Decode caches
# ----------------------------------------------------------------------
def cache_specs(cache, cfg: ModelConfig, mesh: Mesh, batch: int):
    """Seq-sharded KV caches (flash-decode); head-sharded SSM/RWKV states."""
    dp = dp_axes(mesh)
    b_ok = _fits(batch, mesh, dp)
    b = dp if b_ok else None
    # when batch can't shard (long_500k B=1), spread cache seq over data too
    seq = "model" if b_ok else (tuple(dp) + ("model",)
                                if len(dp) == 1 else ("pod", "data", "model"))

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1]
        stacked = int(names[0] == "body")
        core = leaf.shape[stacked:]
        if name in ("k", "v", "ckv", "kr"):          # (B, S, ...) kv caches
            spec = (b, seq) + (None,) * (len(core) - 2)
        elif name == "state":                        # (B, H, ...) fp32 states
            spec = (b, "model") + (None,) * (len(core) - 2)
        elif name == "conv":                         # (B, K-1, C)
            spec = (b, None, "model")
        elif name == "shift":                        # (B, 1, d)
            spec = (b, None, None)
        else:
            spec = (None,) * len(core)
        return _clean((None,) * stacked + spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, cache)


# ----------------------------------------------------------------------
# Activation constraint rules (consumed by distributed/ctx.py hooks)
# ----------------------------------------------------------------------
def activation_rules(cfg: ModelConfig, mesh: Mesh, mode: str,
                     global_batch: int) -> Dict[str, P]:
    dp = dp_axes(mesh)
    b = dp if _fits(global_batch, mesh, dp) else \
        (dp[-1:] if _fits(global_batch, mesh, dp[-1]) else None)
    b = tuple(b) if b else None
    if cfg.family in ("ssm", "hybrid"):
        # TP-heads: batch over data, heads/channels over model
        return {
            "residual": P(b, None, None),
            "heads4": P(b, None, "model", None),     # (B,S,H,P)
            "channels3": P(b, None, "model"),        # (B,S,C)
            "qkv": P(b, None, "model", None),
        }
    # "moe_dispatch" is the permuted (capacity, d) expert-contiguous buffer
    # every schedule policy emits (scheduling/base.py).  Its row order is a
    # data-dependent permutation of tokens, so it must never shard over
    # 'model' (the schedule is rank-local; the EP paths run under shard_map
    # and own their copies) — it rides the dp axes, matching the FSDP
    # weight-gather scheme of the grouped GEMMs.
    if mode == "decode":
        return {
            "residual": P(b, None, None),
            "qkv": P(b, None, None, None),
            "moe_dispatch": P(b, None),
        }
    # transformer train/prefill: SP/CP — sequence over model
    return {
        "residual": P(b, "model", None),
        "q_seq": P(b, "model", None, None),
        "kv_full": P(b, None, None, None),
        "moe_tokens": P(b, "model", None),
        "moe_dispatch": P(b, None),
    }
