"""repro.distributed subpackage."""
