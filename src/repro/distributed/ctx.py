"""Lightweight activation-sharding context.

Model code calls ``constrain("<hook>", x)`` at a handful of semantically
meaningful points (residual stream, qkv, mixer heads...).  Outside a
``use_rules`` context these are no-ops, so single-device tests and CPU
benchmarks never see a mesh; the launcher installs per-(arch x mode) rules
from distributed/sharding.py around the jitted step."""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_rules():
    return getattr(_state, "rules", None), getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Dict[str, P]):
    prev = current_rules()
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def constrain(name: str, x):
    rules, mesh = current_rules()
    if rules is None or name not in rules or x is None:
        return x
    spec = rules[name]
    # drop axes that do not divide the corresponding dim
    fixed = []
    for dim, ax in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(ax if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
