"""Persistent kernel-config cache: the autotuner's memory.

The cutotune-style contract (ROADMAP item 2): winning (block_m, block_n,
block_k) configs are keyed by

    ``<kernel>|E<E>|K<K>|N<N>|M<bucket>|<dtype>|<scheme>|<executor>``

where the M axis is a power-of-two *shape bucket* (decode capacities vary
step to step; tile choice does not care about the exact row count) and
``scheme`` is the kernel-level weight format (``dense``/``int8``/``int4``
— what the in-kernel dequant actually sees, DESIGN.md §8).

Two layers overlay:

* **packaged defaults** — ``default_cache.json`` next to this module,
  shipped with the repo (built by ``tools/build_tune_cache.py`` at the
  paper shapes);
* **local results** — ``results/tuning/cache.json`` (override with
  ``$REPRO_TUNE_CACHE``), written by the build tool / sweeps on the
  deployment machine.  Local entries win.

Files are versioned: a ``version`` mismatch (or unreadable JSON) silently
invalidates the whole file — stale caches degrade to the hard-coded
defaults, never to a crash.  ``kernels/ops.py`` consults ``lookup_block_
sizes`` at *trace* time (shapes are concrete Python ints while jax
traces), so a cache hit costs nothing per step.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Dict, Optional

CACHE_VERSION = 1
ENV_CACHE = "REPRO_TUNE_CACHE"
LOCAL_CACHE = os.path.join("results", "tuning", "cache.json")
_PACKAGED = pathlib.Path(__file__).with_name("default_cache.json")


def shape_bucket(m: int) -> int:
    """Next power of two >= m (min 8): the M axis of the cache key."""
    b = 8
    while b < m:
        b *= 2
    return b


def make_key(kernel: str, *, M: int, K: int, N: int, E: int,
             dtype: str = "float32", scheme: str = "dense",
             executor: str = "pallas") -> str:
    """The canonical cache key. M is bucketed; everything else is exact."""
    return (f"{kernel}|E{E}|K{K}|N{N}|M{shape_bucket(M)}"
            f"|{dtype}|{scheme}|{executor}")


class TuneCache:
    """A dict of key -> winning config record, JSON round-trippable.

    Record schema: ``{"block_m", "block_n", "block_k", "us",
    "default_us", "source"}`` — the winner's tile sizes, its measured
    microbenchmark time, the default config's time on the same
    measurement, and where the entry came from (``swept``/``manual``).
    """

    def __init__(self, entries: Optional[Dict[str, dict]] = None,
                 device: str = ""):
        self.entries: Dict[str, dict] = dict(entries or {})
        self.device = device

    # -- persistence ----------------------------------------------------
    def to_doc(self) -> dict:
        return {"version": CACHE_VERSION, "device": self.device,
                "entries": self.entries}

    @classmethod
    def from_doc(cls, doc: dict) -> "TuneCache":
        if not isinstance(doc, dict) or doc.get("version") != CACHE_VERSION:
            raise ValueError(
                f"tune cache version {doc.get('version') if isinstance(doc, dict) else doc!r} "
                f"!= {CACHE_VERSION} (stale cache; rebuild with "
                "tools/build_tune_cache.py)")
        return cls(doc.get("entries", {}), doc.get("device", ""))

    @classmethod
    def load(cls, path) -> Optional["TuneCache"]:
        """None on missing / unreadable / version-mismatched files — a
        stale cache invalidates itself rather than erroring."""
        try:
            with open(path) as f:
                return cls.from_doc(json.load(f))
        except (OSError, ValueError, json.JSONDecodeError):
            return None

    def save(self, path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_doc(), indent=1, sort_keys=True)
                     + "\n")

    # -- access ---------------------------------------------------------
    def lookup(self, key: str) -> Optional[dict]:
        return self.entries.get(key)

    def put(self, key: str, *, block_m: int, block_n: int, block_k: int,
            us: Optional[float] = None, default_us: Optional[float] = None,
            source: str = "swept", **extra) -> dict:
        """``extra`` carries kernel-family-specific fields (e.g. the
        ``sub_block`` family's ``block_m_min``) — additive: the record
        schema stays a superset of the v1 contract, so no version bump."""
        rec = {"block_m": int(block_m), "block_n": int(block_n),
               "block_k": int(block_k), "source": source}
        if us is not None:
            rec["us"] = float(us)
        if default_us is not None:
            rec["default_us"] = float(default_us)
        for k, v in extra.items():
            rec[k] = int(v) if isinstance(v, (bool, int)) else v
        self.entries[key] = rec
        return rec

    def merge(self, other: Optional["TuneCache"]) -> "TuneCache":
        """Overlay ``other`` on top of self (other's entries win)."""
        if other is not None:
            self.entries.update(other.entries)
            self.device = other.device or self.device
        return self


def local_cache_path() -> str:
    return os.environ.get(ENV_CACHE, LOCAL_CACHE)


_ACTIVE: Optional[TuneCache] = None


def get_cache() -> TuneCache:
    """The process-wide cache: packaged defaults overlaid by the local
    results file.  Loaded lazily once; ``reset_cache()`` drops it (tests,
    and tools that just rewrote the local file)."""
    global _ACTIVE
    if _ACTIVE is None:
        base = TuneCache.load(_PACKAGED) or TuneCache()
        _ACTIVE = base.merge(TuneCache.load(local_cache_path()))
    return _ACTIVE


def reset_cache() -> None:
    global _ACTIVE
    _ACTIVE = None


def lookup_block_sizes(kernel: str, *, M: int, K: int, N: int, E: int,
                       dtype: str = "float32", scheme: str = "dense",
                       executor: str = "pallas") -> Optional[dict]:
    """Trace-time consult: the winning record for this call's shape key,
    or None (caller keeps its hard-coded defaults)."""
    return get_cache().lookup(make_key(
        kernel, M=M, K=K, N=N, E=E, dtype=dtype, scheme=scheme,
        executor=executor))
