"""Kernel autotuning: persistent block-size cache + microbenchmark sweeps.

``kernels/ops.py`` consults ``lookup_block_sizes`` at trace time when a
call carries ``autotune=True`` (threaded from ``RunConfig.autotune``
through the dispatch config); ``tools/build_tune_cache.py`` and
``benchmarks/kernel_tune.py`` fill the cache.  DESIGN.md §12.
"""
from repro.tuning.cache import (CACHE_VERSION, TuneCache, get_cache,
                                local_cache_path, lookup_block_sizes,
                                make_key, reset_cache, shape_bucket)
from repro.tuning.autotune import (bench, candidate_configs, sweep_kernel,
                                   sweep_sub_block, tune_moe_layer)

__all__ = [
    "CACHE_VERSION", "TuneCache", "get_cache", "local_cache_path",
    "lookup_block_sizes", "make_key", "reset_cache", "shape_bucket",
    "bench", "candidate_configs", "sweep_kernel", "sweep_sub_block",
    "tune_moe_layer",
]
