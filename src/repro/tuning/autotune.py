"""Microbenchmark sweeps that fill the tune cache.

Sweeps run the RAW Pallas kernels (repro.kernels.grouped_gemm /
fused_gate_up) on synthetic operands with a round-robin block schedule —
the schedule's content does not change the kernel's tile geometry, which
is all the sweep measures.  Every candidate list ALWAYS contains the
hard-coded default config, and the winner is the argmin over min-of-reps
wall times of the same measurement — so ``winner <= default`` holds by
construction on the recorded numbers, which is exactly the no-regression
property the CI tune-smoke job asserts.

Off-TPU the kernels run in interpret mode: timings there order the
*interpreter's* cost, not the MXU's — fine for exercising the machinery
(CI), meaningless as a deployment cache.  ``tools/build_tune_cache.py``
refuses to ship a packaged cache from a non-TPU backend unless forced.
"""
from __future__ import annotations

import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import fused_gate_up as _fgu
from repro.kernels import grouped_gemm as _gg
from repro.kernels import ops
from repro.tuning.cache import TuneCache, make_key

DEFAULT_TARGETS = (128, 256, 512, 1024)
DEFAULT_BLOCK = 512            # the pre-autotuner hard-coded target
BLOCK_M_TARGETS = (64, 128, 256)


def candidate_configs(M: int, K: int, N: int, fmt: str = "dense", *,
                      targets: Sequence[int] = DEFAULT_TARGETS,
                      block_m_targets: Sequence[int] = BLOCK_M_TARGETS,
                      block_m: Optional[int] = None
                      ) -> Tuple[List[Tuple[int, int, int]],
                                 Tuple[int, int, int]]:
    """All distinct valid (block_m, block_n, block_k) tile configs the
    target grid induces, plus the default config (always a member)."""
    bms = ([block_m] if block_m else
           sorted({ops.pick_block(M, t, align=8) for t in block_m_targets}))
    cands = set()
    for bm, tn, tk in itertools.product(bms, targets, targets):
        cands.add((bm, ops.pick_block(N, tn), ops._pick_block_k(K, tk, fmt)))
    default = (block_m or ops.pick_block(M, 128, align=8),
               ops.pick_block(N, DEFAULT_BLOCK),
               ops._pick_block_k(K, DEFAULT_BLOCK, fmt))
    cands.add(default)
    return sorted(cands), default


def bench(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Min-of-reps wall seconds (min is the standard autotune statistic:
    it rejects one-sided scheduler noise)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _operands(E: int, M: int, K: int, N: int, fmt: str, dtype, seed: int):
    """Synthetic x/w(/scales) + a round-robin schedule at block size bm."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((M, K)), dtype)
    if fmt == "dense":
        w = jnp.asarray(rng.standard_normal((E, K, N)), dtype)
        ws = None
    elif fmt == "int8":
        w = jnp.asarray(rng.integers(-127, 128, (E, K, N)), jnp.int8)
        ws = jnp.asarray(rng.uniform(0.005, 0.02, (E, N)), jnp.float32)
    elif fmt == "int4":
        assert K % 2 == 0, K
        w = jnp.asarray(rng.integers(-128, 128, (E, K // 2, N)), jnp.int8)
        ws = jnp.asarray(rng.uniform(0.05, 0.2, (E, N)), jnp.float32)
    else:
        raise ValueError(fmt)
    return x, w, ws


def _schedule(E: int, M: int, bm: int):
    nb = M // bm
    be = jnp.asarray(np.arange(nb) % E, jnp.int32)
    ba = jnp.ones((nb,), jnp.int32)
    return be, ba


def sweep_kernel(kernel: str, *, E: int, M: int, K: int, N: int,
                 scheme: str = "dense", dtype=jnp.float32,
                 executor: str = "pallas", reps: int = 3,
                 block_m: Optional[int] = None, seed: int = 0,
                 targets: Sequence[int] = DEFAULT_TARGETS,
                 interpret: Optional[bool] = None) -> dict:
    """Time every candidate config of one kernel at one shape key.

    Returns ``{"key", "kernel", "shape", "records", "winner", "default"}``
    where records carry (block_m, block_n, block_k, us, tok_per_s) and
    winner/default are the argmin / default-config records.
    """
    if executor != "pallas":
        raise ValueError(f"only the pallas executor has tunable tiles "
                         f"(got {executor!r}); the xla scan owns no "
                         "block_n/block_k")
    if kernel not in ("grouped_gemm", "fused_gate_up"):
        raise ValueError(kernel)
    interp = ops._interp(interpret)
    x, w, ws = _operands(E, M, K, N, scheme, dtype, seed)
    cands, default = candidate_configs(M, K, N, scheme, targets=targets,
                                       block_m=block_m)

    def run(bm: int, bn: int, bk: int) -> float:
        be, ba = _schedule(E, M, bm)
        if kernel == "grouped_gemm":
            fn = lambda: _gg.grouped_gemm(
                x, w, be, ba, None, ws, block_m=bm, block_n=bn, block_k=bk,
                w_format=scheme, interpret=interp)
        else:
            fn = lambda: _fgu.fused_gate_up(
                x, w, w, be, ba, ws, ws, block_m=bm, block_n=bn, block_k=bk,
                w_format=scheme, interpret=interp)
        return bench(fn, reps=reps)

    records = []
    for bm, bn, bk in cands:
        sec = run(bm, bn, bk)
        records.append({"block_m": bm, "block_n": bn, "block_k": bk,
                        "us": sec * 1e6, "tok_per_s": M / sec,
                        "is_default": (bm, bn, bk) == default})
    winner = min(records, key=lambda r: r["us"])
    default_rec = next(r for r in records if r["is_default"])
    dt = jnp.dtype(dtype).name
    return {"key": make_key(kernel, M=M, K=K, N=N, E=E, dtype=dt,
                            scheme=scheme, executor=executor),
            "kernel": kernel, "executor": executor,
            "shape": {"E": E, "M": M, "K": K, "N": N, "dtype": dt,
                      "scheme": scheme},
            "records": records, "winner": winner, "default": default_rec}


# candidate sub-block floors for the dynamic schedule policy sweep
SUB_BLOCK_FLOORS = (8, 16, 32, 64)


def sweep_sub_block(*, E: int, top_k: int, d_model: int, d_ffn: int,
                    block_m: int, tokens: int = 256, dtype=jnp.float32,
                    reps: int = 3, seed: int = 0, executor: str = "pallas",
                    floors: Sequence[int] = SUB_BLOCK_FLOORS,
                    interpret: Optional[bool] = None) -> dict:
    """Sweep the dynamic policy's sub-block floor (``block_m_min`` —
    scheduling/dynamic.py ``sub_block``) for one routing shape.

    The physical effect of the floor is the grouped-GEMM grid granularity
    ``q = sub_block(block_m, floor)``: finer q trims light-expert padding
    but runs more, smaller grid steps.  The sweep times the down-proj
    grouped GEMM over the layer's routed-row capacity at each distinct q
    and records the winner under the ``sub_block`` kernel key
    (``K`` = block_m, ``N`` = 0 — the schedule owns no output tile).  The
    hard-coded default floor (8) is ALWAYS a candidate, so winner <=
    default holds by construction — the same no-regression contract as
    the tile sweeps.  ``plan_schedule`` consults the record at trace time
    under ``autotune=True``."""
    from repro.scheduling.dynamic import sub_block
    from repro.tuning.cache import shape_bucket
    if executor != "pallas":
        raise ValueError(f"only the pallas executor runs on the schedule's "
                         f"sub-block grid (got {executor!r})")
    interp = ops._interp(interpret)
    M = shape_bucket(tokens * top_k)
    K, N = d_ffn, d_model                       # down-proj geometry
    x, w, ws = _operands(E, M, K, N, "dense", dtype, seed)
    bn = ops.pick_block(N, DEFAULT_BLOCK)
    bk = ops._pick_block_k(K, DEFAULT_BLOCK, "dense")
    # distinct effective grid granularities among the candidate floors
    # (the default floor 8 is always a member)
    qs: Dict[int, int] = {}
    for floor in sorted(set(floors) | {8}):
        if floor > block_m:
            continue
        q = sub_block(block_m, floor)
        if M % q == 0:
            qs.setdefault(q, floor)

    records = []
    for q, floor in sorted(qs.items()):
        be, ba = _schedule(E, M, q)
        fn = lambda: _gg.grouped_gemm(
            x, w, be, ba, None, ws, block_m=q, block_n=bn, block_k=bk,
            w_format="dense", interpret=interp)
        sec = bench(fn, reps=reps)
        records.append({"block_m_min": floor, "sub_block": q,
                        "us": sec * 1e6, "tok_per_s": M / sec,
                        "is_default": floor == 8})
    winner = min(records, key=lambda r: r["us"])
    default_rec = next(r for r in records if r["is_default"])
    dt = jnp.dtype(dtype).name
    return {"key": make_key("sub_block", M=tokens * top_k, K=block_m, N=0,
                            E=E, dtype=dt, executor=executor),
            "kernel": "sub_block", "executor": executor,
            "shape": {"E": E, "M": M, "K": K, "N": N, "dtype": dt,
                      "block_m": block_m},
            "records": records, "winner": winner, "default": default_rec}


# kernel -> (K, N) as a function of (d_model, d_ffn): the three grouped
# GEMM shapes one MoE layer issues (gate+up fused, down projection, and
# the unfused-ablation up/gate shape shares fused_gate_up's geometry)
LAYER_SHAPES = {
    "fused_gate_up": lambda d, f: (d, f),       # (E,d,f) x2 -> silu*up
    "grouped_gemm": lambda d, f: (f, d),        # down: (E,f,d)
}


def tune_moe_layer(*, E: int, top_k: int, d_model: int, d_ffn: int,
                   tokens: int = 256, scheme: str = "dense",
                   dtype=jnp.float32, reps: int = 3,
                   targets: Sequence[int] = DEFAULT_TARGETS,
                   cache: Optional[TuneCache] = None,
                   seed: int = 0,
                   block_m: Optional[int] = None) -> List[dict]:
    """Sweep every kernel shape one MoE layer dispatches at ~``tokens``
    routed tokens, recording winners into ``cache`` when given.  With
    ``block_m`` set, also sweeps the dynamic policy's sub-block floor at
    this routing shape (the ``sub_block`` cache key)."""
    from repro.tuning.cache import shape_bucket
    M = shape_bucket(tokens * top_k)            # padded capacity bucket
    out = []
    for kernel, shape_fn in LAYER_SHAPES.items():
        K, N = shape_fn(d_model, d_ffn)
        res = sweep_kernel(kernel, E=E, M=M, K=K, N=N, scheme=scheme,
                           dtype=dtype, reps=reps, targets=targets,
                           seed=seed)
        if cache is not None:
            win = res["winner"]
            cache.put(res["key"], block_m=win["block_m"],
                      block_n=win["block_n"], block_k=win["block_k"],
                      us=win["us"], default_us=res["default"]["us"])
        out.append(res)
    if block_m is not None:
        res = sweep_sub_block(E=E, top_k=top_k, d_model=d_model,
                              d_ffn=d_ffn, block_m=block_m, tokens=tokens,
                              dtype=dtype, reps=reps, seed=seed)
        if cache is not None:
            win = res["winner"]
            cache.put(res["key"], block_m=win["sub_block"],
                      block_n=0, block_k=0, us=win["us"],
                      default_us=res["default"]["us"],
                      block_m_min=win["block_m_min"])
        out.append(res)
    return out
