"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices; smoke tests and benchmarks see the
plain 1-device CPU.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2, 16, 16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires forced host device count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# ----------------------------------------------------------------------
# Multi-process (multi-host) launch
# ----------------------------------------------------------------------
def init_distributed(coordinator_address: str, num_processes: int,
                     process_id: int) -> None:
    """Join this process to a ``jax.distributed`` group.

    After this returns, ``jax.devices()`` is the GLOBAL device list (all
    hosts) while ``jax.local_devices()`` stays per-host — every mesh built
    from the global list is a multi-host mesh and every collective in the
    EP dispatch spans hosts.  Must run before any other jax call touches
    the backend."""
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)


def multiprocess_compute_supported() -> bool:
    """Whether the active backend can RUN multi-process computations.

    ``jax.distributed.initialize`` succeeds on CPU (coordination service +
    global device visibility work) but jit dispatch across processes does
    not ("Multiprocess computations aren't implemented on the CPU
    backend"), so CPU smoke launches must fall back to a single-process
    forced-device-count mesh after the coordination handshake."""
    return jax.default_backend() != "cpu" or jax.process_count() == 1


def make_ep_mesh(ep: int | None = None, axis: str = "model"):
    """1-D expert-parallel mesh over the global device list.

    ``ep=None`` uses every visible device (multi-host when
    ``init_distributed`` ran first).  The EP dispatch only needs the one
    named axis; serving meshes that also batch-shard should build a 2-D
    mesh via ``make_debug_mesh``/``make_production_mesh`` instead."""
    n = len(jax.devices()) if ep is None else ep
    if len(jax.devices()) % n:
        raise ValueError(
            f"ep={n} does not divide the {len(jax.devices())}-device mesh")
    return jax.make_mesh((n,), (axis,))
