"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices; smoke tests and benchmarks see the
plain 1-device CPU.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2, 16, 16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires forced host device count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
