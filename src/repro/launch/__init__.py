"""repro.launch subpackage."""
