"""Production training launcher.

Wires mesh construction, parameter/batch/optimizer shardings, activation
rules, XLA latency-hiding flags, checkpointing, and the training loop for
any assigned architecture:

    # real TPU pod (mesh axes map onto the physical slice):
    python -m repro.launch.train --arch qwen2-7b --steps 1000 \\
        --ckpt-dir gs://.../ckpts

    # CPU rehearsal on a debug mesh (forces fake host devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \\
        --debug-mesh 2x4 --reduce --steps 20
"""
import os

# overlap compute with collectives on real hardware (no-op on CPU)
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_latency_hiding_scheduler=true")

import argparse  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--reduce", action="store_true",
                    help="use the reduced (smoke) config")
    ap.add_argument("--debug-mesh", default=None,
                    help="DxM, e.g. 2x4 (requires forced host devices)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, reduced
    from repro.compat import set_mesh
    from repro.distributed.ctx import use_rules
    from repro.distributed.sharding import (activation_rules, batch_specs,
                                            param_specs)
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.models.lm import RunConfig
    from repro.optim.adamw import OptConfig
    from repro.train.loop import train
    from repro.train.step import init_train_state

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    rc = RunConfig(q_chunk=0 if not args.reduce else 64, kv_chunk=512,
                   loss_chunk=512, remat=not args.reduce)
    opt = OptConfig(lr=args.lr, total_steps=args.steps,
                    warmup_steps=max(args.steps // 20, 1))

    mesh = None
    state_sh = batch_sh = rules = None
    if args.debug_mesh or len(jax.devices()) > 1:
        if args.debug_mesh:
            d, m = map(int, args.debug_mesh.split("x"))
            mesh = make_debug_mesh(d, m)
        else:
            mesh = make_production_mesh(multi_pod=args.multi_pod)
        ns = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        abstract = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.key(0), rc))
        ps = param_specs(abstract["params"], cfg, mesh)
        state_sh = ns({"params": ps, "opt": {"m": ps, "v": ps, "step": P()}})
        bs = batch_specs(cfg, mesh, "train", args.batch,
                         microbatched=args.accum > 1)
        batch_sh = ns(bs)
        rules = activation_rules(cfg, mesh, "train", args.batch)

    def run():
        return train(cfg, rc, opt, steps=args.steps, batch=args.batch,
                     seq=args.seq, accum=args.accum, ckpt_dir=args.ckpt_dir,
                     save_every=args.save_every, mesh=mesh,
                     state_shardings=state_sh, batch_shardings=batch_sh)

    if mesh is not None:
        with set_mesh(mesh), use_rules(mesh, rules):
            out = run()
    else:
        out = run()
    h = out["history"]
    print(f"done: ce {h[0]['ce']:.4f} -> {h[-1]['ce']:.4f}; "
          f"stragglers={len(out['stragglers'])}")


if __name__ == "__main__":
    main()
