"""ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

``cell_inputs`` returns everything needed to ``jax.jit(step).lower(...)`` a
cell without allocating a single real array: abstract params/opt-state (via
``jax.eval_shape`` over the real initializers), abstract batches and KV
caches, and the matching NamedSharding trees from distributed/sharding.py.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, ShapeConfig, get_config
from repro.distributed.sharding import (activation_rules, batch_specs,
                                        cache_specs, param_specs)
from repro.models.lm import RunConfig, init_cache, init_params
from repro.optim.adamw import init_opt_state

# per-(arch-family, shape) grad-accumulation microbatch counts
ACCUM = {
    "train_4k": 4,
}
# memory-driven overrides (param + moment footprint)
ACCUM_OVERRIDES = {
    ("deepseek-v2-236b", "train_4k"): 2,
}


def dryrun_runconfig(cfg: ModelConfig, shape: ShapeConfig, *,
                     ep: bool = True) -> RunConfig:
    """Execution policy for full-scale lowering (see DESIGN.md §5)."""
    is_seq_model = cfg.family in ("ssm", "hybrid")
    return RunConfig(
        compute_dtype=jnp.bfloat16,
        param_dtype=jnp.bfloat16,
        executor="xla",
        ep=bool(cfg.is_moe and ep),
        remat=(shape.kind == "train"),
        # CP: full-q chunk (each rank computes its sequence shard);
        # TP-heads archs chunk both ways to bound score buffers.
        q_chunk=(1024 if is_seq_model else 0),
        kv_chunk=1024,
        loss_chunk=512,
        capacity_factor=2.0,
    )


def accum_steps(arch: str, shape: ShapeConfig) -> int:
    return ACCUM_OVERRIDES.get((arch, shape.name),
                               ACCUM.get(shape.name, 1))


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig,
                   accum: int = 1) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        B, S = shape.global_batch, 1
    lead: Tuple[int, ...] = ()
    if accum > 1:
        assert B % accum == 0
        lead, B = (accum,), B // accum
    f32 = jnp.bfloat16
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.encoder_only:
        batch["features"] = jax.ShapeDtypeStruct(
            lead + (B, S, cfg.d_model), f32)
        batch["labels"] = jax.ShapeDtypeStruct(lead + (B, S), jnp.int32)
        batch["mask"] = jax.ShapeDtypeStruct(lead + (B, S), jnp.bool_)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct(lead + (B, S), jnp.int32)
    if cfg.cross_attn_every:
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            lead + (B, cfg.n_image_tokens, cfg.d_model), f32)
    return batch


class CellInputs(NamedTuple):
    step_fn: Any
    args: tuple                 # abstract args for .lower(*args)
    in_shardings: tuple
    out_shardings: Any
    rules: Dict[str, P]
    rc: RunConfig
    meta: Dict[str, Any]


def cell_inputs(arch: str, shape: ShapeConfig, mesh: Mesh,
                rc: Optional[RunConfig] = None, *,
                accum: Optional[int] = None, layout: str = "fsdp",
                pin_grads: bool = False,
                quant_experts: bool = False) -> CellInputs:
    cfg = get_config(arch)
    rc = rc or dryrun_runconfig(cfg, shape)
    from repro.quantization import resolve_quant_cli
    quant = resolve_quant_cli(rc.quant, quant_experts)
    ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))

    def _init(key):
        p = init_params(cfg, key, param_dtype=rc.param_dtype)
        if quant != "none" and cfg.is_moe:
            from repro.quantization import quantize_params_tree
            p = quantize_params_tree(p, quant)
        return p

    params_abs = jax.eval_shape(_init, jax.random.key(0))
    pspecs = param_specs(params_abs, cfg, mesh, mode=layout)

    if shape.kind == "train":
        A = accum if accum is not None else accum_steps(arch, shape)
        from repro.optim.adamw import OptConfig
        from repro.train.step import make_train_step
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        state_abs = {"params": params_abs, "opt": opt_abs}
        state_specs = {"params": pspecs, "opt": ospecs}
        batch = abstract_batch(cfg, shape, A)
        bspecs = batch_specs(cfg, mesh, "train", shape.global_batch // A,
                             microbatched=(A > 1))
        bspecs = {k: bspecs[k] for k in batch}
        step = make_train_step(cfg, rc, OptConfig(), accum_steps=A,
                               grad_shardings=ns(pspecs) if pin_grads
                               else None)
        return CellInputs(
            step, (state_abs, batch),
            (ns(state_specs), ns(bspecs)),
            (ns(state_specs), None),
            activation_rules(cfg, mesh, "train", shape.global_batch // A),
            rc, {"accum": A, "mode": "train", "layout": layout,
                 "pin_grads": pin_grads})

    if shape.kind == "prefill":
        batch = abstract_batch(cfg, shape)
        bspecs = {k: v for k, v in batch_specs(
            cfg, mesh, "prefill", shape.global_batch).items() if k in batch}
        if cfg.encoder_only:
            from repro.serve.step import make_forward_only
            step = make_forward_only(cfg, rc)
            return CellInputs(
                step, (params_abs, batch), (ns(pspecs), ns(bspecs)), None,
                activation_rules(cfg, mesh, "prefill", shape.global_batch),
                rc, {"mode": "encode", "layout": layout})
        from repro.serve.step import make_prefill_step
        cache_abs = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                               jnp.bfloat16))
        cspecs = cache_specs(cache_abs, cfg, mesh, shape.global_batch)
        step = make_prefill_step(cfg, rc)
        return CellInputs(
            step, (params_abs, batch, cache_abs),
            (ns(pspecs), ns(bspecs), ns(cspecs)),
            (None, ns(cspecs)),
            activation_rules(cfg, mesh, "prefill", shape.global_batch),
            rc, {"mode": "prefill", "layout": layout})

    # decode
    from repro.serve.step import make_decode_step
    batch = abstract_batch(cfg, shape)
    bspecs = {k: v for k, v in batch_specs(
        cfg, mesh, "decode", shape.global_batch).items() if k in batch}
    cache_abs = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           jnp.bfloat16))
    cspecs = cache_specs(cache_abs, cfg, mesh, shape.global_batch)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    step = make_decode_step(cfg, rc)
    return CellInputs(
        step, (params_abs, batch, cache_abs, pos),
        (ns(pspecs), ns(bspecs), ns(cspecs), NamedSharding(mesh, P())),
        (None, None, ns(cspecs)),
        activation_rules(cfg, mesh, "decode", shape.global_batch),
        rc, {"mode": "decode", "layout": layout})
