"""Serving launcher: load (or init) weights, optionally quantize the
routed experts under a registered scheme (`--quant`, DESIGN.md §8 — int8
per-expert is the §Perf cell-3 deployment layout), and run batched
requests through the continuous-batching engine — all active slots decode
in ONE jitted step over a single batched KV cache, so every MoE layer
dispatches the whole decode batch in one plan.

    PYTHONPATH=src python -m repro.launch.serve --arch moonshot-v1-16b-a3b \\
        --reduce --requests 6 --quant int8_expert --executor xla --slots 4
"""
import argparse


def main():
    from repro.execution import available_executors
    from repro.quantization import available_schemes, resolve_quant_cli
    from repro.sampling import available_samplers
    from repro.scheduling import available_policies
    from repro.serve.admission import available_admission_policies

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2,
                    help="decode slots = rows of the batched KV cache; all "
                         "active slots decode together in one jitted step")
    ap.add_argument("--capacity", type=int, default=128,
                    help="per-slot KV cache capacity (tokens)")
    ap.add_argument("--kv-block-size", type=int, default=None,
                    help="paged KV cache block size in tokens (DESIGN.md "
                         "§9); 0 forces the contiguous pre-paging cache; "
                         "default: auto (paged wherever the architecture's "
                         "caches are positional KV)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="content-hash full prompt blocks and share them "
                         "across requests (paged mode; default on)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="chunked prefill: prompt tokens fed per step into "
                         "the decode step's shared dispatch plan (paged "
                         "mode; prefill never stalls decoding slots)")
    ap.add_argument("--max-steps", type=int, default=512,
                    help="decode-step budget for the whole run; requests "
                         "still in flight when it runs out are reported "
                         "(done=False, partial output kept)")
    ap.add_argument("--quant", default=None, choices=available_schemes(),
                    help="expert-weight quantization scheme "
                         "(repro.quantization registry; default: none)")
    ap.add_argument("--quant-experts", action="store_true",
                    help="DEPRECATED: alias for --quant int8_expert")
    ap.add_argument("--executor", default="xla",
                    choices=available_executors(),
                    help="MoE executor backend (repro.execution registry)")
    ap.add_argument("--autotune", action="store_true",
                    help="consult the persistent kernel tune cache "
                         "(results/tuning/cache.json, DESIGN.md §12) for "
                         "swept block sizes instead of the hard-coded "
                         "defaults (pallas executor)")
    ap.add_argument("--paged-attn", default="auto",
                    choices=("auto", "fused", "gather"),
                    help="paged decode attention path: 'fused' = one "
                         "Pallas kernel walks the block table (no "
                         "gathered-cache materialization), 'gather' = "
                         "pool gather + flash, 'auto' = fused iff the "
                         "executor is pallas")
    ap.add_argument("--schedule-policy", default="dynamic",
                    choices=available_policies(),
                    help="MoE schedule policy (serving default: dynamic)")
    ap.add_argument("--sampling", default="greedy",
                    choices=available_samplers(),
                    help="token selection (repro.sampling registry); "
                         "greedy keeps the bitwise-exact argmax path, the "
                         "stochastic methods draw keyed per-request "
                         "streams on device (one host sync per step)")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation for --sampling top_k (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus mass for --sampling top_p (1.0 = off)")
    ap.add_argument("--seed", type=int, default=0,
                    help="engine-level sampling seed base; request i draws "
                         "from stream seed+i (stochastic methods only)")
    ap.add_argument("--spec-draft", default=None, metavar="ARCH",
                    help="enable speculative decoding with this draft "
                         "architecture (e.g. smollm-360m; reduced "
                         "alongside --reduce, vocab aligned to the "
                         "target); requires the paged engine")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per slot per speculative "
                         "round (target verifies k+1 positions in one "
                         "forward)")
    ap.add_argument("--admission", default="fcfs",
                    choices=available_admission_policies(),
                    help="which pending request gets a freed slot "
                         "(fcfs, sjf = shortest prompt, prefix_hit = "
                         "warmest cached prefix, slo = TTFT-deadline "
                         "feasibility with preemption)")
    ap.add_argument("--stream", action="store_true",
                    help="serve through the open-stream front-end "
                         "(repro.serve.frontend) and print each token as "
                         "the step's host sync retires it — streamed "
                         "sequences are bitwise-identical to the closed-"
                         "batch run")
    ap.add_argument("--slo-ttft", type=float, default=None, metavar="S",
                    help="per-request time-to-first-token deadline "
                         "(seconds); pair with --admission slo")
    ap.add_argument("--slo-tpot", type=float, default=None, metavar="S",
                    help="per-request time-per-output-token budget "
                         "(seconds); pair with --admission slo")
    ap.add_argument("--loadgen", default=None, metavar="PATTERN",
                    help="replay a seeded arrival trace (poisson | burst "
                         "| shared_prefix | longtail) on VIRTUAL time "
                         "through the front-end instead of a closed "
                         "batch; writes the goodput artifact to "
                         "results/serve/loadgen_<arch>[_smoke].json")
    ap.add_argument("--smoke", action="store_true",
                    help="with --loadgen: tiny trace for CI")
    ap.add_argument("--calibrate", action="store_true",
                    help="with --loadgen: advance the virtual clock by "
                         "the measured per-step wall-time EWMA instead "
                         "of a fixed 0.05 s (host-dependent goodput)")
    ap.add_argument("--trace", nargs="?", const="results/trace/serve.json",
                    default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the step "
                         "timeline (admit / prefix probe / assemble / "
                         "forward / host sync / retire + recompile and "
                         "slow-step instants); default path "
                         "results/trace/serve.json")
    ap.add_argument("--metrics-out", nargs="?",
                    const="results/serve/metrics.json", default=None,
                    metavar="PATH",
                    help="write the metrics-registry snapshot (counters/"
                         "gauges/histograms + TTFT/TPOT latency "
                         "percentiles) as JSON")
    ap.add_argument("--device-trace", default=None, metavar="DIR",
                    help="bracket the run in a jax.profiler device trace "
                         "written to DIR (best-effort: degrades to a "
                         "warning when the profiler is unavailable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--distributed", action="store_true",
                    help="expert-parallel serving over every visible "
                         "device: per-host admission queues feed ONE "
                         "global decode step (serve/distributed.py). "
                         "With --num-processes > 1 the processes join a "
                         "jax.distributed group first (multi-host mesh)")
    ap.add_argument("--coordinator", default="localhost:12355",
                    help="jax.distributed coordinator address "
                         "(process 0 binds it)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--hosts", type=int, default=None,
                    help="admission host-queue count (default: "
                         "num-processes)")
    ap.add_argument("--ep-devices", type=int, default=None,
                    help="devices on the EP mesh axis (default: all); "
                         "single-process CPU runs force at least this "
                         "many host devices")
    ap.add_argument("--ep-overlap", action="store_true",
                    help="software-pipeline the sharded EP dispatch "
                         "(a2a of microbatch i+1 overlaps GEMMs of i)")
    ap.add_argument("--ep-microbatches", type=int, default=2)
    ap.add_argument("--ep-decode-layout", default="replicated",
                    choices=("replicated", "sharded"),
                    help="EP token layout for decode steps")
    args = ap.parse_args()

    import contextlib
    import os

    if args.distributed and args.num_processes == 1:
        # single-process fallback (CPU smoke): the EP collectives still
        # need >1 device, so force a multi-device host platform BEFORE
        # jax initializes
        n_dev = args.ep_devices or 2
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{n_dev}").strip()

    import numpy as np
    import jax

    from repro.configs import get_config, reduced
    from repro.models import RunConfig, init_params
    from repro.obs import (NOOP, Observability, device_trace, drop_summary,
                           latency_summary)
    from repro.sampling import SamplingConfig
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.frontend import ServingFrontend
    from repro.serve.loadgen import make_virtual_obs, replay, synth_trace
    from repro.spec import SpecEngine, make_draft_config

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")

    mesh_stack = contextlib.ExitStack()
    if args.distributed:
        from repro.compat import set_mesh
        from repro.launch.mesh import (init_distributed, make_ep_mesh,
                                       multiprocess_compute_supported)
        if args.num_processes > 1:
            init_distributed(args.coordinator, args.num_processes,
                             args.process_id)
            if not multiprocess_compute_supported():
                raise SystemExit(
                    "the active backend cannot run multi-process "
                    "computations (CPU): re-launch single-process with "
                    "--ep-devices N for a forced-host-device mesh")
        mesh = make_ep_mesh(args.ep_devices, axis="model")
        mesh_stack.enter_context(set_mesh(mesh))
        print(f"distributed serving: {jax.process_count()} process(es), "
              f"EP mesh {mesh.devices.shape} over axis 'model', "
              f"decode layout {args.ep_decode_layout}, overlap "
              f"{'on' if args.ep_overlap else 'off'}")

    params = init_params(cfg, jax.random.key(0))
    if args.ckpt_dir:
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(args.ckpt_dir)
        state = mgr.restore(jax.eval_shape(lambda: {
            "params": init_params(cfg, jax.random.key(0))}))
        params = state["params"]
    quant = resolve_quant_cli(args.quant, args.quant_experts)
    if quant != "none" and cfg.is_moe:
        print(f"routed experts quantized under scheme {quant!r} "
              f"(serving layout)")

    if args.loadgen:
        clock, obs = make_virtual_obs(enabled=True)
    else:
        clock = None
        obs = (Observability.memory()
               if (args.trace or args.metrics_out or args.device_trace)
               else NOOP)
    sampling = SamplingConfig(method=args.sampling,
                              temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed)
    kw = dict(slots=args.slots,
              capacity=args.capacity, admission=args.admission,
              kv_block_size=args.kv_block_size,
              prefix_cache=args.prefix_cache,
              prefill_chunk=args.prefill_chunk, obs=obs,
              sampling=sampling,
              rc=RunConfig(q_chunk=64, kv_chunk=64,
                           executor=args.executor,
                           schedule_policy=args.schedule_policy,
                           quant=quant if cfg.is_moe else "none",
                           moe_stats=bool(cfg.is_moe),
                           autotune=args.autotune,
                           paged_attn=args.paged_attn,
                           ep=bool(args.distributed and cfg.is_moe),
                           ep_overlap=args.ep_overlap,
                           ep_microbatches=args.ep_microbatches,
                           ep_decode_layout=args.ep_decode_layout))
    if args.spec_draft:
        draft_cfg = get_config(args.spec_draft)
        if args.reduce:
            draft_cfg = reduced(draft_cfg)
        draft_cfg = draft_cfg.replace(vocab_size=cfg.vocab_size)
        draft_params = init_params(draft_cfg, jax.random.key(1))
        engine = SpecEngine(cfg, params, draft_cfg=draft_cfg,
                            draft_params=draft_params, spec_k=args.spec_k,
                            **kw)
        print(f"speculative decoding: draft {draft_cfg.name} proposes "
              f"k={args.spec_k} tokens/slot/round; target verifies "
              f"{args.spec_k + 1} positions per slot in one forward")
    else:
        engine = ServeEngine(cfg, params, **kw)
    if engine.paged:
        print(f"paged KV cache: {engine.kv.n_blocks} blocks x "
              f"{engine.kv.block_size} tokens, prefix cache "
              f"{'on' if engine.kv.prefix_cache else 'off'}, "
              f"prefill chunk {engine.prefill_chunk}")
    else:
        print("contiguous KV cache (non-pageable family or "
              "--kv-block-size 0)")
    if args.loadgen:
        import json
        import pathlib

        n = 12 if args.smoke else 24
        trace = synth_trace(args.loadgen, seed=0, n=n, rate=8.0,
                            vocab=cfg.vocab_size, max_new=args.max_new,
                            slo_ttft=args.slo_ttft if args.slo_ttft
                            is not None else 0.4,
                            slo_tpot=args.slo_tpot,
                            burst_size=6, prompt_hi=40)
        rec = replay(engine, trace, clock=clock,
                     step_time=None if args.calibrate else 0.05, seed=0,
                     pattern=args.loadgen,
                     max_steps=min(args.max_steps, 1024))
        rec.pop("outputs", None)
        out_path = pathlib.Path("results/serve")
        out_path.mkdir(parents=True, exist_ok=True)
        out_path = out_path / (f"loadgen_{args.arch}"
                               f"{'_smoke' if args.smoke else ''}.json")
        out_path.write_text(json.dumps(
            {"arch": args.arch, "reduced": args.reduce,
             "virtual_time": True,
             "step_time_mode": rec["step_time_mode"],
             "records": [rec]}, indent=1))
        print(f"loadgen {args.loadgen}: {rec['completed']}/"
              f"{rec['n_requests']} completed, goodput "
              f"{rec['goodput_rps']:.3f} req/s, attainment "
              f"{rec['slo_attainment']:.2f}, preempted {rec['preempted']}, "
              f"resumed {rec['resumed']}, TTFT p50 "
              f"{rec['ttft_p50_s']} s")
        print(f"loadgen artifact -> {out_path}")
        return

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        rng.integers(3, 9)).astype(np.int32),
                    max_new=args.max_new,
                    slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot)
            for i in range(args.requests)]
    bracket = (device_trace(args.device_trace) if args.device_trace
               else contextlib.nullcontext())
    with bracket:
        if args.stream:
            fe = ServingFrontend(engine)
            handles = [fe.submit(r.prompt, max_new=r.max_new, rid=r.rid,
                                 slo_ttft=r.slo_ttft, slo_tpot=r.slo_tpot,
                                 on_token=lambda req, tok:
                                 print(f"  stream rid={req.rid} "
                                       f"tok[{len(req.out) - 1}]={tok}"))
                       for r in reqs]
            done = fe.drain(max_steps=args.max_steps)
            reqs = handles
            engine.dropped = [r for r in reqs if not r.done]
        elif args.distributed:
            from repro.serve.distributed import DistributedServeLoop
            loop = DistributedServeLoop(
                engine, n_hosts=args.hosts or max(1, args.num_processes),
                admission=args.admission)
            done = loop.run(reqs, max_steps=args.max_steps)
        else:
            done = engine.run(reqs, max_steps=args.max_steps)
    for r in reqs:
        tag = "" if r.done else "  [INCOMPLETE: step budget exhausted]"
        print(f"req {r.rid}: {r.prompt.tolist()} -> {r.out}{tag}")
        if r.stats:
            sched = {k.split("/", 1)[1]: round(v, 3)
                     for k, v in r.stats.items() if k.startswith("sched/")}
            if sched:
                print(f"  plan stats (last step, shared by "
                      f"{int(r.stats.get('serve/decode_batch', 1))} slot(s), "
                      f"summed over moe layers): {sched}")
    print(f"{len(done)}/{len(reqs)} requests completed")
    if isinstance(engine, SpecEngine):
        print(f"speculation: {engine.n_spec_rounds} rounds, "
              f"{engine.n_accepted}/{engine.n_drafted} drafts accepted "
              f"(rate {engine.acceptance_rate:.2f}); "
              f"{engine.n_forwards} target + {engine.n_draft_forwards} "
              f"draft forwards")
    # completion percentiles over COMPLETED requests only — censored
    # (dropped/preempted) stats are rolled up separately below
    lat = latency_summary([r for r in reqs if r.done])
    if any(lat.values()):
        for fam in ("ttft_s", "tpot_s", "queue_wait_s", "e2e_s"):
            agg = lat.get(fam)
            if agg:
                print(f"  {fam:>13}: mean {agg['mean'] * 1e3:8.2f} ms  "
                      f"p50 {agg['p50'] * 1e3:8.2f} ms  "
                      f"p99 {agg['p99'] * 1e3:8.2f} ms  (n={agg['n']})")
    if engine.paged:
        print(f"paged-cache stats: {engine.kv.stats()}")
    drops = drop_summary(reqs)
    if drops:
        wait = drops["wait_s"]
        tail = (f"; censored wait p50 {wait['p50'] * 1e3:.1f} ms"
                if wait else "")
        print(f"WARNING: {drops['n']} request(s) did not complete under "
              f"--max-steps={args.max_steps} "
              f"({drops['dropped']} dropped, {drops['preempted']} "
              f"preempted-unresumed; rids {drops['rids']}); "
              f"{drops['tokens_out']} partial token(s) retained on "
              f"Request.out{tail}")
    if args.trace:
        path = engine.obs.tracer.save(args.trace)
        print(f"chrome trace ({len(engine.obs.tracer.events)} events) "
              f"-> {path}")
    if args.metrics_out:
        extra = {"latency": lat}
        if engine.paged:
            extra["kv_stats"] = engine.kv.stats()
        engine.obs.metrics.to_json(args.metrics_out, extra=extra)
        print(f"metrics snapshot -> {args.metrics_out}")


if __name__ == "__main__":
    main()
