import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). 512 placeholder host devices back the production
# meshes: 16x16 single-pod, 2x16x16 multi-pod.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Per cell: build abstract inputs (launch/specs.py), install sharding rules,
``jax.jit(step, in_shardings, out_shardings).lower(...).compile()`` on the
production mesh, then record ``memory_analysis()`` / ``cost_analysis()`` and
the parsed collective-byte totals (analysis/hlo.py) to a JSON file that
EXPERIMENTS.md §Dry-run / §Roofline read from.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs-file cells.txt]
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

RESULT_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: bool = False, accum=None, layout: str = "fsdp",
             pin_grads: bool = False, capacity_factor=None,
             variant: str = "", drop_rules=(),
             quant: str = "none", executor: str = None) -> dict:
    import jax

    from repro.analysis.hlo import collective_report
    from repro.configs import SHAPE_BY_NAME, cell_is_runnable, get_config
    from repro.compat import cost_analysis, set_mesh
    from repro.distributed.ctx import use_rules
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import cell_inputs

    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skip", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.launch.specs import dryrun_runconfig
    rc = dryrun_runconfig(cfg, shape)
    if capacity_factor is not None:
        rc = rc._replace(capacity_factor=capacity_factor)
    if executor is not None:
        rc = rc._replace(executor=executor)
    rc = rc._replace(quant=quant)
    ci = cell_inputs(arch, shape, mesh, rc, accum=accum, layout=layout,
                     pin_grads=pin_grads)
    for r in drop_rules:
        ci.rules.pop(r, None)
    if variant:
        rec["variant"] = variant
    # donate the mutable aggregate (train state / decode cache) so XLA
    # aliases it in-place instead of holding input+output copies live
    donate = ()
    if ci.meta.get("mode") == "train":
        donate = (0,)
    elif ci.meta.get("mode") == "decode":
        donate = (2,)
    t0 = time.time()
    try:
        with set_mesh(mesh), use_rules(mesh, ci.rules):
            jitted = jax.jit(ci.step_fn, in_shardings=ci.in_shardings,
                             out_shardings=ci.out_shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*ci.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = cost_analysis(compiled)
        hlo = compiled.as_text()
        from repro.models.lm import group_structure
        _, _, n_groups, _ = group_structure(cfg)
        coll = collective_report(hlo, layer_trips=n_groups,
                                 accum_trips=ci.meta.get("accum", 1))
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            meta=ci.meta,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                      None),
            },
            cost={k: cost.get(k) for k in
                  ("flops", "bytes accessed", "transcendentals")
                  if k in cost},
            collectives=coll,
        )
        if save_hlo:
            p = RESULT_DIR / f"{arch}.{shape_name}.{rec['mesh']}.hlo"
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(hlo)
            rec["hlo_path"] = str(p)
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def all_cells():
    from repro.configs import ARCH_NAMES, SHAPES
    return [(a, s.name) for a in ARCH_NAMES for s in SHAPES]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--layout", default="fsdp", choices=["fsdp", "serve_tp"])
    ap.add_argument("--pin-grads", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--variant", default="",
                    help="tag appended to the output filename (perf runs)")
    ap.add_argument("--drop-rule", action="append", default=[],
                    help="remove an activation-sharding rule (perf exp)")
    ap.add_argument("--quant", default=None,
                    help="expert-weight quantization scheme "
                         "(repro.quantization registry; default: none)")
    ap.add_argument("--quant-experts", action="store_true",
                    help="DEPRECATED: alias for --quant int8_expert")
    ap.add_argument("--executor", default=None,
                    help="MoE executor backend override "
                         "(repro.execution registry; default: xla)")
    ap.add_argument("--out", default=str(RESULT_DIR))
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    if args.all:
        # sweep in subprocesses (fresh XLA state per cell; fault isolation)
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        failures = 0
        for arch, shape in all_cells():
            for mp in meshes:
                tag = f"{arch}.{shape}.{'2x16x16' if mp else '16x16'}"
                dest = out / f"{tag}.json"
                if dest.exists() and \
                        json.loads(dest.read_text()).get("status") == "ok":
                    print(f"[skip-done] {tag}", flush=True)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", str(out)]
                if mp:
                    cmd.append("--multi-pod")
                if args.save_hlo:
                    cmd.append("--save-hlo")
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True)
                dt = time.time() - t0
                status = "?"
                if dest.exists():
                    status = json.loads(dest.read_text()).get("status")
                print(f"[{status:5s}] {tag}  {dt:6.1f}s", flush=True)
                if status not in ("ok", "skip"):
                    failures += 1
                    if r.stderr:
                        print(r.stderr[-2000:], flush=True)
        return 1 if failures else 0

    from repro.quantization import resolve_quant_cli
    rec = run_cell(args.arch, args.shape, args.multi_pod,
                   save_hlo=args.save_hlo, accum=args.accum,
                   layout=args.layout, pin_grads=args.pin_grads,
                   capacity_factor=args.capacity_factor,
                   variant=args.variant, drop_rules=args.drop_rule,
                   quant=resolve_quant_cli(args.quant, args.quant_experts),
                   executor=args.executor)
    tag = f"{args.arch}.{args.shape}.{rec['mesh']}"
    if args.variant:
        tag += f".{args.variant}"
    dest = out / f"{tag}.json"
    dest.write_text(json.dumps(rec, indent=2))
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=2))
    if rec["status"] == "error":
        print(rec.get("traceback", ""), file=sys.stderr)
    return 0 if rec["status"] in ("ok", "skip") else 1


if __name__ == "__main__":
    sys.exit(main())
