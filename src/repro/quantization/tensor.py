"""`QuantTensor`: a compressed expert-weight stack that is a real pytree.

The pre-redesign version was a NamedTuple `(q, s, dtype)` — which made the
dequant *dtype* a tree leaf: `jax.tree_util` flattened it as data, `jit`
re-traced nothing on dtype changes, and checkpoint/sharding code had to
special-case the phantom leaf.  Here the tensor is registered with
`register_pytree_with_keys_class`:

* **array leaves** — ``q`` (the stored payload, layout owned by the scheme:
  e.g. ``(E, K, N) int8`` or two-nibbles-per-byte ``(E, K//2, N) int8``)
  and ``s`` (the scales, ``(E, 1, 1)`` per-expert or ``(E, 1, N)``
  per-output-channel, f32);
* **static aux** — the dequant target ``dtype`` and the ``scheme`` name.
  Both are hashable, so they key jit caches: a jitted function taking a
  quantized tree re-traces exactly when the scheme (or dtype) changes and
  never when only the payload does (tested in tests/test_quantization.py).

Because the leaves are ordinary arrays with the expert axis leading,
QuantTensors flow through `lax.scan` over stacked layer groups, shard_map
partition specs, checkpoint flatten/unflatten, and `jax.tree.map` with no
special-casing anywhere.

Inside the dispatch scans a QuantTensor acts like the dense ``(E, K, N)``
weight stack it compresses: ``w[e]`` gathers the compressed block + scale
and dequantizes in-register via the scheme's ``dequantize`` — this is the
per-block dequant hook the grouped-GEMM scan calls (DESIGN.md §8).
"""
from __future__ import annotations

import jax
import numpy as np


@jax.tree_util.register_pytree_with_keys_class
class QuantTensor:
    """Scheme-tagged compressed weight stack (see module docstring)."""

    __slots__ = ("q", "s", "dtype", "scheme", "meta")

    def __init__(self, q, s, dtype, scheme: str, meta: tuple = ()):
        self.q = q
        self.s = s
        # normalize so aux_data hashes/compares stably across spellings
        # (jnp.float32 vs np.dtype('float32') vs "float32")
        self.dtype = np.dtype(dtype)
        self.scheme = scheme
        # scheme-owned static layout tags as a hashable (key, value)
        # tuple — e.g. int4_packed's ("pad_k", 1) marks an odd logical K
        # stored with one zero pad row (stripped on dequant)
        self.meta = tuple(meta)

    # -- pytree protocol ------------------------------------------------
    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey("q"), self.q),
                 (jax.tree_util.GetAttrKey("s"), self.s)),
                (self.dtype, self.scheme, self.meta))

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, s = children
        return cls(q, s, aux[0], aux[1], aux[2])

    # -- dense-stack interface (what the dispatch pipeline consumes) ----
    @property
    def _scheme(self):
        from repro.quantization.base import get_scheme
        return get_scheme(self.scheme)

    @property
    def _pad_k(self) -> int:
        return dict(self.meta).get("pad_k", 0)

    def _strip(self, w):
        """Drop stored pad rows (packed schemes with odd logical K)."""
        return w[..., :w.shape[-2] - self._pad_k, :] if self._pad_k else w

    @property
    def shape(self):
        """LOGICAL shape of the dense stack this compresses (a packed
        scheme stores fewer physical elements; pad rows excluded)."""
        shp = list(self._scheme.logical_shape(self.q.shape))
        shp[-2] -= self._pad_k
        return tuple(shp)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def nbytes(self) -> int:
        """Stored payload bytes — what a weight gather actually moves."""
        return int(self.q.size) * self.q.dtype.itemsize \
            + int(self.s.size) * self.s.dtype.itemsize

    def __getitem__(self, idx):
        """Gather + dequantize: the per-block hook of the grouped-GEMM
        scans.  ``idx`` may be a traced scalar (a `lax.scan` step's
        block-expert id) or an index array (leading axes only — the
        trailing (K, N) block stays whole, so pad rows strip cleanly)."""
        return self._strip(
            self._scheme.dequantize(self.q[idx], self.s[idx], self.dtype))

    def materialize(self):
        """Full dense (E, K, N) stack in the target dtype (what
        schedule-free backends such as the dense oracle consume)."""
        return self._strip(
            self._scheme.dequantize(self.q, self.s, self.dtype))

    def with_dtype(self, dtype) -> "QuantTensor":
        """Same payload, different dequant target (the layer applies the
        model's compute dtype at dispatch time)."""
        if np.dtype(dtype) == self.dtype:
            return self
        return QuantTensor(self.q, self.s, dtype, self.scheme, self.meta)

    def __repr__(self):
        meta = f", meta={self.meta}" if self.meta else ""
        return (f"QuantTensor(scheme={self.scheme!r}, shape={self.shape}, "
                f"stored={tuple(self.q.shape)}:{self.q.dtype}, "
                f"dtype={self.dtype}{meta})")
