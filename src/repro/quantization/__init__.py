"""Unified quantization API (DESIGN.md §8).

Three pieces, mirroring the scheduling (§3) and executor (§6) registries:

* `QuantScheme` + registry (base.py / schemes.py) — ``none``,
  ``int8_expert`` (the original serving layout), ``int8_channel``,
  ``int4_packed``; each owns quantize/dequantize/declared error bound.
* `QuantTensor` (tensor.py) — pytree-registered compressed weight stack
  (array leaves ``q``/``s``, static aux dtype + scheme name) replacing the
  old ``_q``/``_s`` suffix-keyed param dicts.
* Param-tree helpers (this module) — scheme-tagged MoE param trees:
  ``quantize_moe_params`` / ``quantize_params_tree`` produce trees whose
  routed expert mats are QuantTensors; ``params_scheme`` reads the tag
  back; ``expert_weights`` hands the dispatch pipeline its weight mapping.

Executors consume these through the capability contract in
execution/base.py: ``supports_scheme(scheme)`` + ``prepare_weights`` (the
dense oracle materializes; the xla scan and the pallas kernels dequantize
gathered blocks in-scan).
"""
from __future__ import annotations

import warnings

from repro.quantization.base import (EXPERT_MATS, QuantScheme,  # noqa: F401
                                     available_schemes, get_scheme,
                                     register_scheme)
from repro.quantization.schemes import (Int4PackedScheme,  # noqa: F401
                                        Int8ChannelScheme,
                                        Int8ExpertScheme, NoneScheme,
                                        pack_int4, unpack_int4)
from repro.quantization.tensor import QuantTensor  # noqa: F401


# ----------------------------------------------------------------------
# Scheme-tagged MoE param trees
# ----------------------------------------------------------------------
def quantize_moe_params(moe_params: dict, scheme: str = "int8_expert"
                        ) -> dict:
    """Replace the routed expert mats with scheme-tagged QuantTensors;
    router / shared experts stay dense (router accuracy gates everything
    and shared experts are dense compute)."""
    sch = get_scheme(scheme)
    out = dict(moe_params)
    for name in EXPERT_MATS:
        cur = moe_params[name]
        if isinstance(cur, QuantTensor):
            if cur.scheme == sch.name:
                continue                      # idempotent
            raise ValueError(
                f"param {name!r} is already quantized under "
                f"{cur.scheme!r}; dequantize before re-quantizing as "
                f"{sch.name!r}")
        out[name] = sch.quantize(cur)
    return out


def is_quantized(moe_params: dict) -> bool:
    return isinstance(moe_params.get("w_gate"), QuantTensor)


def params_scheme(moe_params: dict) -> str:
    """The scheme tag of a MoE param dict ('none' for dense params)."""
    w = moe_params.get("w_gate")
    return w.scheme if isinstance(w, QuantTensor) else "none"


def expert_weights(moe_params: dict, dtype=None) -> dict:
    """-> {"w_gate": array-or-QuantTensor, ...} for the dispatch pipeline.
    ``dtype`` retargets dequantization to the layer's compute dtype."""
    out = {}
    for name in EXPERT_MATS:
        w = moe_params[name]
        if isinstance(w, QuantTensor) and dtype is not None:
            w = w.with_dtype(dtype)
        out[name] = w
    return out


def quantize_params_tree(params: dict, scheme: str = "int8_expert") -> dict:
    """Quantize every MoE block in a full model param tree (models/lm.py
    layout).  Stacked 'body' leaves keep their leading layer-group axis —
    the schemes are rank-agnostic over leading axes, so (G, E, K, N)
    quantizes directly.  ``scheme='none'`` returns the tree unchanged."""
    if get_scheme(scheme).name == "none":
        return params

    def walk(node):
        if isinstance(node, dict):
            if "w_gate" in node and "router" in node:      # a moe param dict
                return quantize_moe_params(node, scheme)
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(params)


# ----------------------------------------------------------------------
# CLI shim
# ----------------------------------------------------------------------
def resolve_quant_cli(quant: str | None, quant_experts: bool = False) -> str:
    """One ``--quant <scheme>`` selector for every launcher; maps the
    deprecated ``--quant-experts`` on/off flag onto ``int8_expert``."""
    if quant_experts:
        warnings.warn(
            "--quant-experts is deprecated; use --quant int8_expert "
            "(the equivalent scheme in the quantization registry)",
            DeprecationWarning, stacklevel=2)
        # only an UNSET --quant is overridden: an explicit scheme —
        # including an explicit "none" — always wins over the legacy flag
        if quant is None:
            quant = "int8_expert"
    quant = quant or "none"
    get_scheme(quant)                   # uniform unknown-scheme error
    return quant
