"""Built-in quantization schemes: none, int8_expert, int8_channel,
int4_packed.

All quantizers are rank-agnostic over leading axes — the reduction /
packing axes are the trailing ``(K, N)`` of each expert block — so a
stacked layer-group tree ``(G, E, K, N)`` quantizes in one call (no vmap)
and a single-expert gathered block ``(K, N)`` dequantizes with the same
code the full stack uses.

Error accounting (declared as ``rel_error_bound``, the layer-output
inf-norm bound the acceptance tests assert against the fp32 dense oracle):

* ``int8_expert``  — one scale per expert matrix, step ``max|w|/127``:
  per-element error <= scale/2 ~ 0.4% of the weight range; measured layer
  error on the paper configs is ~1-2%, declared 5%.  This is the
  pre-redesign serving layout, bit-for-bit (same scale formula, same
  round/clip), so the old int8 serving path reproduces exactly.
* ``int8_channel`` — one scale per (expert, output-channel), step
  ``max|w[:, n]|/127``: columns no longer share the heaviest column's
  scale, so the bound tightens; declared 4%.
* ``int4_packed``  — two nibbles per byte along K (rows 2r, 2r+1 share a
  byte: low nibble = even row), one scale per expert, step ``max|w|/7``.
  ~18x coarser than int8 — declared 60%: usable for memory-bound decode
  experiments, not accuracy-neutral, which is exactly what the
  scheme-declared bound is for (consumers read it instead of guessing).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.quantization.base import QuantScheme, register_scheme
from repro.quantization.tensor import QuantTensor


# ----------------------------------------------------------------------
# int4 nibble packing (shared by the scheme and the Pallas kernels' ref)
# ----------------------------------------------------------------------
def pack_int4(q4: jnp.ndarray) -> jnp.ndarray:
    """(..., K, N) ints in [-8, 7] -> (..., K//2, N) int8; byte r packs
    logical rows (2r, 2r+1) as (low, high) nibbles."""
    K = q4.shape[-2]
    assert K % 2 == 0, f"int4_packed needs an even K axis, got {K}"
    q = q4.astype(jnp.int32).reshape(*q4.shape[:-2], K // 2, 2,
                                     q4.shape[-1])
    byte = (q[..., 0, :] & 0xF) | ((q[..., 1, :] & 0xF) << 4)
    return jnp.where(byte >= 128, byte - 256, byte).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., K//2, N) int8 -> (..., K, N) int32 in [-8, 7] (sign-extended
    nibbles, rows interleaved back to logical order)."""
    qi = packed.astype(jnp.int32)
    lo = qi & 0xF
    lo = lo - ((lo & 0x8) << 1)
    hi = (qi >> 4) & 0xF
    hi = hi - ((hi & 0x8) << 1)
    pairs = jnp.stack([lo, hi], axis=-2)            # (..., K//2, 2, N)
    return pairs.reshape(*packed.shape[:-2], 2 * packed.shape[-2],
                         packed.shape[-1])


def _absmax(w: jnp.ndarray, axis) -> jnp.ndarray:
    return jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis,
                   keepdims=True)


# ----------------------------------------------------------------------
@register_scheme("none")
class NoneScheme(QuantScheme):
    """Identity: params stay plain dense arrays, the dispatch path is
    bitwise-identical to a never-quantized run (tested)."""
    bits = 32
    rel_error_bound = 0.0
    kernel_format = "dense"

    def quantize(self, w):
        return w

    def dequantize(self, q, s, dtype):
        raise TypeError("the 'none' scheme never produces a QuantTensor")


@register_scheme("int8_expert")
class Int8ExpertScheme(QuantScheme):
    """Per-expert symmetric int8 — the original serving layout
    (scale = max|W_e|/127; round, clip to [-127, 127])."""
    bits = 8
    rel_error_bound = 0.05
    kernel_format = "int8"

    def quantize(self, w):
        s = _absmax(w, axis=(-2, -1)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -127, 127
                     ).astype(jnp.int8)
        return QuantTensor(q, s.astype(jnp.float32), w.dtype, self.name)

    def dequantize(self, q, s, dtype):
        return (q.astype(jnp.float32) * s).astype(dtype)


@register_scheme("int8_channel")
class Int8ChannelScheme(QuantScheme):
    """Per-(expert, output-channel) symmetric int8: scales (..., E, 1, N).
    Same storage as int8_expert plus 4 bytes/channel; strictly finer
    steps, so the declared bound tightens."""
    bits = 8
    rel_error_bound = 0.04
    kernel_format = "int8"

    def quantize(self, w):
        s = _absmax(w, axis=-2) / 127.0 + 1e-12          # (..., 1, N)
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -127, 127
                     ).astype(jnp.int8)
        return QuantTensor(q, s.astype(jnp.float32), w.dtype, self.name)

    def dequantize(self, q, s, dtype):
        return (q.astype(jnp.float32) * s).astype(dtype)


@register_scheme("int4_packed")
class Int4PackedScheme(QuantScheme):
    """Per-expert symmetric int4, two nibbles per byte along K — half the
    gathered bytes of int8 (scale = max|W_e|/7; range [-7, 7]).

    An odd K is stored with one zero pad row (byte packing needs pairs)
    and tagged ``("pad_k", 1)`` in the QuantTensor's static meta; dequant
    strips it, so quantize -> dequantize round-trips the exact logical
    shape.  The Pallas in-kernel dequant path requires the padless layout
    (kernels/ops.py materializes padded tensors instead — the paper
    configs all have even K, so this is the edge-case escape hatch, not
    the hot path)."""
    bits = 4
    rel_error_bound = 0.6
    kernel_format = "int4"

    def quantize(self, w):
        s = _absmax(w, axis=(-2, -1)) / 7.0 + 1e-12
        q4 = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -7, 7)
        pad = w.shape[-2] % 2
        if pad:
            q4 = jnp.concatenate(
                [q4, jnp.zeros((*q4.shape[:-2], 1, q4.shape[-1]),
                               q4.dtype)], axis=-2)
        return QuantTensor(pack_int4(q4), s.astype(jnp.float32), w.dtype,
                           self.name, (("pad_k", 1),) if pad else ())

    def dequantize(self, q, s, dtype):
        return (unpack_int4(q).astype(jnp.float32) * s).astype(dtype)

    def logical_shape(self, q_shape):
        return tuple(q_shape[:-2]) + (2 * q_shape[-2], q_shape[-1])
