"""Quantization-scheme layer: the `QuantScheme` contract + registry.

Mirrors the schedule-policy registry (scheduling/base.py, DESIGN.md §3)
and the executor registry (execution/base.py, §6): a scheme registers
under a name, owns the complete lifecycle of one compressed layout —
quantize, dequantize, kernel operand view — and *declares* its accuracy
contract so consumers (tests, benchmarks, capability checks) never
hard-code per-scheme knowledge:

* ``quantize(w)``   — dense ``(..., E, K, N)`` expert stack -> `QuantTensor`
  (or a passthrough array for the ``none`` scheme).  Rank-agnostic: a
  stacked layer-group tree ``(G, E, K, N)`` quantizes without vmap.
* ``dequantize(q, s, dtype)`` — the inverse, at ANY granularity: the full
  stack (materialization), one expert's block (the grouped-GEMM scan's
  per-block gather ``w[be]``), or an advanced-indexed batch of blocks.
* ``rel_error_bound`` — declared max relative error (inf-norm) of a MoE
  layer output under this scheme vs the fp32 dense oracle.  The
  acceptance tests assert every scheme honors its own declaration on the
  paper configs.
* ``bits`` / ``kernel_format`` / ``channel_scales`` — what the Pallas
  kernels need to dequantize a gathered block in-kernel (kernels/ops.py).

Adding a scheme (fp8, grouped int4, ...) is one registered class: no
executor, checkpoint, EP, or CLI code changes.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

from repro.quantization.tensor import QuantTensor

# the routed expert matrices every MoE param dict carries (core layout)
EXPERT_MATS = ("w_gate", "w_up", "w_down")


class QuantScheme:
    """Contract for one compressed expert-weight layout."""

    name: str = "?"
    bits: int = 32                  # logical bits per weight element
    rel_error_bound: float = 0.0    # declared layer-output inf-norm rel err
    kernel_format: str = "dense"    # Pallas in-kernel dequant mode:
                                    # "dense" | "int8" | "int4"

    # -- lifecycle ------------------------------------------------------
    def quantize(self, w: jnp.ndarray):
        """(..., E, K, N) dense stack -> QuantTensor (or passthrough)."""
        raise NotImplementedError

    def dequantize(self, q, s, dtype):
        """Invert at any granularity: full stack, one expert block, or an
        advanced-indexed batch of blocks."""
        raise NotImplementedError

    def logical_shape(self, q_shape) -> tuple:
        """Dense-stack shape from the stored payload's shape."""
        return tuple(q_shape)

    def channel_scales(self, qt: QuantTensor) -> jnp.ndarray:
        """(E, N) f32 per-output-channel scales for the Pallas kernels
        (per-expert scales broadcast; the kernel applies them uniformly)."""
        E = qt.s.shape[0]
        N = self.logical_shape(qt.q.shape)[-1]
        # (E, 1) broadcasts across channels; (E, N) is already per-channel
        return jnp.broadcast_to(qt.s.reshape(E, -1),
                                (E, N)).astype(jnp.float32)


_SCHEMES: Dict[str, QuantScheme] = {}


def register_scheme(name: str) -> Callable[[type], type]:
    """Class decorator: instantiate and register a QuantScheme."""
    def deco(cls: type) -> type:
        cls.name = name
        _SCHEMES[name] = cls()
        return cls
    return deco


def get_scheme(name) -> QuantScheme:
    if isinstance(name, QuantScheme):
        return name
    try:
        return _SCHEMES[name]
    except KeyError:
        raise ValueError(f"unknown quant scheme {name!r}; "
                         f"available: {available_schemes()}") from None


def available_schemes():
    return sorted(_SCHEMES)
