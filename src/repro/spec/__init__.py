"""Speculative decoding on the paged serving engine (DESIGN.md §13)."""
from repro.spec.engine import SpecEngine, make_draft_config

__all__ = ["SpecEngine", "make_draft_config"]
