"""Speculative decoding engine: a draft model proposes, the paged target
verifies k+1 positions per slot in ONE forward (DESIGN.md §13).

``SpecEngine`` layers on :class:`~repro.serve.engine.ServeEngine` and
changes NOTHING about admission, chunked prefill, prefix caching,
preemption, or retirement — it swaps the steady-state decode step for a
speculative round:

1. **Draft.**  A small draft model (its own params + its own
   ``PagedKVCache`` pool over the same slot layout) chains ``k``
   single-token proposal steps per active slot — no host sync between
   them (serve/step.py ``make_spec_draft_step``).
2. **Verify.**  The target scores all ``n_active · (k+1)`` rows — each
   slot's last emitted token plus its k proposals at positions
   ``[pos, pos+k]`` — in ONE batched forward riding the exact
   multi-token-rows-per-slot machinery chunked prefill built (PR 5): one
   DispatchPlan per MoE layer covers the whole verify sweep (asserted in
   tests/test_spec.py).  Accept/rejection math runs on device; the round
   costs ONE host sync total.
3. **Rollback.**  The accepted prefix + bonus token are emitted; both KV
   pools truncate back to the new sequence length via
   ``PagedKVCache.truncate_slot`` — a host-side block-table rollback that
   frees whole rejected blocks to the pool (prefix hashes past the
   truncation point are invalidated there).  No device work.

**Draft-state discipline.**  The draft KV pool is *derived* state — every
byte is recomputable from (draft params, the token sequence).  One
cursor, ``_dnext[s]`` = number of leading positions of slot ``s`` the
draft has processed, tracks it; ``_draft_catch_up()`` replays any gap
``[_dnext, pos)`` through the ordinary paged draft step (argmax
discarded), chunked like prefill.  That single mechanism uniformly
covers draft prompt prefill (mirroring the target's chunked prefill),
post-base-step mirroring, and preempt/resume — preemption simply
RELEASES the draft table (the target's parks; re-deriving the draft's is
a latency cost, never a correctness one).

**Correctness bar** (tests/test_spec.py): with greedy sampling the
emitted stream is token-IDENTICAL to the non-speculative engine for ANY
draft model — each accepted token equals the target argmax at its output
index by the verify construction — fuzzed over k × paged block size ×
draft quality (rejection points).  Stochastic sampling implements
standard rejection sampling against the draft distribution; keyed draws
(repro.sampling) make accepted streams reproducible per seed.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig, reduced
from repro.serve.engine import ServeEngine
from repro.serve.kv_cache import PagedKVCache
from repro.serve.step import (make_paged_step, make_spec_draft_step,
                              make_spec_verify_step)


def make_draft_config(target_cfg: ModelConfig, base: str = "smollm-360m",
                      *, reduce: bool = False, layers: int = 2,
                      d_model: int = 128) -> ModelConfig:
    """A draft config vocab-aligned with ``target_cfg`` (rejection
    sampling compares the two distributions token-for-token, so the
    vocabularies must match exactly).  ``reduce=True`` shrinks the draft
    for CPU smoke runs, mirroring how the benchmarks reduce targets."""
    cfg = get_config(base)
    if reduce:
        cfg = reduced(cfg, layers=layers, d_model=d_model,
                      vocab=target_cfg.vocab_size)
    return cfg.replace(vocab_size=target_cfg.vocab_size)


class SpecEngine(ServeEngine):
    """ServeEngine + draft-propose / target-verify / rollback rounds."""

    def __init__(self, cfg: ModelConfig, params, *, draft_cfg: ModelConfig,
                 draft_params, spec_k: int = 4, **kw):
        prefix_cache = kw.get("prefix_cache", True)
        super().__init__(cfg, params, **kw)
        if not self.paged:
            raise ValueError(
                "speculative decoding needs the paged engine (rollback is "
                "a block-table truncation); got a contiguous-cache config "
                "— pass kv_block_size > 0 / a pageable architecture")
        if draft_cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft vocab {draft_cfg.vocab_size} != target vocab "
                f"{cfg.vocab_size}; rejection sampling compares the two "
                "distributions per token id (make_draft_config aligns them)")
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.spec_k = spec_k
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        # the draft runs the same executor/chunking but never quantizes
        # and never collects MoE plan stats (its aux is discarded)
        self.drc = self.rc._replace(quant="none", moe_stats=False)
        self.dkv = PagedKVCache(draft_cfg, self.slots, self.capacity,
                                self.kv_block_size,
                                prefix_cache=prefix_cache)
        self.dkv.bind_obs(self.obs.metrics, self.obs.tracer)
        # catch-up reuses the ordinary paged step (tokens in, argmax out —
        # discarded); proposals/verification use the dedicated spec steps
        self._dstep = make_paged_step(draft_cfg, self.drc, self.obs,
                                      self.sampling)
        self._draft_step = make_spec_draft_step(draft_cfg, self.drc,
                                                self.sampling, self.obs)
        self._verify_step = make_spec_verify_step(cfg, self.rc,
                                                  self.sampling, spec_k,
                                                  self.obs)
        # draft progress cursor: leading positions of slot s whose tokens
        # the draft has processed (KV written)
        self._dnext = np.zeros(self.slots, np.int64)
        # speculation accounting (plain ints: artifact counters must not
        # depend on an obs sink being attached)
        self.n_spec_rounds = 0
        self.n_drafted = 0
        self.n_accepted = 0
        self.n_draft_forwards = 0

    # ------------------------------------------------------------------
    @property
    def acceptance_rate(self) -> float:
        """Accepted draft tokens / drafted tokens (1.0 until the first
        round so an all-baseline run reports a neutral value)."""
        return self.n_accepted / self.n_drafted if self.n_drafted else 1.0

    def describe(self, *, seed=None) -> dict:
        d = super().describe(seed=seed)
        d["spec_k"] = self.spec_k
        d["spec_draft"] = self.draft_cfg.name
        return d

    # -- slot lifecycle hooks ------------------------------------------
    def _admit(self, req, t_admit) -> None:
        super()._admit(req, t_admit)
        s = self.n_active - 1
        # draft prefix-cache probe mirrors the target's; on a cold cache
        # this is 0 and catch-up prefills the draft chunk-by-chunk
        self._dnext[s] = self.dkv.attach_prefix(s, self._seq[s])

    def _retire(self, s: int, *, decode_batch: int) -> None:
        self.dkv.release_slot(s)
        super()._retire(s, decode_batch=decode_batch)

    def preempt(self, s: int):
        # draft KV is derived state: release rather than park (resume
        # re-derives via catch-up — latency, never correctness)
        self.dkv.release_slot(s)
        return super().preempt(s)

    def _compact(self, s: int) -> None:
        last = self.n_active - 1
        if s != last:
            self.dkv.move_slot(s, last)
            self._dnext[s] = self._dnext[last]
        self._dnext[last] = 0
        super()._compact(s)

    # -- draft bookkeeping ---------------------------------------------
    def _full_tokens(self, s: int) -> np.ndarray:
        """Tokens at positions ``[0, pos[s]]`` of slot ``s``: the prefill
        source then the out-suffix extending it (the engine invariant
        ``pos = len(seq) + len(out) - 1`` once prefill completes)."""
        seq = np.asarray(self._seq[s], np.int64)
        t = int(self.pos[s]) + 1 - len(seq)
        if t <= 0:
            return seq[:int(self.pos[s]) + 1]
        out = np.asarray(self.active[s].out[-t:], np.int64)
        return np.concatenate([seq, out])

    def _draft_catch_up(self) -> None:
        """Feed the draft every token position the target is ahead by
        (``[_dnext, pos)`` per slot), chunked like prefill.  Uniformly
        handles draft prompt prefill, post-base-step mirroring, and
        resume replay; a no-op when every slot is caught up."""
        while True:
            rows = []                              # (slot, token, position)
            for s in range(self.n_active):
                dn, p = int(self._dnext[s]), int(self.pos[s])
                if dn >= p:
                    continue
                full = self._full_tokens(s)
                for j in range(min(self.prefill_chunk, p - dn)):
                    rows.append((s, int(full[dn + j]), dn + j))
            if not rows:
                return
            with self.obs.tracer.span("serve/spec_catch_up",
                                      tokens=len(rows)):
                for s in {r[0] for r in rows}:
                    self.dkv.ensure_allocated(
                        s, max(p for sl, _, p in rows if sl == s))
                tables = jnp.asarray(
                    self.dkv.table_rows([r[0] for r in rows]))
                toks = jnp.asarray([[t] for _, t, _ in rows], jnp.int32)
                pos = jnp.asarray([p for _, _, p in rows], jnp.int32)
                z = jnp.zeros(len(rows), jnp.int32)
                eos = jnp.full((len(rows),), -1, jnp.int32)
                _t, _e, self.dkv.pools, _a = self._dstep(
                    self.draft_params, self.dkv.pools, {"tokens": toks},
                    pos, tables, eos, z, z)
                self.n_draft_forwards += 1
            for s in {r[0] for r in rows}:
                self._dnext[s] += sum(1 for sl, _, _ in rows if sl == s)
                seq = np.asarray(self._seq[s])
                self.dkv.register_filled(
                    s, seq, min(int(self._dnext[s]), len(seq)))

    def _spec_ready(self) -> bool:
        """A speculative round covers EVERY active slot (one verify batch,
        one plan); fall back to a base step unless all slots are in
        steady-state decode with headroom for k+1 more positions."""
        if self.n_active == 0:
            return False
        for s in range(self.n_active):
            r = self.active[s]
            if not r.out or int(self._prefill_next[s]) < len(self._seq[s]):
                return False                      # still prefilling
            if int(self.pos[s]) + self.spec_k + 1 >= self.capacity:
                return False                      # no room to speculate
            if int(self._dnext[s]) != int(self.pos[s]):
                return False                      # draft not caught up
        return True

    # -- the speculative round -----------------------------------------
    def step(self) -> int:
        if self.n_active == 0:
            return 0
        self._draft_catch_up()
        if not self._spec_ready():
            return super().step()
        t0 = self._clock()
        n = self._step_spec()
        if n:
            dt = self._clock() - t0
            self._ewma_step_s = dt if self._ewma_step_s is None \
                else 0.7 * self._ewma_step_s + 0.3 * dt
        return n

    def _step_spec(self) -> int:
        n, k = self.n_active, self.spec_k
        obs, i_step = self.obs, self._step_idx
        obs.step_begin(i_step)
        reqs = self.active[:n]
        pos0 = self.pos[:n].astype(np.int64).copy()
        with obs.tracer.span("serve/step", step=i_step, active=n,
                             spec_k=k):
            seeds = jnp.asarray([self._req_seed(r) for r in reqs],
                                jnp.int32)
            counters = jnp.asarray([len(r.out) for r in reqs], jnp.int32)
            # -- draft: chain k proposals, no host sync between them
            with obs.tracer.span("serve/spec_draft", proposals=n * k):
                for s in range(n):
                    # target writes KV at [pos, pos+k]; draft at
                    # [pos, pos+k-1] (the k-th proposal is never fed back)
                    self.kv.ensure_allocated(s, int(pos0[s]) + k)
                    self.dkv.ensure_allocated(s, int(pos0[s]) + k - 1)
                dtables = jnp.asarray(self.dkv.table_rows(list(range(n))))
                cur = jnp.asarray([[r.out[-1]] for r in reqs], jnp.int32)
                dtoks, qdists = [], []
                for t in range(k):
                    dpos = jnp.asarray(pos0 + t, jnp.int32)
                    tok, q, self.dkv.pools, _ = self._draft_step(
                        self.draft_params, self.dkv.pools,
                        {"tokens": cur}, dpos, dtables, seeds,
                        counters + t)
                    dtoks.append(tok)
                    qdists.append(q)
                    cur = tok[:, None]
                    self.n_draft_forwards += 1
                draft_tok = jnp.stack(dtoks, axis=1)          # (n, k)
                draft_q = jnp.stack(qdists, axis=1)           # (n, k, V)
            # -- verify: ONE target forward over all n·(k+1) rows
            with obs.tracer.span("serve/spec_verify", tokens=n * (k + 1)):
                last = jnp.asarray([[r.out[-1]] for r in reqs], jnp.int32)
                vtok = jnp.concatenate([last, draft_tok],
                                       axis=1).reshape(n * (k + 1), 1)
                vpos = (pos0[:, None]
                        + np.arange(k + 1)[None, :]).reshape(-1)
                vtables = np.repeat(self.kv.table_rows(list(range(n))),
                                    k + 1, axis=0)
                emitted, n_emit, self.kv.pools, aux = self._verify_step(
                    self.params, self.kv.pools, self._batch(vtok),
                    jnp.asarray(vpos, jnp.int32), jnp.asarray(vtables),
                    draft_tok, draft_q, seeds, counters)
                self.n_forwards += 1
            with obs.tracer.span("serve/host_sync"):   # the ONE host sync
                em_np, ne_np = jax.device_get((emitted, n_emit))
            t_now = self._clock()
            with obs.tracer.span("serve/postprocess"):
                acc_round = 0
                for s in range(n):
                    r = reqs[s]
                    self._last_aux[r.rid] = aux
                    ne, m = int(ne_np[s]), 0
                    for j in range(ne):
                        if len(r.out) >= r.max_new:
                            break
                        tok = int(em_np[s, j])
                        self._emit(r, tok, t_now)
                        m += 1
                        if r.eos is not None and tok == r.eos:
                            break
                    # rollback: both pools truncate to the new length —
                    # rejected rows die host-side (whole blocks freed)
                    new_pos = int(pos0[s]) + m
                    self.pos[s] = new_pos
                    self.kv.truncate_slot(s, new_pos)
                    dn = min(int(pos0[s]) + k, new_pos)
                    self.dkv.truncate_slot(s, dn)
                    self._dnext[s] = dn
                    self.n_drafted += k
                    acc_round += max(0, min(m, ne - 1))
                self.n_accepted += acc_round
                self.n_spec_rounds += 1
                if obs.enabled:
                    obs.metrics.inc("spec/rounds")
                    obs.metrics.inc("spec/drafted", n * k)
                    obs.metrics.inc("spec/accepted", acc_round)
                    obs.metrics.set_gauge("spec/acceptance_rate",
                                          self.acceptance_rate)
                # retire top-down so compaction never moves an unexamined
                # slot; the emit loop already stopped at EOS/max_new
                for s in range(n - 1, -1, -1):
                    r = self.active[s]
                    if (r.eos is not None and r.out and r.out[-1] == r.eos) \
                            or len(r.out) >= r.max_new \
                            or self.pos[s] >= self.capacity - 1:
                        self._retire(s, decode_batch=n)
        self._end_step(i_step, tokens=n * (k + 1))
        return n * (k + 1)
