"""The paper's four benchmark MoE configurations (Table 1).

These parameterize the dispatch-level benchmarks (benchmarks/*.py) exactly as
the paper benchmarks its kernels: a single MoE layer, not a full model.
"""
from dataclasses import dataclass
from typing import Dict, Tuple


@dataclass(frozen=True)
class PaperMoE:
    name: str
    n_experts: int      # E
    top_k: int          # k
    d_model: int        # d
    d_ffn: int          # d_ffn
    gating: str = "softmax"


PAPER_CONFIGS: Dict[str, PaperMoE] = {
    "mixtral-8x7b": PaperMoE("mixtral-8x7b", 8, 2, 4096, 14336),
    "mixtral-8x22b": PaperMoE("mixtral-8x22b", 8, 2, 6144, 16384),
    "deepseek-v3": PaperMoE("deepseek-v3", 256, 8, 7168, 2048, gating="sigmoid"),
    "qwen2-moe-57b": PaperMoE("qwen2-moe-57b", 64, 4, 3584, 2560),
}

# Paper Table 5: expert-scaling sweep (d_ffn adjusted for ~constant compute).
EXPERT_SCALING: Tuple[Tuple[int, int, int], ...] = (
    # (E, top_k, d_ffn)
    (8, 2, 14336),
    (16, 2, 8192),
    (32, 4, 4096),
    (64, 4, 2560),
    (128, 8, 2048),
    (256, 8, 2048),
)

# Token-count sweep used by paper Tables 2-3.
TOKEN_SWEEP: Tuple[int, ...] = (32, 128, 512, 2048)
