"""Model configuration dataclasses.

One ``ModelConfig`` describes every architecture in the assigned pool; family-
specific sub-configs (MoE / MLA / SSM / RWKV) are attached when present.  All
configs are frozen dataclasses so they hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts block config (the paper's subject)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    gating: str = "softmax"          # "softmax" (Mixtral/DSv2) | "sigmoid" (DSv3)
    norm_topk: bool = False          # renormalize selected weights to sum to 1
    routed_scale: float = 1.0        # DeepSeek routed_scaling_factor
    first_dense_layers: int = 0      # leading layers use a dense FFN instead
    d_ff_dense: int = 0              # d_ff of those dense layers (0 -> 4*d_model)
    capacity_factor: float = 1.25    # EP dispatch buffer headroom
    block_m: int = 128               # grouped-GEMM fixed BLOCK_M (paper §3.2)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD mixer."""

    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    n_groups: int = 1
    chunk: int = 128                 # SSD intra-chunk length


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 "Finch" time-mix."""

    head_size: int = 64
    decay_lora: int = 64             # rank of the data-dependent decay LoRA
    chunk: int = 128                 # chunked-recurrence length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention ---
    causal: bool = True
    rope_theta: float = 10_000.0
    use_rope: bool = True
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    local_window: Optional[int] = None
    layer_pattern: str = "global"    # "global" | "local_global" (alternating)

    # --- block structure ---
    norm: str = "rmsnorm"            # rmsnorm|layernorm
    act: str = "swiglu"              # swiglu|geglu|gelu_mlp
    mlp_bias: bool = False
    post_block_norm: bool = False    # gemma2-style extra norms after attn/mlp
    tie_embeddings: bool = False
    emb_scale: bool = False          # multiply embeddings by sqrt(d_model)

    # --- family sub-configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # --- vlm ---
    cross_attn_every: int = 0        # >0: cross-attn block every N layers
    n_image_tokens: int = 1024       # stub vision frontend output length

    # --- encoder-only (audio) ---
    encoder_only: bool = False

    # --- hybrid (zamba2) ---
    attn_every: int = 0              # >0: shared attention block every N ssm layers
    n_shared_attn_blocks: int = 2    # unique shared blocks, applied round-robin

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def has_decode(self) -> bool:
        """Encoder-only architectures have no autoregressive decode step."""
        return not self.encoder_only

    @property
    def supports_500k(self) -> bool:
        """Sub-quadratic archs only (SSM / hybrid / linear attention)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------------
# Input shapes assigned to the LM pool (seq_len, global_batch, kind)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-not) for an (arch x shape) cell — see DESIGN.md §4."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.supports_500k:
        return False, "524k decode needs sub-quadratic attention (full-attn arch)"
    if shape.name == "prefill_32k" and cfg.encoder_only:
        return True, ""  # encoder forward pass at 32k frames is well-defined
    return True, ""


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 128,
            n_heads: int = 4, vocab: int = 512) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving its structural family."""
    kv = max(1, min(cfg.n_kv_heads, n_heads) * n_heads // max(cfg.n_heads, 1)) \
        if cfg.n_kv_heads < cfg.n_heads else n_heads
    kw = dict(
        n_layers=layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=kv,
        head_dim=d_model // n_heads,
        d_ff=d_model * 3,
        vocab_size=min(cfg.vocab_size, vocab),
        local_window=(64 if cfg.local_window else None),
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 8), top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=d_model * 2, d_ff_dense=d_model * 3,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1), block_m=8)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_size=16, decay_lora=8, chunk=16)
    if cfg.cross_attn_every:
        kw["cross_attn_every"] = 2
        kw["n_image_tokens"] = 16
    if cfg.attn_every:
        kw["attn_every"] = 2
        kw["n_layers"] = max(layers, 4)
    return cfg.replace(**kw)
