"""hubert-xlarge — encoder-only audio transformer backbone.

[arXiv:2106.07447; unverified]  48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.
The conv feature extractor / positional-conv frontend is a STUB: ``input_specs``
provides precomputed frame embeddings of shape (batch, frames, d_model); the
model consumes them directly and trains with masked-prediction over the 504-way
codebook vocabulary.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    causal=False,
    encoder_only=True,
    use_rope=False,          # HuBERT uses conv positional encoding (stubbed)
    norm="layernorm",
    act="gelu_mlp",
    mlp_bias=True,
    qkv_bias=True,
)
