"""zamba2-7b — hybrid Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; unverified]  81L d_model=3584 32H (kv=32) d_ff=14336
ssm_state=64 vocab=32000.  The stack is Mamba2 mixer layers with a SHARED
attention+MLP block applied every ``attn_every`` layers (2 unique shared blocks
used round-robin — weight sharing as in the paper; the concat-embedding input
projection of the original is simplified to a residual application, noted in
DESIGN.md).  Hybrid: runs the long_500k decode shape (Mamba state is O(1);
the shared-attn KV cache is sequence-sharded, see distributed/).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32_000,
    head_dim=112,
    rope_theta=10_000.0,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4, chunk=128),
    attn_every=6,
    n_shared_attn_blocks=2,
)
