"""gemma2-9b — dense GQA with alternating local/global attention + softcaps.

[arXiv:2408.00118; hf]  42L d_model=3584 16H (kv=8) d_ff=14336 vocab=256000,
head_dim=256, local window 4096 on alternating layers, attn logit softcap 50,
final logit softcap 30, GeGLU, pre+post block norms, scaled embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=256_000,
    head_dim=256,
    rope_theta=10_000.0,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    local_window=4096,
    layer_pattern="local_global",
    act="geglu",
    post_block_norm=True,
    emb_scale=True,
    tie_embeddings=True,
)
