"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) — 64-expert top-6 MoE.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (kv=16)
d_ff(expert)=1408 vocab=163840, 64 routed experts top-6 + 2 shared,
sigmoid gating with top-k renormalization (DeepSeek-V3 style), first layer dense.
64 experts is exactly the paper's Qwen2-MoE skew-sensitivity regime (§4.7).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=11264,              # dense (first-layer) FFN width
    vocab_size=163_840,
    head_dim=128,
    rope_theta=50_000.0,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared_experts=2,
        gating="sigmoid",
        norm_topk=True,
        routed_scale=2.446,
        first_dense_layers=1,
        d_ff_dense=11264,
        block_m=128,
    ),
)
