"""deepseek-v2-236b — MoE with multi-head latent attention (MLA).

[arXiv:2405.04434; hf]  60L d_model=5120 128H d_ff(expert)=1536 vocab=102400,
MLA kv_lora=512, 2 shared + 160 routed experts top-6, first layer dense.
This is the PRIMARY target for the paper's grouped-GEMM dispatch technique.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: heads share one compressed latent cache
    d_ff=12288,              # dense (first-layer) FFN width
    vocab_size=102_400,
    head_dim=192,            # qk_nope (128) + qk_rope (64)
    rope_theta=10_000.0,
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_ff_expert=1536,
        n_shared_experts=2,
        gating="softmax",
        norm_topk=False,
        routed_scale=16.0,
        first_dense_layers=1,
        d_ff_dense=12288,
        block_m=128,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
)
