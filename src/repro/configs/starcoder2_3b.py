"""starcoder2-3b — dense code model, GQA kv=2, RoPE, ungated MLP, layernorm.

[arXiv:2402.19173; hf]  30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49_152,
    head_dim=128,
    rope_theta=999_999.4,
    norm="layernorm",
    act="gelu_mlp",
    mlp_bias=True,
    qkv_bias=True,
    tie_embeddings=True,
)
