"""rwkv6-1.6b ("Finch") — attention-free, data-dependent-decay linear attention.

[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536,
head_size=64 (32 heads).  Sub-quadratic: runs the long_500k decode shape.
The paper's MoE dispatch technique is INAPPLICABLE (no experts, channel-mix FFN)
— see DESIGN.md §4; the arch is implemented without it.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,              # d_model / head_size
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65_536,
    head_dim=64,
    use_rope=False,
    norm="layernorm",
    act="gelu_mlp",          # channel-mix uses its own relu^2 path internally
    rwkv=RWKVConfig(head_size=64, decay_lora=64, chunk=128),
)
