"""llama-3.2-vision-11b — VLM backbone with interleaved cross-attention layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  40L d_model=4096 32H (kv=8)
d_ff=14336 vocab=128256; a cross-attention block every 5th layer attends to
image patch embeddings.  The vision encoder is a STUB: ``input_specs`` provides
precomputed patch embeddings (batch, n_image_tokens, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_image_tokens=1024,
)
