"""Config registry: ``get_config("<arch-id>")`` for every assigned architecture."""
from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig, RWKVConfig,
                                ShapeConfig, SHAPES, SHAPE_BY_NAME, SSMConfig,
                                cell_is_runnable, reduced)

from repro.configs import (deepseek_v2_236b, gemma2_9b, hubert_xlarge,
                           llama_3_2_vision_11b, moonshot_v1_16b_a3b,
                           qwen2_7b, rwkv6_1_6b, smollm_360m, starcoder2_3b,
                           zamba2_7b)
from repro.configs.paper import EXPERT_SCALING, PAPER_CONFIGS, TOKEN_SWEEP, PaperMoE

_MODULES = (
    hubert_xlarge, deepseek_v2_236b, moonshot_v1_16b_a3b, qwen2_7b,
    smollm_360m, gemma2_9b, starcoder2_3b, rwkv6_1_6b,
    llama_3_2_vision_11b, zamba2_7b,
)

REGISTRY = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ARCH_NAMES = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RWKVConfig",
    "ShapeConfig", "SHAPES", "SHAPE_BY_NAME", "cell_is_runnable", "reduced",
    "REGISTRY", "ARCH_NAMES", "get_config",
    "PAPER_CONFIGS", "EXPERT_SCALING", "TOKEN_SWEEP", "PaperMoE",
]
