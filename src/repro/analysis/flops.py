"""Analytic per-cell FLOP / HBM-byte / parameter models.

Matmul-exact FLOP counting per architecture block, used three ways:

1. MODEL_FLOPS = 6 * N_active * D (the assignment's convention: N_active =
   matmul-participating parameters touched per token incl. the LM head,
   excl. the embedding gather; D = tokens processed).
2. DISPATCH_FLOPS = what the executed program actually computes, including
   the paper-relevant overheads: top-k expansion (k x expert FFN per token),
   EP capacity padding, causal-mask waste in chunked attention, remat
   recompute (train: bwd = 2x fwd, remat adds ~1x fwd).
3. HBM byte estimates for the memory roofline term (dominant flows only:
   weights, activations residual traffic, KV-cache reads, optimizer state).

cost_analysis() undercounts loop bodies (counted once) — these analytic
numbers are the corrected compute/memory terms; tests/test_roofline.py
validates them against an UNROLLED compile on small cells.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.lm import group_structure


@dataclass
class CellCost:
    model_flops: float          # 6*N_active*D convention (global)
    dispatch_flops: float       # executed, incl. waste (global)
    hbm_bytes: float            # per-device estimate
    n_params: float
    n_active: float
    notes: str = ""


def _attn_params(cfg: ModelConfig) -> float:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is not None:
        m = cfg.mla
        return (d * m.q_lora_rank + m.q_lora_rank * H * (m.qk_nope_head_dim
                + m.qk_rope_head_dim) + d * (m.kv_lora_rank
                + m.qk_rope_head_dim) + m.kv_lora_rank * H
                * (m.qk_nope_head_dim + m.v_head_dim) + H * m.v_head_dim * d)
    return d * H * hd + 2 * d * Hkv * hd + H * hd * d


def _ffn_params(cfg: ModelConfig, f: int) -> float:
    return (3 if cfg.act in ("swiglu", "geglu") else 2) * cfg.d_model * f


def _ssm_params(cfg: ModelConfig) -> float:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    return (cfg.d_model * (2 * d_in + 2 * gn + H)
            + s.conv_kernel * (d_in + 2 * gn) + d_in * cfg.d_model)


def _rwkv_params(cfg: ModelConfig) -> float:
    d, f = cfg.d_model, cfg.d_ff
    r = cfg.rwkv.decay_lora
    return 5 * d * d + 2 * d * r + (d * f + f * d + d * d) + d * d


def block_params(cfg: ModelConfig, kind: str) -> float:
    if kind == "rwkv":
        return _rwkv_params(cfg)
    if kind == "mamba":
        return _ssm_params(cfg)
    a = _attn_params(cfg)
    if kind == "moe":
        m = cfg.moe
        routed = m.n_experts * 3 * cfg.d_model * m.d_ff_expert
        shared = m.n_shared_experts * 3 * cfg.d_model * m.d_ff_expert
        return a + cfg.d_model * m.n_experts + routed + shared
    if kind == "moe_dense":
        return a + _ffn_params(cfg, cfg.moe.d_ff_dense or 4 * cfg.d_model)
    return a + _ffn_params(cfg, cfg.d_ff)


def block_active_params(cfg: ModelConfig, kind: str) -> float:
    """Params touched per token (MoE: only top-k + shared experts)."""
    if kind == "moe":
        m = cfg.moe
        a = _attn_params(cfg)
        return (a + cfg.d_model * m.n_experts
                + (m.top_k + m.n_shared_experts) * 3 * cfg.d_model
                * m.d_ff_expert)
    return block_params(cfg, kind)


def _all_kinds(cfg: ModelConfig):
    prefix, body, n_groups, suffix = group_structure(cfg)
    kinds = list(prefix) + list(body) * n_groups + list(suffix)
    # shared_attn blocks share weights: params counted once per unique block,
    # but ACTIVE per application
    return kinds


def total_params(cfg: ModelConfig) -> float:
    kinds = _all_kinds(cfg)
    n = 0.0
    seen_shared = 0
    for k in kinds:
        if k == "shared_attn":
            if seen_shared < cfg.n_shared_attn_blocks:
                n += block_params(cfg, "attn")
                seen_shared += 1
            continue
        n += block_params(cfg, k)
    n += cfg.vocab_size * cfg.d_model            # embedding
    if not cfg.tie_embeddings and not cfg.encoder_only:
        n += cfg.d_model * cfg.vocab_size        # head
    return n


def active_params(cfg: ModelConfig) -> float:
    """Matmul params per token (head included, embed-gather excluded)."""
    n = 0.0
    for k in _all_kinds(cfg):
        kk = "attn" if k == "shared_attn" else k
        n += block_active_params(cfg, kk)
    n += cfg.d_model * cfg.vocab_size            # LM/classifier head
    return n


# ----------------------------------------------------------------------
def _attn_flops_token(cfg: ModelConfig, kv_len: float, kind: str,
                      decode: bool) -> float:
    """Attention score+value FLOPs per token (projections counted via
    active params)."""
    window = cfg.local_window if kind == "attn_local" else None
    eff = min(kv_len, window) if window else kv_len
    if cfg.mla is not None:
        m = cfg.mla
        if decode:
            r = m.kv_lora_rank
            per = (2 * cfg.n_heads * m.qk_nope_head_dim * r         # absorb q
                   + 2 * cfg.n_heads * (r + m.qk_rope_head_dim) * eff
                   + 2 * cfg.n_heads * r * eff
                   + 2 * cfg.n_heads * r * m.v_head_dim)
            return per
        return 2 * cfg.n_heads * eff * (m.qk_nope_head_dim
                                        + m.qk_rope_head_dim
                                        + m.v_head_dim)
    return 2 * cfg.n_heads * cfg.head_dim * eff * 2


def _mixer_state_flops_token(cfg: ModelConfig) -> float:
    if cfg.family == "ssm":                      # rwkv: rank-1 state updates
        n = cfg.rwkv.head_size
        return 5 * cfg.d_model * n
    if cfg.ssm is not None:                      # mamba2 SSD
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        L = s.chunk
        # intra-chunk (L x L attention-like) + state update/readout
        return (2 * L * s.n_groups * s.d_state + 2 * L * d_in / (d_in
                // s.head_dim) * 0 + 4 * d_in * s.d_state)
    return 0.0


def cell_cost(cfg: ModelConfig, shape: ShapeConfig, *, chips: int,
              accum: int = 1, capacity_factor: float = 2.0,
              remat: bool = True) -> CellCost:
    mode = shape.kind
    decode = mode == "decode"
    if decode:
        tokens = float(shape.global_batch)       # one token per sequence
        kv_len = float(shape.seq_len)
        seq_avg = kv_len
    else:
        tokens = float(shape.global_batch) * shape.seq_len
        kv_len = shape.seq_len
        seq_avg = shape.seq_len / 2 if cfg.causal else shape.seq_len

    n_par = total_params(cfg)
    n_act = active_params(cfg)

    # --- MODEL_FLOPS (assignment convention) ---
    fwd_factor = 2.0                             # 2 flops per param-MAC
    mult = 3.0 if mode == "train" else 1.0       # bwd = 2x fwd
    model_flops = fwd_factor * mult * n_act * tokens

    # --- DISPATCH_FLOPS: add attention quadratic + waste terms ---
    kinds = _all_kinds(cfg)
    attn_extra = 0.0
    moe_waste = 0.0
    mixer_extra = 0.0
    for k in kinds:
        if k in ("attn", "attn_global", "attn_local", "cross", "moe",
                 "moe_dense", "shared_attn"):
            kk = "attn_local" if k == "attn_local" else k
            kvl = cfg.n_image_tokens if k == "cross" else \
                (kv_len if decode else seq_avg)
            attn_extra += _attn_flops_token(cfg, kvl, kk, decode) * tokens
        if k == "moe":
            m = cfg.moe
            # EP static-capacity padding: dispatched rows/useful rows
            ep = 16
            tl = max(tokens / chips * (chips // ep), 1)
            cap = max(128, capacity_factor * tl * m.top_k / m.n_experts)
            waste_ratio = (m.n_experts * cap) / max(tl * m.top_k, 1)
            moe_waste += (waste_ratio - 1.0) * m.top_k * 3 * 2 \
                * cfg.d_model * m.d_ff_expert * tokens
        if k in ("rwkv", "mamba"):
            mixer_extra += _mixer_state_flops_token(cfg) * tokens
    dispatch = model_flops + mult * (attn_extra + mixer_extra) \
        + mult * moe_waste
    if mode == "train" and remat:
        dispatch *= 4.0 / 3.0                    # remat: fwd recompute in bwd

    # --- HBM bytes per device (dominant flows) ---
    pb = 2.0                                     # bf16 params
    per_dev = 1.0 / chips
    if mode == "train":
        # per microbatch: weights gathered+read fwd & bwd(+remat) ~ 3x;
        # optimizer m,v read+write fp32 (16B/param); activations: residual
        # stream read/write ~ 12x d_model bytes per token per layer
        hbm = (3.0 * accum * n_par * pb + n_par * 16) / chips \
            + len(kinds) * 12 * tokens * cfg.d_model * 2.0 / chips
    elif mode == "prefill":
        hbm = (n_par * pb + len(kinds) * 8 * tokens * cfg.d_model * 2.0) \
            / chips
    else:
        # decode: weights + full KV-cache read per step
        cache = 0.0
        for k in kinds:
            if cfg.mla is not None and k in ("moe", "moe_dense"):
                cache += (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) \
                    * kv_len * shape.global_batch * 2.0
            elif k in ("attn", "attn_global", "shared_attn"):
                cache += 2 * cfg.n_kv_heads * cfg.head_dim * kv_len \
                    * shape.global_batch * 2.0
            elif k == "attn_local":
                cache += 2 * cfg.n_kv_heads * cfg.head_dim \
                    * min(kv_len, cfg.local_window or kv_len) \
                    * shape.global_batch * 2.0
            elif k == "mamba":
                s = cfg.ssm
                d_in = s.expand * cfg.d_model
                cache += d_in * s.d_state * 4.0 * shape.global_batch * 2
            elif k == "rwkv":
                n = cfg.rwkv.head_size
                cache += cfg.d_model * n * 4.0 * shape.global_batch * 2
        hbm = (n_par * pb + cache) / chips

    return CellCost(model_flops=model_flops, dispatch_flops=dispatch,
                    hbm_bytes=hbm, n_params=n_par, n_active=n_act)
