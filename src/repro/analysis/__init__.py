"""repro.analysis subpackage."""
