"""Assemble EXPERIMENTS.md from results/dryrun + results/perf JSONs.

    PYTHONPATH=src python -m repro.analysis.report > EXPERIMENTS.md
"""
from __future__ import annotations

import json
import pathlib

from repro.analysis.roofline import analyze_cell, load_results, markdown_table

ROOT = pathlib.Path(__file__).resolve().parents[3]


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | status | compile s | arg GB/dev | "
            "temp GB/dev | HLO GFLOP/dev | coll GB/dev (corrected) |",
            "|" + "---|" * 9]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"SKIP ({r['reason']}) | | | | | |")
            continue
        m, c = r["memory"], r.get("cost", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']} | {m['argument_bytes'] / 1e9:.2f} | "
            f"{m['temp_bytes'] / 1e9:.2f} | "
            f"{(c.get('flops') or 0) / 1e9:.0f} | "
            f"{r['collectives']['total_bytes'] / 1e9:.1f} |")
    return "\n".join(rows)


_POLICY_ORDER = {"fixed": 0, "capacity_factor": 1, "dynamic": 2}


def scheduling_table():
    """ScheduleStats telemetry from benchmarks/skew_sensitivity.py
    (results/sched/*.json) — the three schedule policies head-to-head."""
    sched_dir = ROOT / "results" / "sched"
    recs = []
    if sched_dir.exists():
        for p in sorted(sched_dir.glob("*.json")):
            recs.extend(json.loads(p.read_text()))
    if not recs:
        return ("_(no records — run ``PYTHONPATH=src python -m "
                "benchmarks.skew_sensitivity`` to populate results/sched/)_")
    rows = ["| config | dist | policy | executor | M | pad waste | "
            "occupancy | drop | CPU us |",
            "|" + "---|" * 9]
    for r in sorted(recs, key=lambda r: (r["config"], r["dist"],
                                         _POLICY_ORDER.get(r["policy"], 9),
                                         r.get("executor", "xla"))):
        rows.append(
            f"| {r['config']} | {r['dist']} | {r['policy']} | "
            f"{r.get('executor', 'xla')} | "
            f"{r['block_m']} | {r['pad_waste']:.2f}x | "
            f"{r['occupancy']:.1%} | {r['drop_fraction']:.1%} | "
            f"{r['us']:.0f} |")
    worst = max((r for r in recs if r["policy"] == "fixed"),
                key=lambda r: r["pad_waste"], default=None)
    twin = None if worst is None else next(
        (r for r in recs
         if r["policy"] == "dynamic"
         and (r["config"], r["dist"]) == (worst["config"],
                                          worst["dist"])), None)
    if twin is not None:
        rows.append(
            f"\nWorst fixed-policy cell: {worst['config']}/{worst['dist']} "
            f"pads {worst['pad_waste']:.2f}x; dynamic schedules the same "
            f"assignment at {twin['pad_waste']:.2f}x "
            f"({twin['occupancy']:.0%} block occupancy).")
    return "\n".join(rows)


def _load_serve_docs(name_filter):
    serve_dir = ROOT / "results" / "serve"
    docs = []
    if serve_dir.exists():
        for p in sorted(serve_dir.glob("*.json")):
            if not name_filter(p.name):
                continue
            try:
                d = json.loads(p.read_text())
            except (json.JSONDecodeError, UnicodeDecodeError):
                continue
            if isinstance(d, dict) and "records" in d:
                docs.append(d)
    return docs


def _cfg_str(c):
    """Compact self-describing cell config (the ``config`` block every
    results/serve record carries: ServeEngine.describe)."""
    if not c:
        return "—"
    seed = c.get("seed")
    return (f"{c.get('executor', '?')}/{c.get('schedule_policy', '?')}"
            f"/q:{c.get('quant', 'none')} adm={c.get('admission', '?')} "
            f"kvb={c.get('kv_block_size')} pc={c.get('prefill_chunk')}"
            + (f" seed={seed}" if seed is not None else ""))


def _ms(agg):
    return (f"{agg['p50'] * 1e3:.1f} / {agg['p99'] * 1e3:.1f}"
            if agg else "—")


def serving_table():
    """Per-request latency + paged-cache telemetry from
    benchmarks/serving_throughput.py (results/serve/*.json): the shared-
    prefix workload cells carry TTFT/TPOT aggregates (nearest-rank
    p50/p99 over retired requests, repro.obs.latency), the final
    ``PagedKVCache.stats()`` snapshot, and the self-describing cell
    config."""
    docs = _load_serve_docs(lambda n: not n.startswith("loadgen_"))
    cells = [(d.get("arch", "?"), r) for d in docs
             for r in d.get("shared_prefix") or []]
    if not cells:
        return ("_(no records — run ``PYTHONPATH=src python -m "
                "benchmarks.serving_throughput`` to populate "
                "results/serve/)_")

    rows = ["| arch | mode | tok/s | TTFT p50/p99 ms | TPOT p50/p99 ms | "
            "queue p50/p99 ms | kv in-use/total | prefix hit tok | "
            "config |",
            "|" + "---|" * 9]
    for arch, r in cells:
        lat = r.get("latency") or {}
        kv = r.get("kv_stats")
        rows.append(
            f"| {arch} | {r['mode']} | {r['tok_per_s']:.1f} | "
            f"{_ms(lat.get('ttft_s'))} | {_ms(lat.get('tpot_s'))} | "
            f"{_ms(lat.get('queue_wait_s'))} | "
            + (f"{kv['blocks_in_use']}/{kv['blocks_total']} | "
               f"{kv['prefix_hit_tokens']} | " if kv else "— | — | ")
            + f"{_cfg_str(r.get('config'))} |")
    return "\n".join(rows)


def loadgen_table():
    """Goodput under SLO from benchmarks/serve_loadgen.py
    (results/serve/loadgen_*.json): every cell is one seeded arrival
    trace replayed on virtual time through the open-stream front-end
    under one admission policy."""
    docs = _load_serve_docs(lambda n: n.startswith("loadgen_"))
    cells = [(d.get("arch", "?"), r) for d in docs
             for r in d.get("records") or []]
    if not cells:
        return ("_(no records — run ``PYTHONPATH=src python -m "
                "benchmarks.serve_loadgen`` to populate "
                "results/serve/loadgen_*.json)_")
    rows = ["| arch | pattern | admission | done/offered | goodput req/s | "
            "SLO attain | TTFT p50/p99 s | TPOT p50/p99 s | pre/res | "
            "config |",
            "|" + "---|" * 10]

    def s(v):
        return f"{v:.2f}" if v is not None else "—"

    for arch, r in sorted(cells, key=lambda c: (c[0], c[1].get("pattern")
                                                or "?")):
        cfg = dict(r.get("config") or {})
        adm = cfg.get("admission", "?")
        rows.append(
            f"| {arch} | {r.get('pattern', '?')} | {adm} | "
            f"{r['completed']}/{r['offered']} | "
            f"{r['goodput_rps']:.3f} | {r['slo_attainment']:.2f} | "
            f"{s(r.get('ttft_p50_s'))} / {s(r.get('ttft_p99_s'))} | "
            f"{s(r.get('tpot_p50_s'))} / {s(r.get('tpot_p99_s'))} | "
            f"{r['preempted']}/{r['resumed']} | {_cfg_str(cfg)} |")
    return "\n".join(rows)


def spec_table():
    """Speculative-decoding sweep from benchmarks/spec_decode.py
    (results/spec/*.json): acceptance rate and decode tokens per target
    forward vs the k=0 baseline, per (sampling, k, draft) cell."""
    spec_dir = ROOT / "results" / "spec"
    cells = []
    for p in sorted(spec_dir.glob("*.json")) if spec_dir.exists() else []:
        try:
            d = json.loads(p.read_text())
        except (json.JSONDecodeError, UnicodeDecodeError):
            continue
        cells.extend((d.get("arch", "?"), r) for r in d.get("records") or [])
    if not cells:
        return ("_(no records — run ``PYTHONPATH=src python -m "
                "benchmarks.spec_decode`` to populate results/spec/)_")
    rows = ["| arch | sampling | k | draft | accept | tgt fwd | "
            "tok/fwd | fwd win |",
            "|" + "---|" * 8]
    for arch, r in sorted(cells, key=lambda c: (c[0], c[1]["sampling"],
                                                c[1]["spec_k"],
                                                c[1].get("draft", ""))):
        if r["spec_k"] == 0:
            rows.append(f"| {arch} | {r['sampling']} | 0 | — | — | "
                        f"{r['target_forwards']} | "
                        f"{r['tokens_per_forward']:.2f} | baseline |")
        else:
            dname = "self" if r.get("draft_self") else r.get("draft", "?")
            rows.append(f"| {arch} | {r['sampling']} | {r['spec_k']} | "
                        f"{dname} | {r['acceptance_rate']:.2f} | "
                        f"{r['target_forwards']} | "
                        f"{r['tokens_per_forward']:.2f} | "
                        f"{r.get('forward_reduction', 0):.2f}x |")
    return "\n".join(rows)


def tuning_table():
    """Kernel-autotuner sweep results from benchmarks/kernel_tune.py
    (results/tuning/kernel_tune*.json): per (paper config, kernel) cell,
    the hard-coded default tile config vs the swept winner on the same
    microbenchmark, plus the persistent-cache footprint."""
    tune_dir = ROOT / "results" / "tuning"
    rows_in = []
    for p in sorted(tune_dir.glob("kernel_tune*.json")) \
            if tune_dir.exists() else []:
        try:
            d = json.loads(p.read_text())
        except (json.JSONDecodeError, UnicodeDecodeError):
            continue
        rows_in.extend((d, r) for r in d.get("records") or [])
    if not rows_in:
        return ("_(no records — run ``PYTHONPATH=src python -m "
                "benchmarks.kernel_tune`` to populate results/tuning/)_")

    def blk(c):
        return f"({c['block_m']},{c['block_n']},{c['block_k']})"

    rows = ["| config | kernel | shape (E,M,K,N) | scheme | "
            "default blocks / us | tuned blocks / us | speedup | cands |",
            "|" + "---|" * 8]
    for doc, r in sorted(rows_in, key=lambda x: (x[1]["config"],
                                                 x[1]["kernel"])):
        s = r["shape"]
        rows.append(
            f"| {r['config']} | {r['kernel']} | "
            f"({s['E']},{s['M']},{s['K']},{s['N']}) | {s['scheme']} | "
            f"{blk(r['default'])} {r['default']['us']:.0f} | "
            f"{blk(r['tuned'])} {r['tuned']['us']:.0f} | "
            f"{r['speedup']:.2f}x | {r['n_candidates']} |")
    cache_p = tune_dir / "cache.json"
    if cache_p.exists():
        try:
            c = json.loads(cache_p.read_text())
            rows.append(f"\nPersistent cache: {len(c.get('entries', {}))} "
                        f"entries (version {c.get('version')}, device "
                        f"{c.get('device') or '?'}) in "
                        f"results/tuning/cache.json.")
        except (json.JSONDecodeError, UnicodeDecodeError):
            pass
    return "\n".join(rows)


def perf_rows(paths, baseline_path, label):
    base = json.loads((ROOT / baseline_path).read_text())
    bc = base["collectives"]["total_bytes"]
    bt = base["memory"]["temp_bytes"]
    out = [f"**{label}** — baseline: collective "
           f"{bc / 1e9:.1f} GB/dev/step ({bc / 50e9:.2f} s), temp "
           f"{bt / 1e9:.1f} GB/dev", "",
           "| variant | collective GB | Δ coll | temp GB | Δ temp | verdict |",
           "|---|---|---|---|---|---|"]
    for p, verdict in paths:
        d = json.loads((ROOT / p).read_text())
        c = d["collectives"]["total_bytes"]
        t = d["memory"]["temp_bytes"]
        out.append(f"| {d.get('variant', 'baseline')} | {c / 1e9:.1f} | "
                   f"{c / bc:.2f}x | {t / 1e9:.1f} | {t / bt:.2f}x | "
                   f"{verdict} |")
    return "\n".join(out)


def main():
    dr = load_results(str(ROOT / "results" / "dryrun"))
    ok = [r for r in dr if r.get("status") == "ok"]
    skips = [r for r in dr if r.get("status") == "skip"]
    rl = [analyze_cell(r) for r in ok]
    rl1 = [r for r in rl if r.mesh == "16x16"]

    frac = sorted(rl1, key=lambda r: -r.roofline_fraction())
    print(EXPERIMENTS_TEMPLATE.format(
        n_ok=len(ok), n_skip=len(skips),
        sched=scheduling_table(),
        serving=serving_table(),
        loadgen=loadgen_table(),
        spec=spec_table(),
        tuning=tuning_table(),
        dryrun=dryrun_table(dr),
        roofline=markdown_table(sorted(
            rl1, key=lambda r: (r.arch, r.shape))),
        roofline_mp=markdown_table(sorted(
            [r for r in rl if r.mesh == "2x16x16"],
            key=lambda r: (r.arch, r.shape))),
        best="\n".join(f"  * {r.arch}/{r.shape}: "
                       f"{r.roofline_fraction():.1%} ({r.dominant}-bound)"
                       for r in frac[:5]),
        perf_qwen=perf_rows([
            ("results/perf/qwen2-7b.train_4k.16x16.accum1.json",
             "CONFIRMED (3.6x; predicted ~4x — grad reduce-scatter is "
             "accum-invariant)"),
            ("results/perf/qwen2-7b.train_4k.16x16.accum4pin.json",
             "REFUTED (no change: GSPMD had already sharded the carry)"),
            ("results/perf/qwen2-7b.train_4k.16x16.accum1nokvc.json",
             "REFUTED (+5%: GSPMD re-derives a worse all-to-all pattern)"),
            ("results/perf/qwen2-7b.train_4k.16x16.accum1-don.json",
             "kept (donation aliases 0.3 GB; correctness practice)"),
        ], "results/dryrun/qwen2-7b.train_4k.16x16.json",
            "Cell 1: qwen2-7b x train_4k (most collective-bound)"),
        perf_ds=perf_rows([
            ("results/perf/deepseek-v2-236b.train_4k.16x16.cf125.json",
             "CONFIRMED (a2a -43%, temp -23%)"),
            ("results/perf/deepseek-v2-236b.train_4k.16x16.accum1.json",
             "REFUTED for this arch (coll -23% but temp +59%, far over HBM)"),
            ("results/perf/deepseek-v2-236b.train_4k.16x16.cf125-pin.json",
             "REFUTED (carry already sharded)"),
            ("results/perf/deepseek-v2-236b.train_4k.16x16.cf125-bf16attn.json",
             "kept (strictly less traffic; peak unchanged on CPU model)"),
            ("results/perf/deepseek-v2-236b.train_4k.16x16.cf125-a4.json",
             "memory/collective tradeoff point"),
            ("results/perf/deepseek-v2-236b.train_4k.16x16.cf125-a8.json",
             "memory/collective tradeoff point"),
            ("results/perf/deepseek-v2-236b.train_4k.2x16x16.cf125-a8-mp.json",
             "2-pod: temp -13% further"),
        ], "results/dryrun/deepseek-v2-236b.train_4k.16x16.json",
            "Cell 2: deepseek-v2-236b x train_4k (paper-representative)"),
        perf_dsd=perf_rows([
            ("results/perf/deepseek-v2-236b.decode_32k.16x16.servetp.json",
             "partial (-5%: dense gathers were the small term)"),
            ("results/perf/deepseek-v2-236b.decode_32k.16x16.fsdp-int8.json",
             "CONFIRMED (3.4x: halved logical bytes + avoids f32-gather)"),
            ("results/perf/deepseek-v2-236b.decode_32k.16x16.servetp-int8.json",
             "CONFIRMED (4.1x combined — the optimized serving config)"),
        ], "results/dryrun/deepseek-v2-236b.decode_32k.16x16.json",
            "Cell 3: deepseek-v2-236b x decode_32k (worst roofline frac)"),
    ))


EXPERIMENTS_TEMPLATE = """# EXPERIMENTS

TPU-native reproduction of *Cross-Platform Fused MoE Dispatch in Triton*
(TritonMoE). Hardware model: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI. Meshes: 16x16 (1 pod, 256 chips) and 2x16x16 (2 pods,
512 chips). This container is CPU-only: all full-scale numbers come from
``lower().compile()`` artifacts (dry-run), kernels are validated in
interpret mode, CPU benchmarks run width-scaled shapes.

## §Paper-claims validation (benchmarks/, CPU + analytic)

| paper claim | our result | artifact |
|---|---|---|
| grouped GEMM >> loop-over-experts (Table 4: 15.4x) | 2.5x CPU-measured at 1/8 width, 512 tok (CPU has no launch-overhead cliff; structural win reproduced) | fusion_ablation |
| fused gate+up over unfused: 1.15x (Table 4) | 1.13x CPU-measured; 1.08x analytic v5e at full Mixtral dims | fusion_ablation |
| dispatch faster than dense at small batch (Tables 2-3) | 1.19-10.4x vs dense oracle across configs/batches | e2e_latency |
| expert-scaling cliff at 64+ experts (Table 5: 111->8 TFLOPS) | v5e-analytic 102->13 TFLOPS (E=8->256); CPU tok/s mirrors | expert_scaling |
| expert FFN dominates pipeline (Table 6: >95%) | 99.3% CPU-measured; permute+unpermute <1% | stage_roofline |
| fused kernel ~43% BW / ~35% compute eff (Table 6) | analytic v5e: 52% compute eff fused vs 48% unfused | stage_roofline |
| skew hurts fixed-BLOCK_M at 64+ experts (§4.7) | tile-padding waste up to 1.75x; EP drop\\@cf1.25 43.9%->74.6% (qwen2-moe, zipf 1.2->2.0) | skew_sensitivity |

## §Scheduling policies (beyond-paper; DESIGN.md §3)

Schedule construction is a pluggable policy (repro.scheduling): ``fixed``
(the paper), ``capacity_factor`` (bounded buckets, GShard drops),
``dynamic`` (adaptive block-to-expert assignment — the paper's named future
work; serving default).  ScheduleStats telemetry per (config x distribution
x policy), from benchmarks/skew_sensitivity.py:

{sched}

## §Serving latency (beyond-paper; DESIGN.md §10)

Per-request latency accounting is always on in the serve engine (host
clock reads only — no device ops): TTFT, TPOT (mean inter-token gap),
queue wait, end-to-end, materialized into ``Request.stats`` and
aggregated to nearest-rank p50/p99.  The shared-prefix workload
(benchmarks/serving_throughput.py) reports them per cache layout,
alongside the run-final paged-cache counters:

{serving}

## §Goodput under SLO (beyond-paper; DESIGN.md §11)

The open-stream front-end (repro.serve.frontend) serves seeded arrival
traces replayed on VIRTUAL time (one engine step = one fixed virtual
tick), so goodput — completions that met their TTFT/TPOT deadlines, per
second — is a pure function of (trace seed, cell config).  ``slo``
admission orders by deadline feasibility and preempts requests that
already lost their own SLO (paged: host-side table park, KV pinned;
contiguous: resume re-prefills), but only while a feasible
deadline-holder waits:

{loadgen}

## §Speculative decoding (beyond-paper; DESIGN.md §13)

``SpecEngine`` drafts k tokens per slot with a cheap draft model (its
own paged block pool) and verifies all n*(k+1) rows in ONE batched
target forward — rejected tokens roll back as a host-side block-table
truncation.  Greedy speculative output is token-identical to the
non-speculative engine for ANY draft (asserted); the device-independent
win metric is decode tokens per target forward (wall-clock tok/s is
TPU-gated — CPU timings price the draft's interpreter overhead, not the
forward it saves):

{spec}

## §Kernel autotuning (beyond-paper; DESIGN.md §12)

The cutotune-style sweep (repro.tuning) times every valid
(block_m, block_n, block_k) tile config of the grouped-GEMM kernels per
(kernel, shape-bucket, dtype, quant scheme, executor) key and persists
winners to a versioned JSON cache consulted at trace time when
``RunConfig.autotune`` is set.  The default config is always a sweep
candidate, so tuned >= default holds on every recorded cell (asserted in
CI).  Off-TPU timings order the interpreter, not the MXU — the table
below is machinery validation; the deployment cache is built on the TPU
host by ``tools/build_tune_cache.py``:

{tuning}

## §Dry-run

{n_ok} cells compiled OK across both meshes; {n_skip} architectural skips
(encoder-only decode, quadratic-attention 500k) — see DESIGN.md §4.
Per-device numbers from ``memory_analysis()`` / ``cost_analysis()`` of the
SPMD module; collective GB are link-byte estimates corrected for scan trip
counts (methodology below).

{dryrun}

## §Roofline

Methodology: ``cost_analysis()`` counts while-loop bodies ONCE (verified:
a 10-step scan reports 1x body FLOPs), so raw HLO numbers are lower bounds.
The three terms below use (i) matmul-exact analytic FLOPs (validated
against an unrolled compile: tests/test_roofline.py), (ii) analytic
dominant-flow HBM bytes, (iii) HLO-parsed collective link bytes x static
trip counts (layer-scan depth x grad-accum steps, scope-classified via
``op_name`` metadata). ``MODEL/HLO`` = 6*N_active*D / executed FLOPs —
exposes remat (4/3x), top-k expansion, and capacity-padding waste. Known
CPU-lowering artifact: XLA:CPU upcasts bf16 dots to f32, so some weight
all-gathers appear at 2x their TPU-native bytes; the collective terms are
therefore conservative upper bounds (quantified in §Perf cell 3, where
int8 gathers dodge the artifact entirely).

### Single-pod (16x16, 256 chips) — BASELINE, all runnable cells

{roofline}

### Multi-pod (2x16x16, 512 chips)

{roofline_mp}

Best roofline fractions (single-pod):
{best}

Reading: TRAIN cells are collective-bound under the baseline FSDP^2+CP
policy (per-microbatch weight gathers dominate); prefill cells approach
25-42% of roofline on dense archs; decode cells are weight-gather-bound
(the paper's own DeepSeek-V3 finding, §Discussion). long_500k on rwkv6 is
effectively idle hardware (B=1) — the arch runs it, the economics don't.

## §Perf — hypothesis -> change -> measure log

The paper-faithful baseline (fused gate+up dispatch, fold-combine, EP
capacity 2.0, FSDP^2+CP, accum per specs.ACCUM) is the FLOOR recorded
above; every variant below is a separately-lowered artifact in
results/perf/. Stop rule: three consecutive <5% changes.

{perf_qwen}

Lesson: grad-accum microbatching multiplies weight-gather traffic; at 1M
tokens/step the activation memory (12.3 GB/dev) affords accum=1, paying
3.6x less ICI. Collective term 12.5 s -> 3.5 s/step; roofline fraction
7.05% -> 25.4% (the single largest measured win in this repo).

{perf_ds}

Lessons: (1) EP capacity factor is the paper's fixed-BLOCK_M tradeoff in
distributed form — 1.25 costs zero drops under uniform routing (benchmarks
skew_sensitivity quantifies the skew risk) and cuts a2a 43%. (2) For a
236B MoE, memory and collectives PULL OPPOSITE on accum: the table maps
the frontier; 2-pod + accum 8 + cf1.25 is the best measured point
(temp 32.3 GB on the conservative CPU buffer model). (3) Three
consecutive sub-5% iterations (pin, bf16attn, donation) hit the stop rule.

{perf_dsd}

Lesson (beyond-paper): MoE decode is expert-weight-gather bound exactly as
the paper's §Discussion predicts for DeepSeek-class models; weight-only
int8 experts + TP-resident dense weights cut the dominant term 4.1x
(1.11 s -> 0.27 s/step, int8 dequant validated to 2% rel err in
tests/test_quant.py). This is the serving configuration we'd deploy.

**Extended (beyond the three assigned cells) — prefill layout probe.**
Hypothesis: prefill is weight-gather bound like decode, so serve-TP should
flip it compute-bound. Measured: qwen2-7b prefill 35.1 -> 33.6 GB (-4%),
gemma2-9b 77.5 -> 76.9 GB (-1%) — REFUTED: prefill's collective term is
CP's per-layer KV all-gather (small-GQA archs replicate K/V across the
sequence-sharded ranks), not weight movement. The fix on real hardware is
ring attention (collective-permute KV chunks overlapped with the score
GEMMs — bytes unchanged but fully hidden under compute in the max-term
roofline); left as the top item for a follow-up iteration.

## §Perf — paper-faithful vs beyond-paper summary

| cell | paper-faithful baseline | beyond-paper optimized | gain | roofline frac |
|---|---|---|---|---|
| qwen2-7b train_4k | coll 625.9 GB/step (12.5 s) | 173.9 GB (3.5 s) via accum=1 + donation | 3.6x | 7.05% -> 25.4% |
| deepseek-v2 train_4k | coll 2649.7 GB (53.0 s), temp 86.1 GB | 2371.3 GB (47.4 s), temp 65.8 GB via cf1.25+bf16-attn; frontier to temp 32.3 GB at 2-pod/accum8 | 1.12x coll / 1.31-2.7x mem | 4.91% -> 5.48% |
| deepseek-v2 decode_32k | coll 55.5 GB (1.11 s) | 13.5 GB (0.27 s) via serve-TP + int8 experts | 4.1x | 0.01% -> 0.04% (gather-bound by nature at B=128; see paper §Discussion) |
"""


if __name__ == "__main__":
    main()
