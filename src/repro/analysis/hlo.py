"""HLO-text analysis: collective traffic and loop structure.

``compiled.cost_analysis()`` does NOT report collective traffic, and it
counts each ``while``-loop body exactly ONCE (verified empirically — a
10-iteration scan reports 1x its body FLOPs).  This module parses
``compiled.as_text()`` directly:

* every collective op (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute) with its RESULT shape (post-optimization
  HLO prints operands without shapes), replica-group size g, and the JAX
  scope path from ``metadata={op_name=...}``;
* per-device link-byte estimates using ring-collective formulas:
    all-reduce       2 * bytes * (g-1)/g
    all-gather       bytes * (g-1)/g          (bytes = result/output size)
    reduce-scatter   bytes_in * (g-1)/g       (bytes_in = result * g)
    all-to-all       bytes * (g-1)/g
    collective-permute  bytes
* scope classification so the roofline layer can multiply collectives that
  live inside the layer-stack / grad-accum scans by their static trip
  counts (the op metadata carries the ``layer_stack`` named_scope).

Per-device numbers: the SPMD module is the per-device program.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, NamedTuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_KIND_RE = re.compile(
    r"\b(" + "|".join(COLLECTIVES) + r")(-start|-done)?\(")
_RESULT_RE = re.compile(r"=\s*(?:\()?\s*([a-z]+[0-9]+[a-z0-9]*|pred)\[([0-9,]*)\]")
_TUPLE_RES_RE = re.compile(r"([a-z]+[0-9]+[a-z0-9]*|pred)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


class CollectiveOp(NamedTuple):
    kind: str
    result_bytes: int
    group_size: int
    link_bytes: float      # per-device estimate (ring formulas)
    scope: str


def _result_bytes(line: str) -> int:
    """Sum all result-shape components before the op name (handles tuples)."""
    lhs = line.split("=", 1)[1]
    # result shapes appear before the opcode token
    m = _KIND_RE.search(lhs)
    head = lhs[:m.start()] if m else lhs
    total = 0
    for dt, dims in _TUPLE_RES_RE.findall(head):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _link_bytes(kind: str, res: int, g: int) -> float:
    if g <= 1 and kind != "collective-permute":
        return 0.0
    f = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * res * f
    if kind == "all-gather":
        return res * f
    if kind == "reduce-scatter":
        return res * g * f
    if kind == "all-to-all":
        return res * f
    return float(res)      # collective-permute


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _KIND_RE.search(line)
        if not m or "=" not in line:
            continue
        if m.group(2) == "-done":      # async pair: count -start only
            continue
        kind = m.group(1)
        res = _result_bytes(line)
        g = _group_size(line)
        if kind == "all-gather" and m.group(2) == "-start":
            # result of all-gather-start is a tuple (operand, result);
            # keep the larger component as the gathered output
            pass
        scope = ""
        om = _OPNAME_RE.search(line)
        if om:
            scope = om.group(1)
        out.append(CollectiveOp(kind, res,
                                g, _link_bytes(kind, res, g), scope))
    return out


def in_layer_stack(scope: str) -> bool:
    return "layer_stack" in scope


def in_accum_loop(scope: str) -> bool:
    # grad-accum scan wraps the whole microbatch: its ops carry the
    # train_step/while prefix but NOT the optimizer scopes
    return "/while/" in scope


def collective_report(hlo_text: str, layer_trips: int = 1,
                      accum_trips: int = 1) -> Dict:
    """Aggregate with structural loop multipliers.

    Ops whose scope shows they live in the layer-stack scan get x
    layer_trips; everything inside the grad-accum while additionally x
    accum_trips (the layer scan is inside the accum scan)."""
    by_kind: Dict[str, float] = defaultdict(float)
    by_kind_raw: Dict[str, float] = defaultdict(float)
    total = 0.0
    raw = 0.0
    n = 0
    for op in parse_collectives(hlo_text):
        mult = 1
        if in_layer_stack(op.scope):
            mult *= layer_trips
        if accum_trips > 1 and in_accum_loop(op.scope):
            mult *= accum_trips
        by_kind[op.kind] += op.link_bytes * mult
        by_kind_raw[op.kind] += op.link_bytes
        total += op.link_bytes * mult
        raw += op.link_bytes
        n += 1
    return {"total_bytes": total, "raw_bytes": raw,
            "by_kind": dict(by_kind), "by_kind_raw": dict(by_kind_raw),
            "count": n,
            "layer_trips": layer_trips, "accum_trips": accum_trips}
