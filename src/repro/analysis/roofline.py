"""Roofline assembly: three terms per (arch x shape x mesh) cell.

    compute term    = FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory term     = HBM bytes / (chips x 819e9 B/s)
    collective term = link bytes / (chips x 50e9 B/s per ICI link)

Sources (documented in EXPERIMENTS.md §Roofline methodology):
  * FLOPs / HBM bytes: ``compiled.cost_analysis()`` raw values are reported
    as-is ("hlo_raw"), but XLA counts while-loop bodies ONCE, so the primary
    numbers come from the analytic model in analysis/flops.py (matmul-exact;
    validated against an unrolled compile in tests/test_roofline.py).
  * collective bytes: parsed from the compiled HLO with ring-collective
    link-byte formulas and multiplied by the statically-known layer-scan /
    grad-accum trip counts (analysis/hlo.py).

The dominant term is the bottleneck; MODEL_FLOPS / dispatch-FLOPs exposes
remat + top-k expansion + capacity-padding waste.
"""
from __future__ import annotations

import json
import pathlib
from dataclasses import asdict, dataclass
from typing import Dict, Optional

from repro.analysis.flops import cell_cost
from repro.configs import SHAPE_BY_NAME, get_config

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

HBM_PER_CHIP = 16e9          # v5e capacity, for fit checks


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    dispatch_flops: float
    flops_ratio: float          # MODEL / dispatched (useful fraction)
    hlo_raw_flops: Optional[float]
    hlo_raw_bytes: Optional[float]
    collective_bytes: float
    temp_bytes_per_dev: Optional[float]
    fits_hbm: Optional[bool]
    note: str = ""

    def step_time_s(self) -> float:
        """Perfect-overlap bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the step-time bound (an MFU bound)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        t = self.step_time_s()
        return ideal / t if t > 0 else 0.0


_NOTES = {
    "compute": "compute-bound: raise useful-FLOP fraction (cut remat/"
               "capacity waste) or grow per-chip arithmetic intensity",
    "memory": "HBM-bound: cut weight/cache re-reads (fuse gate+up, batch "
              "more tokens per weight load, quantize cache)",
    "collective": "ICI-bound: shrink per-layer gathers (gather bf16 not "
                  "fp32, overlap a2a with expert GEMMs, widen DP axis)",
}


def analyze_cell(record: Dict, *, capacity_factor: float = 2.0) -> Roofline:
    cfg = get_config(record["arch"])
    shape = SHAPE_BY_NAME[record["shape"]]
    chips = 512 if record["mesh"] == "2x16x16" else 256
    accum = (record.get("meta") or {}).get("accum", 1)
    cost = cell_cost(cfg, shape, chips=chips, accum=accum,
                     capacity_factor=capacity_factor,
                     remat=(shape.kind == "train"))

    coll_bytes = (record.get("collectives") or {}).get("total_bytes", 0.0)
    compute_s = cost.dispatch_flops / (chips * PEAK_FLOPS)
    memory_s = cost.hbm_bytes / HBM_BW             # hbm_bytes is per-device
    collective_s = coll_bytes / ICI_BW             # per-device link bytes
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    temp = (record.get("memory") or {}).get("temp_bytes")
    arg = (record.get("memory") or {}).get("argument_bytes") or 0
    fits = None
    if temp is not None:
        fits = (temp + arg) <= HBM_PER_CHIP

    return Roofline(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops=cost.model_flops,
        dispatch_flops=cost.dispatch_flops,
        flops_ratio=cost.model_flops / max(cost.dispatch_flops, 1.0),
        hlo_raw_flops=(record.get("cost") or {}).get("flops"),
        hlo_raw_bytes=(record.get("cost") or {}).get("bytes accessed"),
        collective_bytes=coll_bytes,
        temp_bytes_per_dev=temp,
        fits_hbm=fits,
        note=_NOTES[dominant],
    )


def load_results(result_dir: str):
    out = []
    for p in sorted(pathlib.Path(result_dir).glob("*.json")):
        try:
            out.append(json.loads(p.read_text()))
        except Exception:
            pass
    return out


def markdown_table(rooflines, *, include_note: bool = False) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | MODEL/HLO | roofline frac | fits HBM |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for r in rooflines:
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | {r.dominant} | "
            f"{r.flops_ratio:.2f} | {r.roofline_fraction():.2%} | "
            f"{'Y' if r.fits_hbm else 'N' if r.fits_hbm is not None else '?'} |")
    return "\n".join(rows)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    recs = [r for r in load_results(args.results) if r.get("status") == "ok"]
    if args.mesh:
        recs = [r for r in recs if r["mesh"] == args.mesh]
    rl = [analyze_cell(r) for r in recs]
    print(markdown_table(rl))
    for r in rl:
        print(f"  {r.arch}/{r.shape}/{r.mesh}: {r.dominant} -> {r.note}")


if __name__ == "__main__":
    main()
