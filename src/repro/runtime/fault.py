"""Fault-tolerance runtime: straggler detection, failure injection,
restart-with-resume supervision.

At 1000+ nodes the common failure modes are (a) a node dying (checkpoint/
restart handles it), (b) a node running slow (stragglers silently drag the
whole synchronous step).  ``StragglerMonitor`` keeps a rolling step-time
window and flags steps beyond ``factor`` x the rolling median — in a real
deployment the signal feeds the scheduler (evict + re-shard via the elastic
checkpoint path, which tests exercise end-to-end on fake devices)."""
from __future__ import annotations

import collections
import statistics
import time
from typing import Callable, Deque, List, Optional


class StragglerMonitor:
    def __init__(self, window: int = 32, factor: float = 2.0,
                 warmup: int = 3,
                 clock: Callable[[], float] = time.perf_counter):
        """``clock`` is injectable so tests drive the monitor with a
        deterministic virtual clock instead of wall-time sleeps."""
        self.window: Deque[float] = collections.deque(maxlen=window)
        self.factor = factor
        self.warmup = warmup
        self.clock = clock
        self.flagged: List[dict] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._t0 = self.clock()
        self._step = step

    def end_step(self) -> Optional[dict]:
        dt = self.clock() - self._t0
        verdict = None
        if len(self.window) >= self.warmup:
            med = statistics.median(self.window)
            if dt > self.factor * med:
                verdict = {"step": self._step, "duration": dt,
                           "median": med,
                           "slowdown": dt / med}
                self.flagged.append(verdict)
        self.window.append(dt)
        return verdict


class FailureInjector:
    """Deterministically raise at a given step — tests use this to prove
    the checkpoint/restart path loses no more than `save_every` steps."""

    def __init__(self, fail_at_step: Optional[int] = None,
                 exc: type = RuntimeError):
        self.fail_at_step = fail_at_step
        self.exc = exc
        self.fired = False

    def maybe_fail(self, step: int) -> None:
        if self.fail_at_step is not None and step == self.fail_at_step \
                and not self.fired:
            self.fired = True
            raise self.exc(f"injected failure at step {step}")


def supervise(run: Callable[[], dict], *, max_restarts: int = 3) -> dict:
    """Run a (resumable) training function, restarting on failure — the
    single-process stand-in for a cluster supervisor."""
    restarts = 0
    while True:
        try:
            out = run()
            out["restarts"] = restarts
            return out
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
