"""repro.runtime subpackage."""
