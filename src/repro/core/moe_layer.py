"""Parameterized MoE layer: routed experts + optional shared experts.

Wraps core.dispatch with parameter init/apply in the repo's pytree-params
convention.  Shared experts (DeepSeek-style) are a single dense SwiGLU of
width ``n_shared * d_ff_expert`` applied to every token (they are dense
compute — XLA already optimal — so they bypass the dispatch pipeline, as the
paper's framing implies)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.dispatch import MoEDispatchConfig, moe_ffn


def init_moe_params(key, moe: MoEConfig, d_model: int, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    E, f = moe.n_experts, moe.d_ff_expert
    s = d_model ** -0.5
    params = {
        "router": (jax.random.normal(ks[0], (d_model, E)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d_model, f)) * s).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, f)) * s).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d_model)) * f ** -0.5).astype(dtype),
    }
    if moe.n_shared_experts:
        fs = moe.n_shared_experts * f
        k1, k2, k3 = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w_gate": (jax.random.normal(k1, (d_model, fs)) * s).astype(dtype),
            "w_up": (jax.random.normal(k2, (d_model, fs)) * s).astype(dtype),
            "w_down": (jax.random.normal(k3, (fs, d_model)) * fs ** -0.5).astype(dtype),
        }
    return params


def dispatch_config(moe: MoEConfig, *, executor: str | None = None,
                    impl: str | None = None,
                    fuse_gate_up: bool = True, fold_combine: bool = True,
                    schedule_policy: str = "fixed",
                    capacity_factor: float | None = None,
                    block_m_min: int = 8, emit_stats: bool = False,
                    autotune: bool = False,
                    interpret=None) -> MoEDispatchConfig:
    """``executor`` names a registered backend (repro.execution); ``impl``
    is the deprecated pre-registry alias for it."""
    if impl is not None:
        import warnings
        warnings.warn("dispatch_config(impl=...) is deprecated; pass "
                      "executor=... (the registry field name)",
                      DeprecationWarning, stacklevel=2)
    return MoEDispatchConfig(
        n_experts=moe.n_experts, top_k=moe.top_k, block_m=moe.block_m,
        executor=(executor or impl or "xla"),
        fuse_gate_up=fuse_gate_up, fold_combine=fold_combine,
        gating=moe.gating, norm_topk=moe.norm_topk,
        routed_scale=moe.routed_scale, interpret=interpret,
        schedule_policy=schedule_policy,
        capacity_factor=(moe.capacity_factor if capacity_factor is None
                         else capacity_factor),
        block_m_min=block_m_min, emit_stats=emit_stats,
        autotune=autotune)


def apply_moe(params, x: jnp.ndarray, cfg: MoEDispatchConfig):
    """x: (..., d) -> (y, aux). Flattens leading dims for dispatch.

    Quantized params (scheme-tagged QuantTensor expert mats) flow through
    the executor's capability contract: ``supports_scheme`` gates, and the
    backend's ``prepare_weights`` decides between materializing and
    in-scan per-block dequantization (DESIGN.md §8)."""
    from repro.execution import get_executor
    from repro.quantization import expert_weights, params_scheme
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    scheme = params_scheme(params)
    if not get_executor(cfg.executor).supports_scheme(scheme):
        raise ValueError(
            f"executor {cfg.executor!r} does not support quant scheme "
            f"{scheme!r}; requantize the params or pick another backend")
    w = expert_weights(params, x.dtype)
    y, aux = moe_ffn(x2, params["router"], w["w_gate"],
                     w["w_up"], w["w_down"], cfg)
    if "shared" in params:
        sh = params["shared"]
        xf = x2.astype(jnp.float32)
        g = jnp.dot(xf, sh["w_gate"].astype(jnp.float32))
        u = jnp.dot(xf, sh["w_up"].astype(jnp.float32))
        y_sh = jnp.dot((g * jax.nn.sigmoid(g)) * u,
                       sh["w_down"].astype(jnp.float32))
        y = y + y_sh.astype(y.dtype)
    return y.reshape(shape), aux
