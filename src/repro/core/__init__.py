"""Core: the paper's MoE dispatch pipeline as a composable JAX module."""
from repro.core.dispatch import (DispatchPlan, MoEDispatchConfig,  # noqa: F401
                                 execute, moe_ffn, plan_dispatch)
from repro.core.moe_layer import apply_moe, dispatch_config, init_moe_params  # noqa: F401
from repro.core.schedule import BlockSchedule, build_schedule, schedule_capacity  # noqa: F401
