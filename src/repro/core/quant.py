"""Back-compat shim over the unified quantization API (DESIGN.md §8).

The int8-only module that used to live here (suffix-keyed ``_q``/``_s``
param dicts, a single hard-coded layout) grew into a registry of
`QuantScheme`s with a pytree `QuantTensor` — see ``repro.quantization``.
Serving notes that motivated it are unchanged: MoE decode is gather-bound
on expert weights (EXPERIMENTS.md §Perf cell 3), so compressing the
gathered bytes is the dominant lever; dequantization happens per selected
expert block inside the grouped GEMM scans, after the gather.

Old call sites keep working with the old names; ``quantize_moe_params`` /
``quantize_params_tree`` now default to the ``int8_expert`` scheme, which
is the original layout bit-for-bit (same scale formula, same round/clip).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.quantization import (EXPERT_MATS, QuantTensor,  # noqa: F401
                                expert_weights, get_scheme, is_quantized,
                                params_scheme, quantize_moe_params,
                                quantize_params_tree)


def quantize_expert(w: jnp.ndarray):
    """(E, K, N) -> int8 payload + (E, 1, 1) scales (the pre-registry
    int8_expert entry point; prefer get_scheme(...).quantize)."""
    qt = get_scheme("int8_expert").quantize(w)
    return qt.q, qt.s


def effective_expert_weights(moe_params: dict, dtype) -> dict:
    """Pre-registry name for ``expert_weights`` (dtype retargeting)."""
    return expert_weights(moe_params, dtype)
