"""Weight-only int8 expert quantization (beyond-paper, serving path).

MoE decode is gather-bound on expert weights (EXPERIMENTS.md §Perf cell 3):
every step all-gathers each rank's expert shards over the FSDP axis.
Storing routed experts as int8 + per-expert fp32 scale halves the gathered
bytes; dequantization happens per selected expert block inside the grouped
GEMM scan, after the gather.  Per-expert (not per-channel) scales keep the
schedule-driven block gather trivial; tests bound the relative error.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

EXPERT_MATS = ("w_gate", "w_up", "w_down")


class QuantTensor(NamedTuple):
    """Acts like the (E, K, N) weight array inside the dispatch scans:
    ``w[e]`` gathers the int8 block + scale and dequantizes in-register."""
    q: jnp.ndarray        # (E, K, N) int8
    s: jnp.ndarray        # (E, 1, 1) f32
    dtype: jnp.dtype

    @property
    def shape(self):
        return self.q.shape

    def __getitem__(self, idx):
        return (self.q[idx].astype(jnp.float32)
                * self.s[idx]).astype(self.dtype)


def quantize_expert(w: jnp.ndarray):
    """(E, K, N) -> int8 weights + (E,1,1) scales."""
    s = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=(1, 2),
                keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -127, 127
                 ).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def quantize_moe_params(moe_params: dict) -> dict:
    """Replace routed expert tensors with (q, s) pairs; router/shared stay."""
    out = {k: v for k, v in moe_params.items() if k not in EXPERT_MATS}
    for name in EXPERT_MATS:
        q, s = quantize_expert(moe_params[name])
        out[name + "_q"] = q
        out[name + "_s"] = s
    return out


def is_quantized(moe_params: dict) -> bool:
    return "w_gate_q" in moe_params


def effective_expert_weights(moe_params: dict, dtype) -> dict:
    """-> {"w_gate": array-or-QuantTensor, ...} for the dispatch pipeline."""
    if not is_quantized(moe_params):
        return {k: moe_params[k] for k in EXPERT_MATS}
    return {name: QuantTensor(moe_params[name + "_q"],
                              moe_params[name + "_s"], dtype)
            for name in EXPERT_MATS}


def quantize_params_tree(params: dict) -> dict:
    """Quantize every MoE block in a full model param tree (lm.py layout:
    stacked 'body' leaves keep their leading group axis — quantization is
    vmapped over it)."""
    def walk(node):
        if isinstance(node, dict):
            if "w_gate" in node and "router" in node:      # a moe param dict
                w = node["w_gate"]
                if w.ndim == 4:                            # stacked (G,E,K,N)
                    qfn = jax.vmap(quantize_moe_params)
                    # vmap over dicts: build manually
                    out = {k: v for k, v in node.items()
                           if k not in EXPERT_MATS}
                    for name in EXPERT_MATS:
                        q, s = jax.vmap(quantize_expert)(node[name])
                        out[name + "_q"] = q
                        out[name + "_s"] = s
                    return out
                return quantize_moe_params(node)
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(params)
