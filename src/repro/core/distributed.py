"""Expert-parallel MoE dispatch over a mesh axis (beyond-paper).

The paper targets single-GPU dispatch and defers multi-device expert
parallelism (its Limitation 6).  Here the paper's pipeline becomes the
*per-device inner loop* of a GShard-style EP layer.  EP is an *executor
wrapper*, not a forked pipeline: each rank consumes a `DispatchPlan`
(routing built once by ``plan_dispatch`` — never re-derived locally) and
composes the configured executor's phase methods (permute / expert_ffn /
unpermute, repro.execution) over a rank-local layout.  Any schedule-capable
backend works under EP unchanged; only the layout between the phases is
EP-specific:

``token_layout="sharded"`` (train / prefill — tokens are sequence-sharded
over the EP axis):
  plan (local router) -> capacity-bucketed send buffers -> all_to_all ->
  executor.expert_ffn on a static tile-aligned receive layout (slot s of
  rank r belongs to local expert s // C — no dynamic schedule needed) ->
  all_to_all back -> weighted combine on the source rank.

``token_layout="replicated"`` (decode — every EP rank sees the same tokens):
  each rank restricts the plan's routing to the experts it owns (non-owned
  assignments routed to an inactive sentinel expert whose blocks are
  skipped), runs executor permute/expert_ffn/unpermute on that local
  schedule, then a single psum over the EP axis combines partial outputs
  — the collective is O(B*d) instead of an all_to_all of expert buffers.

Tokens overflowing an expert's capacity bucket are dropped (GShard
semantics); capacity_factor controls headroom and tests cover the
drop/no-drop regimes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, current_mesh, shard_map
from repro.core.dispatch import MoEDispatchConfig
from repro.execution import (combine_scale_rows, get_executor,
                             plan_dispatch)
from repro.scheduling import (BlockSchedule, build_schedule, capacity_slots,
                              expert_capacity, policy_config_kwargs)


def _static_schedule(n_rows: int, n_local_experts: int, block_m: int,
                     rows_per_expert: int) -> BlockSchedule:
    """Schedule for the fixed EP receive layout: rows grouped by local
    expert with a static group size (rows_per_expert each)."""
    nb = n_rows // block_m
    block_expert = (jnp.arange(nb, dtype=jnp.int32)
                    // (rows_per_expert // block_m))
    return BlockSchedule(
        counts=jnp.full((n_local_experts,), rows_per_expert, jnp.int32),
        group_offsets=jnp.arange(n_local_experts + 1, dtype=jnp.int32)
        * rows_per_expert,
        src_tok=jnp.zeros((n_rows,), jnp.int32),
        pos=jnp.zeros((1, 1), jnp.int32),
        block_expert=block_expert,
        block_active=jnp.ones((nb,), jnp.int32),
        capacity=n_rows, block_m=block_m)


def _rank_plan(params, x_loc, cfg: MoEDispatchConfig, axis: str):
    """Routing plan for this rank's tokens + EP-meaned aux.  One plan per
    batch; both layouts consume it instead of re-deriving routing."""
    plan = plan_dispatch(x_loc, params["router"], cfg, with_schedule=False)
    aux = {k: jax.lax.pmean(v, axis) for k, v in plan.aux.items()}
    return plan._replace(aux=aux)


# ----------------------------------------------------------------------
def _ep_sharded_local(params, x_loc, cfg: MoEDispatchConfig, axis: str,
                      capacity_factor: float):
    """Per-rank body for token_layout='sharded'. x_loc: (T_local, d)."""
    ep = axis_size(axis)
    E, k, M = cfg.n_experts, cfg.top_k, cfg.block_m
    E_local = E // ep
    Tl, d = x_loc.shape

    plan = _rank_plan(params, x_loc, cfg, axis)

    # capacity per (expert) bucket, tile-aligned so the receive layout is
    # statically tile-aligned for the grouped GEMM; slot/keep semantics are
    # shared with the single-device capacity_factor policy (scheduling/)
    cap = expert_capacity(Tl, k, E, M, capacity_factor)

    flat = plan.indices.reshape(-1)                          # (Tl*k,)
    slot, _counts = capacity_slots(flat, E)
    keep = slot < cap
    dest = flat * cap + slot                                 # row in send buf

    send = jnp.zeros((E * cap, d), x_loc.dtype)
    src_rows = jnp.repeat(jnp.arange(Tl), k)
    send = send.at[jnp.where(keep, dest, E * cap)].set(
        x_loc[src_rows], mode="drop")

    # (E*cap, d) -> (ep, E_local*cap, d) -> a2a -> rows from every peer
    send = send.reshape(ep, E_local * cap, d)
    recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    # regroup: (ep, E_local, cap, d) -> (E_local, ep*cap, d): contiguous
    # per local expert, group size ep*cap (tile-aligned since cap % M == 0)
    recv = recv.reshape(ep, E_local, cap, d).transpose(1, 0, 2, 3) \
        .reshape(E_local * ep * cap, d)

    from repro.quantization import expert_weights
    ex = get_executor(cfg.executor)
    sched = _static_schedule(E_local * ep * cap, E_local, M, ep * cap)
    local_w = ex.prepare_weights(expert_weights(params, x_loc.dtype), cfg)
    y = ex.expert_ffn(recv, local_w, sched, cfg)

    # inverse path
    y = y.reshape(E_local, ep, cap, d).transpose(1, 0, 2, 3) \
        .reshape(ep, E_local * cap, d)
    y = jax.lax.all_to_all(y, axis, split_axis=0, concat_axis=0, tiled=False)
    y = y.reshape(E * cap, d)

    gathered = y[jnp.minimum(dest, E * cap - 1)]             # (Tl*k, d)
    w_eff = jnp.where(keep, plan.weights.reshape(-1), 0.0)
    out = jnp.sum(gathered.reshape(Tl, k, d).astype(jnp.float32)
                  * w_eff.reshape(Tl, k, 1), axis=1)
    return out.astype(x_loc.dtype), plan.aux


def _ep_replicated_local(params, x_loc, cfg: MoEDispatchConfig, axis: str,
                         capacity_factor: float):
    """Per-rank body for token_layout='replicated' (decode)."""
    ep = axis_size(axis)
    E, M = cfg.n_experts, cfg.block_m
    E_local = E // ep
    r = jax.lax.axis_index(axis)
    base = r * E_local

    plan = _rank_plan(params, x_loc, cfg, axis)

    mine = (plan.indices >= base) & (plan.indices < base + E_local)
    # non-owned assignments -> sentinel expert E_local (blocks deactivated)
    idx_local = jnp.where(mine, plan.indices - base, E_local)
    w_masked = jnp.where(mine, plan.weights, 0.0)

    # the configured schedule policy, over the local experts plus one
    # sentinel "expert" that absorbs non-owned assignments; capacity buckets
    # must be sized over the GLOBAL expert count so EP drop semantics match
    # the single-device policy exactly
    kw = policy_config_kwargs(cfg.schedule_policy, cfg)
    if cfg.schedule_policy == "capacity_factor":
        kw["cap"] = expert_capacity(x_loc.shape[0], cfg.top_k, E, M,
                                    capacity_factor)
    sched = build_schedule(idx_local, E_local + 1, M,
                           policy=cfg.schedule_policy, **kw)
    # deactivate sentinel blocks so Pallas skips them on TPU
    sched = sched._replace(
        block_active=sched.block_active
        * (sched.block_expert < E_local).astype(jnp.int32),
        block_expert=jnp.minimum(sched.block_expert, E_local - 1))

    from repro.quantization import expert_weights
    ex = get_executor(cfg.executor)
    xp = ex.permute(x_loc, sched, cfg)
    scale = combine_scale_rows(sched, w_masked)
    local_w = ex.prepare_weights(expert_weights(params, x_loc.dtype), cfg)
    y = ex.expert_ffn(xp, local_w, sched, cfg, row_scale=scale)
    out = ex.unpermute(y, sched, None, cfg)
    out = jax.lax.psum(out.astype(jnp.float32), axis)
    return out.astype(x_loc.dtype), plan.aux


# ----------------------------------------------------------------------
def apply_moe_ep(params, x: jnp.ndarray, cfg: MoEDispatchConfig, *,
                 axis: str = "model", capacity_factor: Optional[float] = None,
                 token_layout: str = "sharded"):
    """Distributed MoE layer. x: (B, S, d) inside jit (GSPMD context);
    the EP dispatch itself runs under shard_map over `axis`.

    ``capacity_factor`` (None -> ``cfg.capacity_factor``) is the single
    capacity knob for BOTH layouts: the sharded path's a2a transport
    buckets, and the replicated path's capacity_factor-policy drop buckets.
    Note the sharded layout's receive side is inherently a static capacity
    layout (the all-to-all needs load-independent buffers), so
    ``cfg.schedule_policy`` applies to the replicated (decode) layout and
    single-device dispatch only — the sharded path ignores it by design.

    ``cfg.executor`` must name a schedule-capable backend (phase-level
    permute/expert_ffn/unpermute) — ``xla`` or ``pallas``; the ``dense``
    oracle has no permuted layout and raises under EP.

    Shared experts are dense compute on (sharded) tokens — they stay in
    plain GSPMD-land outside the shard_map.
    """
    if capacity_factor is None:
        capacity_factor = cfg.capacity_factor
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        raise RuntimeError("apply_moe_ep requires an active mesh "
                           "(jax.set_mesh(...) or `with mesh:`)")
    shape = x.shape
    d = shape[-1]
    other = [a for a in mesh.axis_names if a != axis]

    if token_layout == "sharded":
        # tokens: flatten (B, S) and split the token dim across `axis`;
        # batch stays on the dp axes.
        bspec = tuple(other) if shape[0] % _axsize(mesh, other) == 0 else None
        in_spec = P(bspec, axis, None)
        out_spec = P(bspec, axis, None)

        def body(p_loc, x_loc):
            B_l, S_l, _ = x_loc.shape
            y, aux = _ep_sharded_local(p_loc, x_loc.reshape(-1, d), cfg,
                                       axis, capacity_factor)
            return y.reshape(B_l, S_l, d), aux
    else:
        bspec = tuple(other) if shape[0] % _axsize(mesh, other) == 0 else None
        in_spec = P(bspec, None, None)
        out_spec = P(bspec, None, None)

        def body(p_loc, x_loc):
            B_l, S_l, _ = x_loc.shape
            y, aux = _ep_replicated_local(p_loc, x_loc.reshape(-1, d), cfg,
                                          axis, capacity_factor)
            return y.reshape(B_l, S_l, d), aux

    from repro.execution import get_executor as _get_ex
    from repro.quantization import params_scheme
    scheme = params_scheme(params)
    if not _get_ex(cfg.executor).supports_scheme(scheme):
        raise ValueError(
            f"executor {cfg.executor!r} does not support quant scheme "
            f"{scheme!r} under EP")

    routed = {k_: v for k_, v in params.items() if k_ != "shared"}
    # expert tensors shard over the EP axis on their leading (expert)
    # axis.  Built per LEAF so quantized params work for ANY scheme: a
    # QuantTensor contributes its compressed payload + scale leaves (both
    # expert-leading), and each rank gathers only compressed bytes.
    pspecs = {k_: (P(None, None) if k_ == "router"
                   else jax.tree.map(
                       lambda l: P(axis, *([None] * (l.ndim - 1))), v))
              for k_, v in routed.items()}
    aux_spec = {"lb_loss": P(), "router_z": P()}
    y, aux = shard_map(
        body, mesh=mesh, in_specs=(pspecs, in_spec),
        out_specs=(out_spec, aux_spec))(routed, x)

    if "shared" in params:
        sh = params["shared"]
        xf = x.astype(jnp.float32)
        g = jnp.dot(xf, sh["w_gate"].astype(jnp.float32))
        u = jnp.dot(xf, sh["w_up"].astype(jnp.float32))
        y = y + jnp.dot((g * jax.nn.sigmoid(g)) * u,
                        sh["w_down"].astype(jnp.float32)).astype(y.dtype)
    return y, aux


def _axsize(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _current_mesh():
    """Concrete mesh from set_mesh(...) or a `with mesh:` block."""
    return current_mesh()
