"""Expert-parallel MoE dispatch over a mesh axis (beyond-paper).

The paper targets single-GPU dispatch and defers multi-device expert
parallelism (its Limitation 6).  Here the paper's pipeline becomes the
*per-device inner loop* of a GShard-style EP layer.  EP is an *executor
wrapper*, not a forked pipeline: each rank consumes a `DispatchPlan`
(routing built once by ``plan_dispatch`` — never re-derived locally) and
composes the configured executor's phase methods (permute / expert_ffn /
unpermute, repro.execution) over a rank-local layout.  Any schedule-capable
backend works under EP unchanged; only the layout between the phases is
EP-specific:

``token_layout="sharded"`` (train / prefill / batch-sharded decode — tokens
are split over the EP axis), the **padding-free send path** (X-MoE style):
  plan (local router) -> policy drop decisions on GLOBAL slot ranks ->
  per-destination-rank COMPACTED send buffers (no per-expert tile rounding:
  the transport is sized by the schedule policy's capacity, not
  ``E_local * static_cap``) -> int32 metadata all_to_all carrying each
  row's expert assignment (the receive side recovers true per-expert
  counts from it) + payload all_to_all -> the receive side builds a REAL
  ``BlockSchedule`` under ``cfg.schedule_policy`` (any schedule-capable
  executor runs unchanged) -> inverse all_to_all -> weighted combine on
  the source rank.  Dropped assignments are decided by the policy exactly
  as on a single device (global first-come-first-kept slot ranks) and flow
  into the ``sched/*`` aux stats.

``token_layout="sharded_static"`` — the legacy static capacity transport
(every expert gets a tile-aligned ``cap`` bucket in the a2a buffer,
``E_local * cap`` rows per destination regardless of load; assignments
beyond a bucket are silently dropped).  Kept for A/B measurement of the
padding-free path's payload win (benchmarks/serving_throughput.py
--ep-scaling); it ignores ``cfg.schedule_policy`` — which is the historic
bug the padding-free path fixes.

``token_layout="replicated"`` (decode — every EP rank sees the same tokens):
  each rank restricts the plan's routing to the experts it owns (non-owned
  assignments routed to an inactive sentinel expert whose blocks are
  skipped), runs executor permute/expert_ffn/unpermute on that local
  schedule, then a single psum over the EP axis combines partial outputs
  — the collective is O(B*d) instead of an all_to_all of expert buffers.

Drop semantics are the *schedule policy's* under every layout: ``fixed``
and ``dynamic`` drop nothing (the padding-free transport reserves the
worst-case per-destination send capacity so parity with single-device
dispatch is exact); ``capacity_factor`` drops beyond its per-expert bucket
sized over the GLOBAL token count, matching the single-device policy
row-for-row.  ``capacity_factor`` (the argument) resolves as documented in
``apply_moe_ep``; drop counts surface through the same ``sched/*`` aux
keys as single-device dispatch when ``cfg.emit_stats`` is set.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, current_mesh, shard_map
from repro.core.dispatch import MoEDispatchConfig
from repro.execution import (combine_scale_rows, get_executor,
                             plan_dispatch)
from repro.scheduling import (BlockSchedule, ScheduleStats, build_schedule,
                              capacity_slots, expert_capacity,
                              policy_config_kwargs, round_up)

# Padding-free send buffers are lane-aligned to this row multiple (no
# per-expert block_m rounding — that is the whole point).
_SEND_ALIGN = 8


def _resolve_capacity_factor(cfg: MoEDispatchConfig,
                             capacity_factor: Optional[float]) -> float:
    """THE resolution order for EP capacity headroom (asserted by tests):
    an explicit ``capacity_factor`` argument wins; ``None`` falls back to
    ``cfg.capacity_factor`` (which ``dispatch_config`` defaults from the
    model's ``MoEConfig``).  No other source is consulted."""
    return cfg.capacity_factor if capacity_factor is None else capacity_factor


def a2a_send_rows(n_local_tokens: int, top_k: int, n_experts: int, ep: int,
                  block_m: int, capacity_factor: float, policy: str) -> int:
    """Per-destination-rank send-buffer rows of the padding-free path.

    Sized by the POLICY's capacity commitment, not by a per-expert static
    bucket: no-drop policies (fixed/dynamic) reserve the worst case — every
    local assignment routed to one destination — so parity with
    single-device dispatch is exact; ``capacity_factor`` is additionally
    bounded by the destination's post-drop acceptance
    (``E_local * cap_global``).  One rank's total a2a payload is
    ``ep * a2a_send_rows(...)`` rows (compare ``a2a_send_rows_static``).
    """
    F = n_local_tokens * top_k
    C = round_up(max(F, 1), _SEND_ALIGN)
    if policy == "capacity_factor":
        cap_g = expert_capacity(n_local_tokens * ep, top_k, n_experts,
                                block_m, capacity_factor)
        C = min(C, round_up((n_experts // ep) * cap_g, _SEND_ALIGN))
    return C


def a2a_send_rows_static(n_local_tokens: int, top_k: int, n_experts: int,
                         block_m: int, capacity_factor: float) -> int:
    """Total send rows of the legacy static-capacity transport
    (``token_layout='sharded_static'``): every expert gets a tile-aligned
    bucket whether or not any token routed to it."""
    return n_experts * expert_capacity(n_local_tokens, top_k, n_experts,
                                       block_m, capacity_factor)


def _static_schedule(n_rows: int, n_local_experts: int, block_m: int,
                     rows_per_expert: int) -> BlockSchedule:
    """Schedule for the legacy fixed EP receive layout: rows grouped by
    local expert with a static group size (rows_per_expert each).

    The ``rows_per_expert // block_m`` layout math silently misassigns
    ``block_expert`` when handed an unaligned capacity, so misalignment is
    a loud error here — callers must round capacity up to a ``block_m``
    multiple first (``round_up``)."""
    if rows_per_expert % block_m or n_rows % block_m:
        raise ValueError(
            f"static EP receive layout requires block_m-aligned capacity: "
            f"rows_per_expert={rows_per_expert}, n_rows={n_rows}, "
            f"block_m={block_m}; round capacity up with "
            f"scheduling.round_up before building the layout")
    nb = n_rows // block_m
    block_expert = (jnp.arange(nb, dtype=jnp.int32)
                    // (rows_per_expert // block_m))
    return BlockSchedule(
        counts=jnp.full((n_local_experts,), rows_per_expert, jnp.int32),
        group_offsets=jnp.arange(n_local_experts + 1, dtype=jnp.int32)
        * rows_per_expert,
        src_tok=jnp.zeros((n_rows,), jnp.int32),
        pos=jnp.zeros((1, 1), jnp.int32),
        block_expert=block_expert,
        block_active=jnp.ones((nb,), jnp.int32),
        capacity=n_rows, block_m=block_m)


def _rank_plan(params, x_loc, cfg: MoEDispatchConfig, axis: str):
    """Routing plan for this rank's tokens + EP-meaned aux.  One plan per
    batch; all layouts consume it instead of re-deriving routing."""
    plan = plan_dispatch(x_loc, params["router"], cfg, with_schedule=False)
    aux = {k: jax.lax.pmean(v, axis) for k, v in plan.aux.items()}
    return plan._replace(aux=aux)


def _ep_stats(axes, *, kept, dropped, counts_local, sched):
    """EP ScheduleStats under the single-device ``sched/*`` key contract.

    ``kept``/``dropped`` count this rank's SOURCE assignments (each
    assignment is counted on exactly one rank); padding cost comes from the
    rank's receive-side schedule; all totals psum over EVERY token-sharding
    axis (``axes``: the EP axis plus any batch-sharding axes) so each rank
    returns the same replicated global scalars."""
    useful = jax.lax.psum(kept.astype(jnp.int32), axes)
    dropped = jax.lax.psum(dropped.astype(jnp.int32), axes)
    n_active = jax.lax.psum(
        jnp.sum(sched.block_active.astype(jnp.int32)), axes)
    padded = n_active * sched.block_m
    counts_g = jax.lax.psum(counts_local.astype(jnp.int32), axes)
    total = jnp.sum(counts_g)
    f32 = jnp.float32
    safe = lambda a, b: a.astype(f32) / jnp.maximum(b, 1).astype(f32)
    st = ScheduleStats(
        useful_rows=useful, dropped_rows=dropped, padded_rows=padded,
        pad_waste=safe(padded, useful),
        drop_fraction=safe(dropped, useful + dropped),
        top1_share=safe(jnp.max(counts_g), total),
        n_blocks_active=n_active, occupancy=safe(useful, padded))
    return {f"sched/{k}": v for k, v in st._asdict().items()}


def _deactivate_sentinel(sched: BlockSchedule,
                         n_local_experts: int) -> BlockSchedule:
    """Turn the sentinel expert's blocks off so Pallas skips them on TPU
    (and the XLA scan zeroes their rows)."""
    return sched._replace(
        block_active=sched.block_active
        * (sched.block_expert < n_local_experts).astype(jnp.int32),
        block_expert=jnp.minimum(sched.block_expert, n_local_experts - 1))


def _recv_schedule(e_recv, cfg: MoEDispatchConfig, E_local: int,
                   cap_global: Optional[int]) -> BlockSchedule:
    """Receive-side schedule under the configured policy: E_local real
    experts + one sentinel absorbing transport padding rows.  The
    ``capacity_factor`` policy's bucket is pinned to the GLOBAL cap, so the
    send-side drop decisions are final (received counts never exceed it —
    the policy never drops twice)."""
    kw = policy_config_kwargs(cfg.schedule_policy, cfg)
    if cfg.schedule_policy == "capacity_factor":
        kw["cap"] = cap_global
    sched = build_schedule(e_recv[:, None], E_local + 1, cfg.block_m,
                           policy=cfg.schedule_policy, **kw)
    return _deactivate_sentinel(sched, E_local)


# ----------------------------------------------------------------------
# Padding-free sharded path (token_layout="sharded")
# ----------------------------------------------------------------------
def _capacity_keep(flat, gtok, Tl, k, E, ep, cap_global, axis):
    """Exact single-device first-come-first-kept for the capacity policy:
    gather every rank's (expert, global-token-order) assignment keys, rank
    slots in TRUE global token order, read back this rank's verdicts.  The
    gather is O(T*k) int32 — metadata scale, not the payload's.  ``gtok``
    (Tl,) holds each local row's global token id (any values whose order
    matches the unsharded flatten order), making the drop set invariant to
    which dim the tokens were split on."""
    F = Tl * k
    if gtok is None:
        gtok = jax.lax.axis_index(axis) * Tl \
            + jnp.arange(Tl, dtype=jnp.int32)
    gkey = gtok.astype(jnp.int32)[:, None] * k \
        + jnp.arange(k, dtype=jnp.int32)[None, :]            # (Tl, k)
    fa = jax.lax.all_gather(flat, axis).reshape(-1)          # (ep*F,)
    ga = jax.lax.all_gather(gkey.reshape(-1), axis).reshape(-1)
    perm = jnp.argsort(ga)                   # -> single-device token order
    slot_sorted, _ = capacity_slots(fa[perm], E)
    keep_all = jnp.zeros((ep * F,), bool).at[perm].set(
        slot_sorted < cap_global)
    r = jax.lax.axis_index(axis)
    return jax.lax.dynamic_slice_in_dim(keep_all, r * F, F)


def _sharded_send_phase(x_loc, cfg: MoEDispatchConfig, ep: int, plan, keep,
                        cap_global: Optional[int]):
    """Local half of the dispatch: compact this rank's KEPT assignments
    into per-destination send chunks.  Drop/keep was already decided by
    the policy over the FULL batch (``_capacity_keep``), so microbatching
    cannot change the drop set.  Returns (send (ep, C, d), e_send (ep, C)
    int32 local-expert ids with ``E_local`` marking transport padding,
    state dict for compute/combine)."""
    E, k = cfg.n_experts, cfg.top_k
    E_local = E // ep
    Tl, d = x_loc.shape
    F = Tl * k

    flat = plan.indices.reshape(-1).astype(jnp.int32)        # (F,) global e
    _, counts_local = capacity_slots(flat, E)

    # compacted per-destination send chunks: stable slot within the kept
    # rows headed to each destination rank (token-major inside a chunk).
    # C is the policy's transport commitment: worst case for no-drop
    # policies, bounded by the destination's post-drop acceptance for
    # capacity_factor (always using the FULL-batch cap).
    C = round_up(max(F, 1), _SEND_ALIGN)
    if cap_global is not None:
        C = min(C, round_up(E_local * cap_global, _SEND_ALIGN))
    dest_rank = flat // E_local
    dkey = jnp.where(keep, dest_rank, ep)                    # drops -> bin ep
    send_slot, _ = capacity_slots(dkey, ep + 1)
    tkeep = keep & (send_slot < C)     # C covers kept rows by construction
    send_pos = dkey * C + send_slot                          # row in send buf

    src_rows = jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), k)
    oob = jnp.where(tkeep, send_pos, ep * C)
    send = jnp.zeros((ep * C, d), x_loc.dtype).at[oob].set(
        x_loc[src_rows], mode="drop")
    e_send = jnp.full((ep * C,), E_local, jnp.int32).at[oob].set(
        flat % E_local, mode="drop")

    state = dict(plan=plan, tkeep=tkeep, send_pos=send_pos,
                 counts_local=counts_local, cap_global=cap_global,
                 C=C, ep=ep, E_local=E_local, Tl=Tl, k=k, d=d)
    return send.reshape(ep, C, d), e_send.reshape(ep, C), state


def _sharded_compute_phase(recv, e_recv, cfg: MoEDispatchConfig, state):
    """Receive half: build the policy's BlockSchedule over the received
    rows (+ sentinel for transport padding) and run the executor phases."""
    from repro.quantization import expert_weights
    ex = get_executor(cfg.executor)
    d, E_local = state["d"], state["E_local"]
    rows = recv.reshape(-1, d)
    sched = _recv_schedule(e_recv.reshape(-1), cfg, E_local,
                           state["cap_global"])
    local_w = ex.prepare_weights(
        expert_weights(state["params"], rows.dtype), cfg)
    xp = ex.permute(rows, sched, cfg)
    y = ex.expert_ffn(xp, local_w, sched, cfg)
    y_rows = ex.unpermute(y, sched, None, cfg)               # (ep*C, d)
    return y_rows.reshape(state["ep"], state["C"], d), sched


def _sharded_combine_phase(back, cfg: MoEDispatchConfig, state):
    """Source-side weighted combine of the returned expert outputs."""
    ep, C, Tl, k, d = (state["ep"], state["C"], state["Tl"], state["k"],
                       state["d"])
    y = back.reshape(ep * C, d)
    gathered = y[jnp.minimum(state["send_pos"], ep * C - 1)]  # (Tl*k, d)
    w_eff = jnp.where(state["tkeep"],
                      state["plan"].weights.reshape(-1), 0.0)
    out = jnp.sum(gathered.reshape(Tl, k, d).astype(jnp.float32)
                  * w_eff.reshape(Tl, k, 1), axis=1)
    return out


def _a2a(v, axis: str):
    return jax.lax.all_to_all(v, axis, split_axis=0, concat_axis=0,
                              tiled=False)


def _ep_sharded_local(params, x_loc, cfg: MoEDispatchConfig, axis: str,
                      capacity_factor: float, n_micro: int = 1,
                      stat_axes=None, gtok=None):
    """Per-rank body for token_layout='sharded'. x_loc: (T_local, d).

    ``n_micro > 1`` software-pipelines the dispatch: the all_to_all of
    microbatch i+1 is issued BEFORE the expert GEMMs of microbatch i in
    the traced program, so XLA's async collective scheduler can overlap
    transport with compute (X-MoE double buffering).  ``n_micro == 1`` is
    the exact straight-line path — the pipeline degenerates to
    send -> a2a -> compute -> a2a -> combine with no extra ops.  Routing
    and the capacity policy's drop set are decided over the FULL batch
    before chunking, so the overlap path is token-identical to the
    non-overlapped one."""
    ep = axis_size(axis)
    E, k, M = cfg.n_experts, cfg.top_k, cfg.block_m
    if E % ep:
        raise ValueError(f"n_experts={E} must divide over EP axis size {ep}")
    Tl = x_loc.shape[0]
    while Tl % n_micro:
        n_micro -= 1                       # largest divisor <= requested
    c = Tl // n_micro
    chunks = [x_loc[i * c:(i + 1) * c] for i in range(n_micro)]
    plans = [_rank_plan(params, ch, cfg, axis) for ch in chunks]

    cap_global = None
    if cfg.schedule_policy == "capacity_factor":
        cap_global = expert_capacity(Tl * ep, k, E, M, capacity_factor)
        flat_full = jnp.concatenate(
            [p.indices.reshape(-1).astype(jnp.int32) for p in plans])
        keep_full = _capacity_keep(flat_full, gtok, Tl, k, E, ep,
                                   cap_global, axis)
        keeps = [keep_full[i * c * k:(i + 1) * c * k]
                 for i in range(n_micro)]
    else:
        keeps = [jnp.ones((c * k,), bool) for _ in range(n_micro)]

    sends = []
    for i, ch in enumerate(chunks):
        send, e_send, st = _sharded_send_phase(ch, cfg, ep, plans[i],
                                               keeps[i], cap_global)
        st["params"] = params
        sends.append((send, e_send, st))

    outs, auxes = [], []
    recv = (_a2a(sends[0][0], axis), _a2a(sends[0][1], axis))
    for i in range(n_micro):
        nxt = None
        if i + 1 < n_micro:                # issue i+1's a2a before GEMMs i
            nxt = (_a2a(sends[i + 1][0], axis), _a2a(sends[i + 1][1], axis))
        st = sends[i][2]
        y, sched = _sharded_compute_phase(recv[0], recv[1], cfg, st)
        back = _a2a(y, axis)
        outs.append(_sharded_combine_phase(back, cfg, st))
        aux = dict(st["plan"].aux)
        if cfg.emit_stats:
            kept = jnp.sum(st["tkeep"].astype(jnp.int32))
            aux.update(_ep_stats(
                stat_axes or (axis,), kept=kept,
                dropped=jnp.int32(st["tkeep"].shape[0]) - kept,
                counts_local=st["counts_local"], sched=sched))
        auxes.append(aux)
        recv = nxt

    out = jnp.concatenate(outs, axis=0) if n_micro > 1 else outs[0]
    aux = _merge_chunk_aux(auxes)
    return out.astype(x_loc.dtype), aux


def _merge_chunk_aux(auxes):
    """Combine per-microbatch aux: additive stats sum, ratios recompute,
    losses average — one chunk passes through untouched."""
    if len(auxes) == 1:
        return auxes[0]
    n = len(auxes)
    out = {}
    add = ("sched/useful_rows", "sched/dropped_rows", "sched/padded_rows",
           "sched/n_blocks_active")
    for k in auxes[0]:
        if k in add:
            out[k] = sum(a[k] for a in auxes)
        elif k == "sched/top1_share":
            out[k] = jnp.max(jnp.stack([a[k] for a in auxes]))
        elif k.startswith("sched/"):
            continue                        # ratios rebuilt below
        else:
            out[k] = sum(a[k] for a in auxes) / n
    if "sched/useful_rows" in out:
        f32 = jnp.float32
        safe = lambda a, b: a.astype(f32) / jnp.maximum(b, 1).astype(f32)
        u, dr = out["sched/useful_rows"], out["sched/dropped_rows"]
        out["sched/pad_waste"] = safe(out["sched/padded_rows"], u)
        out["sched/drop_fraction"] = safe(dr, u + dr)
        out["sched/occupancy"] = safe(u, out["sched/padded_rows"])
    return out


# ----------------------------------------------------------------------
# Legacy static-capacity transport (token_layout="sharded_static")
# ----------------------------------------------------------------------
def _ep_sharded_static_local(params, x_loc, cfg: MoEDispatchConfig,
                             axis: str, capacity_factor: float,
                             stat_axes=None):
    """The pre-padding-free a2a layout, kept for A/B payload measurement.
    Every expert gets a static tile-aligned ``cap`` bucket; assignments
    beyond it are dropped REGARDLESS of ``cfg.schedule_policy`` (the
    historic policy bypass)."""
    ep = axis_size(axis)
    E, k, M = cfg.n_experts, cfg.top_k, cfg.block_m
    E_local = E // ep
    Tl, d = x_loc.shape

    plan = _rank_plan(params, x_loc, cfg, axis)
    cap = round_up(expert_capacity(Tl, k, E, M, capacity_factor), M)

    flat = plan.indices.reshape(-1)                          # (Tl*k,)
    slot, counts_local = capacity_slots(flat, E)
    keep = slot < cap
    dest = flat * cap + slot                                 # row in send buf

    send = jnp.zeros((E * cap, d), x_loc.dtype)
    src_rows = jnp.repeat(jnp.arange(Tl), k)
    send = send.at[jnp.where(keep, dest, E * cap)].set(
        x_loc[src_rows], mode="drop")

    # (E*cap, d) -> (ep, E_local*cap, d) -> a2a -> rows from every peer
    recv = _a2a(send.reshape(ep, E_local * cap, d), axis)
    # regroup: (ep, E_local, cap, d) -> (E_local, ep*cap, d): contiguous
    # per local expert, group size ep*cap (tile-aligned since cap % M == 0)
    recv = recv.reshape(ep, E_local, cap, d).transpose(1, 0, 2, 3) \
        .reshape(E_local * ep * cap, d)

    from repro.quantization import expert_weights
    ex = get_executor(cfg.executor)
    sched = _static_schedule(E_local * ep * cap, E_local, M, ep * cap)
    local_w = ex.prepare_weights(expert_weights(params, x_loc.dtype), cfg)
    y = ex.expert_ffn(recv, local_w, sched, cfg)

    # inverse path
    y = y.reshape(E_local, ep, cap, d).transpose(1, 0, 2, 3) \
        .reshape(ep, E_local * cap, d)
    y = _a2a(y, axis).reshape(E * cap, d)

    gathered = y[jnp.minimum(dest, E * cap - 1)]             # (Tl*k, d)
    w_eff = jnp.where(keep, plan.weights.reshape(-1), 0.0)
    out = jnp.sum(gathered.reshape(Tl, k, d).astype(jnp.float32)
                  * w_eff.reshape(Tl, k, 1), axis=1)
    aux = dict(plan.aux)
    if cfg.emit_stats:
        kept = jnp.sum(keep.astype(jnp.int32))
        aux.update(_ep_stats(stat_axes or (axis,), kept=kept,
                             dropped=jnp.int32(Tl * k) - kept,
                             counts_local=counts_local, sched=sched))
    return out.astype(x_loc.dtype), aux


# ----------------------------------------------------------------------
# Replicated path (token_layout="replicated")
# ----------------------------------------------------------------------
def _ep_replicated_local(params, x_loc, cfg: MoEDispatchConfig, axis: str,
                         capacity_factor: float, stat_axes=None):
    """Per-rank body for token_layout='replicated' (decode)."""
    ep = axis_size(axis)
    E, M = cfg.n_experts, cfg.block_m
    E_local = E // ep
    r = jax.lax.axis_index(axis)
    base = r * E_local

    plan = _rank_plan(params, x_loc, cfg, axis)

    mine = (plan.indices >= base) & (plan.indices < base + E_local)
    # non-owned assignments -> sentinel expert E_local (blocks deactivated)
    idx_local = jnp.where(mine, plan.indices - base, E_local)
    w_masked = jnp.where(mine, plan.weights, 0.0)

    # the configured schedule policy, over the local experts plus one
    # sentinel "expert" that absorbs non-owned assignments; capacity buckets
    # must be sized over the GLOBAL expert count so EP drop semantics match
    # the single-device policy exactly
    kw = policy_config_kwargs(cfg.schedule_policy, cfg)
    cap = None
    if cfg.schedule_policy == "capacity_factor":
        cap = expert_capacity(x_loc.shape[0], cfg.top_k, E, M,
                              capacity_factor)
        kw["cap"] = cap
    sched = build_schedule(idx_local, E_local + 1, M,
                           policy=cfg.schedule_policy, **kw)
    sched = _deactivate_sentinel(sched, E_local)

    from repro.quantization import expert_weights
    ex = get_executor(cfg.executor)
    xp = ex.permute(x_loc, sched, cfg)
    scale = combine_scale_rows(sched, w_masked)
    local_w = ex.prepare_weights(expert_weights(params, x_loc.dtype), cfg)
    y = ex.expert_ffn(xp, local_w, sched, cfg, row_scale=scale)
    out = ex.unpermute(y, sched, None, cfg)
    out = jax.lax.psum(out.astype(jnp.float32), axis)
    aux = dict(plan.aux)
    if cfg.emit_stats:
        flat_mine = mine.reshape(-1)
        if cap is not None:
            slot, _ = capacity_slots(idx_local.reshape(-1), E_local + 1)
            dropped = jnp.sum((flat_mine & (slot >= cap)).astype(jnp.int32))
        else:
            dropped = jnp.int32(0)
        kept = jnp.sum(flat_mine.astype(jnp.int32)) - dropped
        counts_local = jnp.bincount(
            jnp.where(flat_mine, idx_local.reshape(-1) + base, E),
            length=E + 1)[:E]              # owned-expert counts only
        aux.update(_ep_stats(stat_axes or (axis,), kept=kept,
                             dropped=dropped,
                             counts_local=counts_local, sched=sched))
    return out.astype(x_loc.dtype), aux


# ----------------------------------------------------------------------
def apply_moe_ep(params, x: jnp.ndarray, cfg: MoEDispatchConfig, *,
                 axis: str = "model", capacity_factor: Optional[float] = None,
                 token_layout: str = "sharded", overlap: int = 0):
    """Distributed MoE layer. x: (B, S, d) inside jit (GSPMD context);
    the EP dispatch itself runs under shard_map over `axis`.

    ``capacity_factor`` resolution order (one rule, asserted by tests):
    **explicit argument > cfg.capacity_factor** — ``None`` means "use the
    config", anything else wins outright.  It feeds the
    ``capacity_factor`` schedule policy's drop buckets and the legacy
    ``sharded_static`` transport; the padding-free sharded path needs no
    separate headroom knob (its transport is sized by the policy's own
    capacity, see ``a2a_send_rows``).

    ``cfg.schedule_policy`` is honored by EVERY layout except the legacy
    ``sharded_static`` transport (kept only for payload A/B measurement):
    the sharded path builds the policy's ``BlockSchedule`` on the receive
    side of the all_to_all, the replicated path over its owned experts.
    Drop decisions match single-device dispatch row-for-row.

    ``overlap`` (sharded layout only): number of dispatch microbatches to
    software-pipeline — expert GEMMs of microbatch i overlap the
    all_to_all of i+1.  ``0``/``1`` = the straight-line path.

    The sharded layout splits tokens over ``axis`` on the sequence dim
    when ``S`` divides, else the batch dim (decode slots), else falls
    back to the replicated layout (always correct — tokens just aren't
    split).

    ``cfg.executor`` must name a schedule-capable backend (phase-level
    permute/expert_ffn/unpermute) — ``xla`` or ``pallas``; the ``dense``
    oracle has no permuted layout and raises under EP.

    Shared experts are dense compute on (sharded) tokens — they stay in
    plain GSPMD-land outside the shard_map.
    """
    capacity_factor = _resolve_capacity_factor(cfg, capacity_factor)
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        raise RuntimeError("apply_moe_ep requires an active mesh "
                           "(jax.set_mesh(...) or `with mesh:`)")
    if token_layout not in ("sharded", "sharded_static", "replicated"):
        raise ValueError(f"unknown token_layout {token_layout!r}")
    shape = x.shape
    d = shape[-1]
    other = [a for a in mesh.axis_names if a != axis]
    ep = mesh.shape[axis]
    bspec = tuple(other) if shape[0] % max(_axsize(mesh, other), 1) == 0 \
        else None
    # stats totals must span every axis tokens are split over: the EP axis
    # plus the batch-sharding axes (aux out_specs claim full replication)
    stat_axes = (tuple(bspec) if bspec else ()) + (axis,)

    if token_layout in ("sharded", "sharded_static") \
            and shape[1] % ep and shape[0] % ep:
        token_layout = "replicated"        # nothing divides: don't split

    if token_layout in ("sharded", "sharded_static"):
        seq_sharded = shape[1] % ep == 0
        if seq_sharded:
            in_spec = P(bspec, axis, None)     # seq-sharded (train/prefill)
        else:
            in_spec = P(axis, None, None)      # batch-sharded (decode slots)
        out_spec = in_spec

        def body(p_loc, x_loc):
            B_l, S_l, _ = x_loc.shape
            # global token ids in the unsharded (b, s) flatten order, so
            # policy drop decisions are sharding-invariant
            r = jax.lax.axis_index(axis)
            idx = jnp.arange(B_l * S_l, dtype=jnp.int32)
            if seq_sharded:
                gtok = (idx // S_l) * (S_l * ep) + r * S_l + idx % S_l
            else:
                gtok = r * (B_l * S_l) + idx
            if token_layout == "sharded":
                y, aux = _ep_sharded_local(p_loc, x_loc.reshape(-1, d), cfg,
                                           axis, capacity_factor,
                                           max(1, overlap),
                                           stat_axes=stat_axes, gtok=gtok)
            else:
                y, aux = _ep_sharded_static_local(
                    p_loc, x_loc.reshape(-1, d), cfg, axis, capacity_factor,
                    stat_axes=stat_axes)
            return y.reshape(B_l, S_l, d), aux
    else:
        in_spec = P(bspec, None, None)
        out_spec = P(bspec, None, None)

        def body(p_loc, x_loc):
            B_l, S_l, _ = x_loc.shape
            y, aux = _ep_replicated_local(p_loc, x_loc.reshape(-1, d), cfg,
                                          axis, capacity_factor,
                                          stat_axes=stat_axes)
            return y.reshape(B_l, S_l, d), aux

    from repro.execution import get_executor as _get_ex
    from repro.quantization import params_scheme
    scheme = params_scheme(params)
    if not _get_ex(cfg.executor).supports_scheme(scheme):
        raise ValueError(
            f"executor {cfg.executor!r} does not support quant scheme "
            f"{scheme!r} under EP")

    routed = {k_: v for k_, v in params.items() if k_ != "shared"}
    # expert tensors shard over the EP axis on their leading (expert)
    # axis.  Built per LEAF so quantized params work for ANY scheme: a
    # QuantTensor contributes its compressed payload + scale leaves (both
    # expert-leading), and each rank gathers only compressed bytes.
    pspecs = {k_: (P(None, None) if k_ == "router"
                   else jax.tree.map(
                       lambda l: P(axis, *([None] * (l.ndim - 1))), v))
              for k_, v in routed.items()}
    aux_spec = {"lb_loss": P(), "router_z": P()}
    if cfg.emit_stats:
        aux_spec.update({f"sched/{k}": P() for k in ScheduleStats._fields})
    y, aux = shard_map(
        body, mesh=mesh, in_specs=(pspecs, in_spec),
        out_specs=(out_spec, aux_spec))(routed, x)

    if "shared" in params:
        sh = params["shared"]
        xf = x.astype(jnp.float32)
        g = jnp.dot(xf, sh["w_gate"].astype(jnp.float32))
        u = jnp.dot(xf, sh["w_up"].astype(jnp.float32))
        y = y + jnp.dot((g * jax.nn.sigmoid(g)) * u,
                        sh["w_down"].astype(jnp.float32)).astype(y.dtype)
    return y, aux


def _axsize(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _current_mesh():
    """Concrete mesh from set_mesh(...) or a `with mesh:` block."""
    return current_mesh()
