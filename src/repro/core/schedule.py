"""Back-compat shim: schedule construction moved to ``repro.scheduling``.

``build_schedule(indices, E, M)`` keeps its historical fixed-policy
behavior; pass ``policy="capacity_factor"`` / ``policy="dynamic"`` (or set
``MoEDispatchConfig.schedule_policy``) for the adaptive layouts.  See
scheduling/base.py and DESIGN.md §3.
"""
from repro.scheduling import (BlockSchedule, ScheduleStats,  # noqa: F401
                              available_policies, build_schedule,
                              round_up, schedule_capacity, schedule_stats)
