"""Single-device MoE dispatch pipeline (the paper's end-to-end §3.1).

    router logits -> gating/top-k -> schedule -> permute
      -> fused gate+up grouped GEMM -> down grouped GEMM (fused combine scale)
      -> unpermute

Three interchangeable implementations of the grouped compute:

* ``impl="pallas"``  — the paper's technique as Pallas TPU kernels
  (kernels/).  Runs in interpret mode off-TPU.  Inference-path (forward).
* ``impl="xla"``     — the SAME block schedule executed as a
  ``lax.scan`` over M-tiles with per-step expert-weight gathers.  Pure
  jnp: differentiable (training path), memory-lean (no (blocks, K, N)
  weight gather blow-up), compiles at full scale on any backend — this is
  what the multi-pod dry-run lowers.  Structurally identical traffic to
  the kernel, so its roofline terms are representative.
* ``impl="dense"``   — one-hot dense-over-all-experts oracle (the paper's
  "PyTorch reference" baseline; used by tests and small benchmarks).

``fuse_gate_up=False`` reproduces the paper's unfused ablation arm
(Table 4b): two separate grouped GEMMs whose outputs round-trip HBM.
``fold_combine=True`` applies the top-k combine weights inside the down
projection's epilogue instead of at unpermute (beyond-paper; see DESIGN.md).

``schedule_policy`` selects how the block schedule is constructed
(repro.scheduling; DESIGN.md §3): ``fixed`` (the paper), ``capacity_factor``
(bounded buckets + overflow drops), or ``dynamic`` (adaptive block-to-expert
assignment under skew — the serving default).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.kernels import ops, ref
from repro.scheduling import BlockSchedule, build_schedule, schedule_stats


class MoEDispatchConfig(NamedTuple):
    n_experts: int
    top_k: int
    block_m: int = 128
    impl: str = "xla"              # pallas | xla | dense
    fuse_gate_up: bool = True
    fold_combine: bool = True
    gating: str = "softmax"
    norm_topk: bool = False
    routed_scale: float = 1.0
    interpret: Optional[bool] = None
    schedule_policy: str = "fixed"   # fixed | capacity_factor | dynamic
    capacity_factor: float = 2.0     # capacity_factor policy: bucket headroom
    block_m_min: int = 8             # dynamic policy: sub-block granularity
    emit_stats: bool = False         # add ScheduleStats scalars to aux (off in
                                     # the layer scan: aux is a fixed carry)


def schedule_kwargs(cfg: MoEDispatchConfig) -> dict:
    """Per-policy construction kwargs from the dispatch config."""
    if cfg.schedule_policy == "capacity_factor":
        return {"capacity_factor": cfg.capacity_factor}
    if cfg.schedule_policy == "dynamic":
        return {"block_m_min": cfg.block_m_min}
    return {}


def build_dispatch_schedule(indices: jnp.ndarray,
                            cfg: MoEDispatchConfig) -> BlockSchedule:
    """The configured policy's schedule for this batch's routing."""
    return build_schedule(indices, cfg.n_experts, cfg.block_m,
                          policy=cfg.schedule_policy, **schedule_kwargs(cfg))


# ----------------------------------------------------------------------
# XLA scan-over-blocks grouped compute (differentiable)
# ----------------------------------------------------------------------
def _gemm_blocks_xla(x: jnp.ndarray, sched: BlockSchedule, step_fn):
    M = sched.block_m
    nb = sched.capacity // M
    xb = x.reshape(nb, M, x.shape[-1])

    def step(_, inp):
        xblk, be, active = inp
        out = step_fn(xblk, be)
        out = out * active.astype(out.dtype)
        return None, out

    _, out = jax.lax.scan(step, None,
                          (xb, sched.block_expert, sched.block_active))
    return out.reshape(sched.capacity, -1)


def fused_gate_up_xla(x, w_gate, w_up, sched: BlockSchedule):
    def step(xblk, be):
        wg = w_gate[be]
        wu = w_up[be]
        g = jnp.dot(xblk, wg, preferred_element_type=jnp.float32)
        u = jnp.dot(xblk, wu, preferred_element_type=jnp.float32)
        return ((g * jax.nn.sigmoid(g)) * u).astype(x.dtype)
    return _gemm_blocks_xla(x, sched, step)


def grouped_gemm_xla(x, w, sched: BlockSchedule, row_scale=None):
    out = _gemm_blocks_xla(
        x, sched,
        lambda xblk, be: jnp.dot(xblk, w[be],
                                 preferred_element_type=jnp.float32
                                 ).astype(x.dtype))
    if row_scale is not None:
        out = out * row_scale[:, None].astype(out.dtype)
    return out


# ----------------------------------------------------------------------
def route(x: jnp.ndarray, w_router: jnp.ndarray, cfg: MoEDispatchConfig):
    """Router projection (XLA — near-optimal small-N GEMM, as in the paper)
    + fused gating/top-k. Returns (weights, indices, probs-for-aux)."""
    logits = jnp.dot(x.astype(jnp.float32), w_router.astype(jnp.float32))
    if cfg.impl == "pallas":
        weights, indices = ops.router_topk(
            logits, top_k=cfg.top_k, gating=cfg.gating,
            norm_topk=cfg.norm_topk, routed_scale=cfg.routed_scale,
            interpret=cfg.interpret)
    else:
        weights, indices = ref.router_ref(
            logits, cfg.top_k, gating=cfg.gating,
            norm_topk=cfg.norm_topk, routed_scale=cfg.routed_scale)
    return weights, indices, logits


def combine_scale_rows(sched: BlockSchedule, weights: jnp.ndarray):
    """Scatter the (T, k) combine weights onto padded rows for the fused
    down-projection epilogue. Padding rows get 0."""
    scale = jnp.zeros((sched.capacity,), jnp.float32)
    return scale.at[sched.pos.reshape(-1)].set(
        weights.reshape(-1).astype(jnp.float32), mode="drop")


def moe_ffn(x: jnp.ndarray, w_router: jnp.ndarray, w_gate: jnp.ndarray,
            w_up: jnp.ndarray, w_down: jnp.ndarray,
            cfg: MoEDispatchConfig):
    """Full dispatch pipeline.  x: (T, d) -> (y: (T, d), aux dict)."""
    weights, indices, logits = route(x, w_router, cfg)
    aux = _aux_losses(logits, indices, cfg)

    if cfg.impl == "dense":
        y = ref.moe_ffn_dense_ref(x, w_gate, w_up, w_down, weights, indices)
        return y, aux

    sched = build_dispatch_schedule(indices, cfg)
    if cfg.emit_stats:
        aux.update({f"sched/{k}": v
                    for k, v in schedule_stats(sched)._asdict().items()})

    if cfg.impl == "pallas":
        xp = ops.permute(x, sched, interpret=cfg.interpret)
        xp = constrain("moe_dispatch", xp)
        if cfg.fuse_gate_up:
            h = ops.fused_gate_up(xp, w_gate, w_up, sched,
                                  interpret=cfg.interpret)
        else:
            g = ops.grouped_gemm(xp, w_gate, sched, interpret=cfg.interpret)
            u = ops.grouped_gemm(xp, w_up, sched, interpret=cfg.interpret)
            gf = g.astype(jnp.float32)
            h = ((gf * jax.nn.sigmoid(gf)) * u.astype(jnp.float32)
                 ).astype(x.dtype)
        scale = combine_scale_rows(sched, weights) if cfg.fold_combine else None
        y = ops.grouped_gemm(h, w_down, sched, row_scale=scale,
                             interpret=cfg.interpret)
        y = ops.unpermute(y, sched, None if cfg.fold_combine else weights,
                          interpret=cfg.interpret)
    elif cfg.impl == "xla":
        xp = constrain("moe_dispatch", ref.permute_ref(x, sched))
        if cfg.fuse_gate_up:
            h = fused_gate_up_xla(xp, w_gate, w_up, sched)
        else:
            g = grouped_gemm_xla(xp, w_gate, sched)
            u = grouped_gemm_xla(xp, w_up, sched)
            gf = g.astype(jnp.float32)
            h = ((gf * jax.nn.sigmoid(gf)) * u.astype(jnp.float32)
                 ).astype(x.dtype)
        scale = combine_scale_rows(sched, weights) if cfg.fold_combine else None
        y = grouped_gemm_xla(h, w_down, sched, row_scale=scale)
        y = ref.unpermute_ref(y, sched, None if cfg.fold_combine else weights)
    else:
        raise ValueError(f"unknown impl {cfg.impl!r}")
    return y.astype(x.dtype), aux


def _aux_losses(logits: jnp.ndarray, indices: jnp.ndarray,
                cfg: MoEDispatchConfig):
    """Load-balance + router-z losses (training substrate; the paper is
    inference-only so these sit outside its measured pipeline)."""
    probs = jax.nn.softmax(logits, axis=-1)
    E = cfg.n_experts
    frac = jnp.mean(
        jax.nn.one_hot(indices, E, dtype=jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=0)
    lb = E * jnp.sum(frac * mean_prob)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return {"lb_loss": lb, "router_z": z}
