"""Single-device MoE dispatch (the paper's end-to-end §3.1) — thin shim.

    router logits -> gating/top-k -> schedule -> permute
      -> fused gate+up grouped GEMM -> down grouped GEMM (fused combine scale)
      -> unpermute

The pipeline is split into two phases (DESIGN.md §6; repro.execution):
`plan_dispatch` builds a backend-independent `DispatchPlan` (routing,
`BlockSchedule`, combine-scale rows, aux/telemetry) once per batch, and a
registered `Executor` backend runs it.  Three executors ship built-in:

* ``executor="pallas"`` — the paper's technique as Pallas TPU kernels
  (kernels/).  Runs in interpret mode off-TPU.  Inference-path (forward).
* ``executor="xla"``    — the SAME block schedule executed as a
  ``lax.scan`` over M-tiles with per-step expert-weight gathers.  Pure
  jnp: differentiable (training path), memory-lean, compiles at full scale
  on any backend — this is what the multi-pod dry-run lowers.
* ``executor="dense"``  — one-hot dense-over-all-experts oracle (the
  paper's "PyTorch reference" baseline; used by tests and benchmarks).

``fuse_gate_up=False`` reproduces the paper's unfused ablation arm
(Table 4b); ``fold_combine=True`` applies the top-k combine weights inside
the down projection's epilogue (beyond-paper; DESIGN.md §2).
``schedule_policy`` selects how the block schedule is constructed
(repro.scheduling; DESIGN.md §3) — backend, schedule policy, and
distribution layout (core/distributed.py) compose orthogonally.

This module keeps the historical `moe_ffn` entry point and re-exports the
helpers older call sites import from here.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.execution import (DispatchPlan, combine_scale_rows,  # noqa: F401
                             execute, get_executor, plan_dispatch,
                             plan_schedule, router_aux_losses)
from repro.execution import fused_gate_up_xla, grouped_gemm_xla  # noqa: F401
from repro.scheduling import BlockSchedule, policy_config_kwargs

# historical private name, still imported by older call sites
_aux_losses = router_aux_losses


class MoEDispatchConfig(NamedTuple):
    n_experts: int
    top_k: int
    block_m: int = 128
    executor: str = "xla"          # any repro.execution registered backend
    fuse_gate_up: bool = True
    fold_combine: bool = True
    gating: str = "softmax"
    norm_topk: bool = False
    routed_scale: float = 1.0
    interpret: Optional[bool] = None
    schedule_policy: str = "fixed"   # any repro.scheduling registered policy
    capacity_factor: float = 2.0     # capacity_factor policy: bucket headroom
    block_m_min: int = 8             # dynamic policy: sub-block granularity
    emit_stats: bool = False         # add ScheduleStats scalars to aux (needs
                                     # RunConfig.moe_stats in the layer scan:
                                     # aux is a fixed carry)
    autotune: bool = False           # pallas executor: consult the
                                     # persistent kernel tune cache
                                     # (repro.tuning) at trace time

    @property
    def impl(self) -> str:
        """Deprecated alias for ``executor`` (pre-registry field name)."""
        import warnings
        warnings.warn("MoEDispatchConfig.impl is deprecated; read "
                      ".executor (the registry field name)",
                      DeprecationWarning, stacklevel=2)
        return self.executor


def schedule_kwargs(cfg: MoEDispatchConfig) -> dict:
    """Per-policy construction kwargs — each policy declares the config
    fields it consumes (scheduling/base.py); kept for older call sites."""
    return policy_config_kwargs(cfg.schedule_policy, cfg)


def build_dispatch_schedule(indices: jnp.ndarray,
                            cfg: MoEDispatchConfig) -> BlockSchedule:
    """The configured policy's schedule for this batch's routing."""
    return plan_schedule(indices, cfg)


def route(x: jnp.ndarray, w_router: jnp.ndarray, cfg: MoEDispatchConfig):
    """Router projection (XLA — near-optimal small-N GEMM, as in the paper)
    + the executor's gating/top-k. Returns (weights, indices, probs-for-aux)."""
    logits = jnp.dot(x.astype(jnp.float32), w_router.astype(jnp.float32))
    weights, indices = get_executor(cfg.executor).route(logits, cfg)
    return weights, indices, logits


def moe_ffn(x: jnp.ndarray, w_router: jnp.ndarray, w_gate: jnp.ndarray,
            w_up: jnp.ndarray, w_down: jnp.ndarray,
            cfg: MoEDispatchConfig):
    """Full dispatch pipeline.  x: (T, d) -> (y: (T, d), aux dict).

    Equivalent to ``plan_dispatch`` + ``execute`` on ``cfg.executor``; kept
    as the one-call entry point every model-level consumer uses."""
    plan = plan_dispatch(x, w_router, cfg)
    y = execute(plan, x, {"w_gate": w_gate, "w_up": w_up, "w_down": w_down},
                cfg)
    return y.astype(x.dtype), plan.aux
