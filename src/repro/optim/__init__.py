"""repro.optim subpackage."""
