"""Gradient compression for the cross-pod reduction (beyond-paper,
distributed-optimization trick).

int8 symmetric quantization with ERROR FEEDBACK: the quantization residual
is carried into the next step's gradient so the compressed reduction is
unbiased over time (Seide et al. / 1-bit-SGD lineage).  The cross-pod
all-reduce then moves 1/4 of the fp32 bytes (per-tensor fp32 scale + int8
payload); tests assert convergence matches uncompressed within tolerance.

``compressed_psum``: shard_map-side helper — quantize, all_gather int8 over
the pod axis, dequantize + sum locally (g-1 extra copies of int8 instead of
fp32: link bytes ~/4)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, error_state):
    """-> (quantized tree of (q, scale), new_error_state).
    error_state is a pytree like grads (fp32 residuals)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize(gf)
        deq = dequantize(q, s)
        return (q, s), gf - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([p[0] for p in pairs]),
            tdef.unflatten([p[1] for p in pairs]))


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(g: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Inside shard_map: int8 all_gather over `axis`, dequant + sum."""
    q, s = quantize(g.astype(jnp.float32))
    qs = jax.lax.all_gather(q, axis)                 # (g, ...) int8
    ss = jax.lax.all_gather(s, axis)                 # (g,) f32
    return jnp.tensordot(ss, qs.astype(jnp.float32), axes=(0, 0))
