"""AdamW with decoupled weight decay, global-norm clipping, and a
warmup+cosine schedule — pure JAX (no optax in this environment).

Moments are kept in fp32 regardless of parameter dtype (bf16 master params
at scale; see DESIGN.md).  The update is a pure pytree function, so GSPMD
shards optimizer compute exactly like the parameters (ZeRO: moments inherit
the FSDP layout via opt_state_specs)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptConfig(NamedTuple):
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def schedule(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(step.astype(jnp.float32), cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mh = m_new / c1
        vh = v_new / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
