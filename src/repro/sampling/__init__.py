"""Seeded sampling registry (DESIGN.md §13): logits processors + keyed
per-row device draws for the serving step."""
from repro.sampling.base import (ROLE_ACCEPT, ROLE_DRAFT, ROLE_RESIDUAL,
                                 ROLE_SAMPLE, SamplingConfig,
                                 available_samplers, get_sampler,
                                 process_logits, register_sampler, row_key,
                                 sample_rows, uniform_rows)

__all__ = [
    "SamplingConfig", "register_sampler", "get_sampler",
    "available_samplers", "process_logits", "sample_rows", "uniform_rows",
    "row_key", "ROLE_SAMPLE", "ROLE_DRAFT", "ROLE_ACCEPT", "ROLE_RESIDUAL",
]
