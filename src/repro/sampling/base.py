"""Seeded sampling subsystem: logits processors + per-row device draws.

Lifts the serve path's greedy-only restriction (DESIGN.md §13).  Mirrors
the schedule-policy / executor / quant / admission registries: a *sampler*
is a logits processor ``fn(logits, cfg) -> processed logits`` registered
under a name the engine/launcher select by flag —

* ``greedy``      — identity; the engine keeps the EXACT pre-sampling
                    ``argmax`` path (decided at trace time), so greedy
                    tokens stay bitwise-identical to every prior PR.
* ``temperature`` — logits / T.
* ``top_k``       — temperature scale, then all but the k largest logits
                    masked to -inf.
* ``top_p``       — temperature scale, then nucleus masking: the smallest
                    set of tokens whose cumulative probability reaches p
                    (the top-1 token is always kept).

**Determinism.**  Stochastic draws are keyed, not stateful: the key for
the draw that produces a request's output token ``i`` is

    fold_in(fold_in(PRNGKey(seed), i), role)

— a pure function of (per-request seed, output index, role).  Batched
vs. unbatched runs, slot permutations, and preempt-resume replays
therefore produce identical tokens *by construction* (no RNG state to
keep in sync), which tests/test_sampling.py asserts against a per-request
oracle.  ``role`` separates the independent streams one output index can
consume (target sample / draft proposal / accept-u / residual resample —
the speculative-decoding verify math, serve/step.py).

Everything here runs INSIDE the jitted serving step on (T, V) row
batches: per-row categorical draws keep the engine's one-host-sync-per-
step invariant.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

# key roles: the independent per-output-index draw streams
ROLE_SAMPLE = 0        # target-distribution sample (also the spec bonus)
ROLE_DRAFT = 1         # draft-model proposal (speculative decoding)
ROLE_ACCEPT = 2        # rejection-sampling accept uniform
ROLE_RESIDUAL = 3      # rejection-sampling residual resample


class SamplingConfig(NamedTuple):
    """Per-engine sampling configuration (per-request seeds ride on
    ``Request.seed``; ``seed`` here is the engine-level base from which
    seedless requests derive theirs)."""
    method: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0                 # 0 = no top-k truncation
    top_p: float = 1.0             # 1.0 = no nucleus truncation
    seed: int = 0


Sampler = Callable[[jnp.ndarray, SamplingConfig], jnp.ndarray]

_SAMPLERS: Dict[str, Sampler] = {}


def register_sampler(name: str):
    def deco(fn: Sampler) -> Sampler:
        _SAMPLERS[name] = fn
        return fn
    return deco


def get_sampler(name: str) -> Sampler:
    if name not in _SAMPLERS:
        raise ValueError(f"unknown sampling method {name!r}; "
                         f"registered: {sorted(_SAMPLERS)}")
    return _SAMPLERS[name]


def available_samplers():
    return sorted(_SAMPLERS)


# ----------------------------------------------------------------------
# Processors
# ----------------------------------------------------------------------
def _scale(logits: jnp.ndarray, cfg: SamplingConfig) -> jnp.ndarray:
    t = max(float(cfg.temperature), 1e-6)
    return logits if t == 1.0 else logits / t


@register_sampler("greedy")
def greedy(logits: jnp.ndarray, cfg: SamplingConfig) -> jnp.ndarray:
    return logits


@register_sampler("temperature")
def temperature(logits: jnp.ndarray, cfg: SamplingConfig) -> jnp.ndarray:
    return _scale(logits, cfg)


@register_sampler("top_k")
def top_k(logits: jnp.ndarray, cfg: SamplingConfig) -> jnp.ndarray:
    logits = _scale(logits, cfg)
    k = int(cfg.top_k)
    if k <= 0 or k >= logits.shape[-1]:
        return logits
    kth = jnp.sort(logits, axis=-1)[..., -k][..., None]
    return jnp.where(logits < kth, -jnp.inf, logits)


@register_sampler("top_p")
def top_p(logits: jnp.ndarray, cfg: SamplingConfig) -> jnp.ndarray:
    logits = _scale(logits, cfg)
    p = float(cfg.top_p)
    if p >= 1.0:
        return logits
    srt = jnp.sort(logits, axis=-1)[..., ::-1]            # descending
    probs = jax.nn.softmax(srt, axis=-1)
    # exclusive cumulative mass: a token is kept while the mass BEFORE it
    # is < p, so the top-1 token is always kept and the kept set is the
    # smallest one reaching p
    cum = jnp.cumsum(probs, axis=-1) - probs
    thr = jnp.min(jnp.where(cum < p, srt, jnp.inf), axis=-1, keepdims=True)
    return jnp.where(logits < thr, -jnp.inf, logits)


def process_logits(logits: jnp.ndarray, cfg: SamplingConfig) -> jnp.ndarray:
    """The configured method's processed logits (greedy: unchanged)."""
    return get_sampler(cfg.method)(logits, cfg)


# ----------------------------------------------------------------------
# Keyed per-row draws (device-side; no host sync)
# ----------------------------------------------------------------------
def row_key(seed, counter, role: int):
    """The draw key for one request's output index ``counter`` under
    ``role`` — a pure function of its arguments (see module docstring)."""
    k = jax.random.PRNGKey(seed)
    k = jax.random.fold_in(k, counter)
    return jax.random.fold_in(k, role)


def sample_rows(logits: jnp.ndarray, cfg: SamplingConfig,
                seeds: jnp.ndarray, counters: jnp.ndarray,
                role: int = ROLE_SAMPLE) -> jnp.ndarray:
    """One token per row of ``logits`` (T, V).  Greedy is EXACT argmax
    (the pre-sampling path, chosen at trace time — ``seeds``/``counters``
    are never touched); every other method draws a categorical from the
    processed logits under the row's (seed, counter, role) key."""
    if cfg.method == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    proc = process_logits(logits, cfg)
    draw = jax.vmap(
        lambda lg, s, c: jax.random.categorical(row_key(s, c, role), lg))
    return draw(proc, seeds, counters).astype(jnp.int32)


def uniform_rows(seeds: jnp.ndarray, counters: jnp.ndarray, k: int,
                 role: int = ROLE_ACCEPT) -> jnp.ndarray:
    """(T, k) uniforms: column i of row t uses key (seeds[t],
    counters[t] + i, role) — the accept-u stream of speculative
    verification, aligned with the output index each column decides."""
    def one(s, c):
        return jax.vmap(
            lambda i: jax.random.uniform(row_key(s, c + i, role)))(
                jnp.arange(k))
    return jax.vmap(one)(seeds, counters)
