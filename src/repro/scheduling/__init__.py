"""Pluggable schedule-construction policies for MoE dispatch.

See scheduling/base.py for the policy contract and registry;
DESIGN.md §3 for the design.  Importing this package registers the three
built-in policies: ``fixed``, ``capacity_factor``, ``dynamic``.
"""
from repro.scheduling.base import (DEFAULT_POLICY_SWEEP,  # noqa: F401
                                   BlockSchedule, ScheduleStats,
                                   available_policies, build_schedule,
                                   get_policy, policy_config_kwargs,
                                   register_policy, round_up,
                                   schedule_stats)
from repro.scheduling.capacity import (build_capacity_schedule,  # noqa: F401
                                       capacity_slots, expert_capacity)
from repro.scheduling.dynamic import (build_dynamic_schedule,  # noqa: F401
                                      sub_block)
from repro.scheduling.fixed import (build_fixed_schedule,  # noqa: F401
                                    schedule_capacity)
