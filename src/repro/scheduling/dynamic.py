"""``dynamic`` policy: adaptive block-to-expert assignment under routing skew.

The paper names this as future work: its fixed-``BLOCK_M`` layout
underperforms Megablocks' block-sparse layout at 64+ experts under extreme
Zipfian skew, because every light expert pads its partial tile up to a full
``block_m`` rows.  This policy removes that waste while keeping every
``BlockSchedule`` invariant the kernels rely on:

1. **Adaptive per-expert block sizing.**  The physical grid runs on
   sub-blocks of ``q = largest divisor of block_m <= block_m_min`` rows
   (q >= 8 keeps the f32 sublane tiling).  Each expert's segment is padded
   to an adaptively selected alignment — full ``block_m`` tiles for *heavy*
   experts (counts >= block_m, where MXU-shaped tiles matter), ``q`` rows
   for *light* ones — so per-expert padding is

       heavy: round_up(c, block_m)   (identical to ``fixed``)
       light: round_up(c, q)         (<= fixed's round_up(c, block_m))

   and total padded rows are <= the ``fixed`` policy's on EVERY assignment,
   strictly lower whenever any light expert has a partial tile (the Zipf
   regime: asserted in tests/test_scheduling_policies.py).

2. **Greedy bin-packing of expert segments.**  Segments are laid out in
   decreasing-load order (first-fit-decreasing on the block line).  All
   heavy segments therefore come first and — being block_m-multiples
   summed — start M-aligned, preserving the paper's full-tile property
   exactly where the FLOPs are; the light tail packs many small q-aligned
   segments into what ``fixed`` would spend on per-expert padding tiles,
   i.e. light experts share padding.  Heavy experts own proportionally more
   of the (now finer) block list: blocks-per-expert = padded_c / q.

Everything is jnp on-device (argsort / cumsum / searchsorted) — no host
round-trip, so the TPU no-host-sync property of the fixed policy is
preserved; the capacity envelope reuses fixed's static worst case, so jit
shapes are load-independent.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.scheduling.base import BlockSchedule, register_policy
from repro.scheduling.fixed import schedule_capacity


def sub_block(block_m: int, block_m_min: int = 8) -> int:
    """Largest divisor of block_m that is <= block_m_min AND keeps the
    8-row f32 sublane alignment (the Pallas kernels run on these blocks).
    When no such divisor exists (block_m not a multiple of 8), returns
    block_m itself: dynamic degrades to fixed alignment rather than ever
    emitting a TPU-misaligned tile.  block_m_min below 8 is clamped up to
    8 — never silently disable sub-tiling because the floor was small."""
    for q in range(max(min(block_m_min, block_m), 8), 7, -1):
        if block_m % q == 0 and q % 8 == 0:
            return q
    return block_m


@register_policy("dynamic", config_fields=("block_m_min",))
def build_dynamic_schedule(indices: jnp.ndarray, n_experts: int,
                           block_m: int, *,
                           block_m_min: int = 8) -> BlockSchedule:
    T, k = indices.shape
    E, M = n_experts, block_m
    q = sub_block(M, block_m_min)
    capacity = schedule_capacity(T, k, E, M)   # fixed policy's static envelope
    num_blocks = capacity // q

    flat = indices.reshape(-1).astype(jnp.int32)
    sort_idx = jnp.argsort(flat, stable=True)
    counts = jnp.bincount(flat, length=E).astype(jnp.int32)

    # (1) adaptive per-expert alignment: M-tiles where compute is dense,
    # q-sub-blocks where fixed would mostly pad
    heavy = counts >= M
    padded_counts = jnp.where(heavy,
                              (counts + M - 1) // M * M,
                              (counts + q - 1) // q * q).astype(jnp.int32)

    # (2) greedy decreasing packing: heavy experts first (M-aligned bases),
    # light experts share the q-granular tail
    order = jnp.argsort(-counts, stable=True).astype(jnp.int32)
    padded_ord = padded_counts[order]
    ends_ord = jnp.cumsum(padded_ord).astype(jnp.int32)
    starts_ord = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), ends_ord]).astype(jnp.int32)
    seg_start = jnp.zeros((E,), jnp.int32).at[order].set(starts_ord[:-1])

    unpadded_starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)]).astype(jnp.int32)
    ranks = jnp.arange(T * k, dtype=jnp.int32)
    expert_sorted = flat[sort_idx]
    dest = (seg_start[expert_sorted]
            + ranks - unpadded_starts[expert_sorted])

    pos = jnp.zeros((T * k,), jnp.int32).at[sort_idx].set(dest).reshape(T, k)
    src_tok = jnp.full((capacity,), -1, jnp.int32).at[dest].set(
        sort_idx // k, mode="drop")

    block_starts = jnp.arange(num_blocks, dtype=jnp.int32) * q
    pos_in_order = jnp.searchsorted(ends_ord, block_starts, side="right")
    block_expert = order[jnp.minimum(pos_in_order, E - 1)]
    total_padded = ends_ord[-1] if E > 0 else jnp.int32(0)
    block_active = (block_starts < total_padded).astype(jnp.int32)

    return BlockSchedule(
        counts=counts,
        group_offsets=starts_ord,      # packing order; per-expert: seg_start
        src_tok=src_tok,
        pos=pos,
        block_expert=block_expert,
        block_active=block_active,
        capacity=capacity,
        block_m=q,
        seg_start=seg_start,
    )
