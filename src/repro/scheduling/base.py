"""Schedule-policy layer: types, telemetry, and the policy registry.

The paper builds ONE fixed-tile block schedule (Algorithm 1).  Production
traffic is not uniform — under Zipfian routing skew the fixed-``block_m``
layout pads hard (paper §4.7, our skew_sensitivity benchmark) — so schedule
construction is a *pluggable policy*:

* ``fixed``            — the paper's tile-aligned layout (scheduling/fixed.py)
* ``capacity_factor``  — bounded per-expert capacity, GShard-style overflow
                         drops with residual pass-through (scheduling/capacity.py)
* ``dynamic``          — the paper's proposed future work: adaptive per-expert
                         block sizing + greedy packing (scheduling/dynamic.py)

Every policy is a function ``(indices, n_experts, block_m, **kw) ->
BlockSchedule`` built from on-device jnp primitives only (no host sync —
the TPU scalar-prefetch property of core/schedule.py is preserved), and all
policies emit the same ``BlockSchedule`` contract so every consumer (Pallas
kernels, the XLA scan, the EP paths) works with any policy unchanged:

  - uniform physical block size ``block_m`` (policies may *shrink* it, e.g.
    ``dynamic`` schedules sub-tiles);
  - every block is owned by exactly one expert (``block_expert``), inactive
    blocks carry only padding (``block_active``);
  - ``src_tok == -1`` marks padding rows; ``pos`` maps each expanded token
    (t, j) to its padded row (dropped assignments point at a zeroed row).
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional

import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


class BlockSchedule(NamedTuple):
    """Everything the dispatch pipeline needs, all device arrays.

    With T = tokens, k = top_k, E = experts, M = the policy's physical
    block size: capacity is a static, policy-dependent row budget and
    num_blocks = capacity // M.
    """

    counts: jnp.ndarray          # (E,)  int32 tokens routed to each expert (pre-drop)
    group_offsets: jnp.ndarray   # (E+1,) int32 segment starts; for the
                                 # ``dynamic`` policy these are in packing
                                 # order (use ``seg_start`` per expert)
    src_tok: jnp.ndarray         # (capacity,) int32 source token row, -1 = padding
    pos: jnp.ndarray             # (T, k) int32 padded row of expanded token (t, j)
    block_expert: jnp.ndarray    # (num_blocks,) int32 owning expert (clamped)
    block_active: jnp.ndarray    # (num_blocks,) int32 1 = block has real rows
    capacity: int                # static
    block_m: int                 # static physical block size
    seg_start: Optional[jnp.ndarray] = None   # (E,) int32 per-expert base row
                                              # (None = group_offsets[:-1])


class ScheduleStats(NamedTuple):
    """Per-schedule telemetry (all 0-d jnp arrays — traced-safe).

    Emitted by every policy; consumed by benchmarks/skew_sensitivity.py and
    analysis/report.py to compare policies head-to-head.
    """

    useful_rows: jnp.ndarray     # kept (non-dropped) expanded tokens
    dropped_rows: jnp.ndarray    # assignments dropped by bounded capacity
    padded_rows: jnp.ndarray     # rows covered by ACTIVE blocks (compute cost)
    pad_waste: jnp.ndarray       # padded_rows / useful_rows
    drop_fraction: jnp.ndarray   # dropped / (T*k)
    top1_share: jnp.ndarray      # heaviest expert's share of raw routing
    n_blocks_active: jnp.ndarray
    occupancy: jnp.ndarray       # useful_rows / padded_rows


def schedule_stats(sched: BlockSchedule) -> ScheduleStats:
    """Telemetry from any policy's schedule (pure jnp, no host sync)."""
    n_assign = jnp.int32(sched.pos.size)
    useful = jnp.sum((sched.src_tok >= 0).astype(jnp.int32))
    dropped = n_assign - useful
    n_active = jnp.sum(sched.block_active.astype(jnp.int32))
    padded = n_active * sched.block_m
    total = jnp.sum(sched.counts)
    f32 = jnp.float32
    safe = lambda a, b: a.astype(f32) / jnp.maximum(b, 1).astype(f32)
    return ScheduleStats(
        useful_rows=useful,
        dropped_rows=dropped,
        padded_rows=padded,
        pad_waste=safe(padded, useful),
        drop_fraction=safe(dropped, n_assign),
        top1_share=safe(jnp.max(sched.counts), total),
        n_blocks_active=n_active,
        occupancy=safe(useful, padded),
    )


# The canonical head-to-head sweep — (policy name, build kwargs) — shared
# by benchmarks/skew_sensitivity.py, examples/skew_study.py, and the
# invariants tests so they always compare the same policy set.
DEFAULT_POLICY_SWEEP = (
    ("fixed", {}),
    ("capacity_factor", {"capacity_factor": 1.25}),
    ("dynamic", {}),
)


# ----------------------------------------------------------------------
# Policy registry
# ----------------------------------------------------------------------
PolicyFn = Callable[..., BlockSchedule]

_POLICIES: Dict[str, PolicyFn] = {}
_POLICY_CONFIG_FIELDS: Dict[str, tuple] = {}


def register_policy(name: str, *, config_fields: tuple = ()
                    ) -> Callable[[PolicyFn], PolicyFn]:
    """Register a schedule policy.  ``config_fields`` names the dispatch-
    config fields this policy consumes as build kwargs (e.g. the
    ``capacity_factor`` policy reads ``cfg.capacity_factor``) — consumers
    call ``policy_config_kwargs`` instead of hard-coding per-policy
    branches."""
    def deco(fn: PolicyFn) -> PolicyFn:
        _POLICIES[name] = fn
        _POLICY_CONFIG_FIELDS[name] = tuple(config_fields)
        return fn
    return deco


def policy_config_kwargs(policy: str, cfg) -> dict:
    """The registered policy's build kwargs, read off any config object
    carrying the fields the policy declared at registration."""
    get_policy(policy)                       # uniform unknown-policy error
    return {f: getattr(cfg, f) for f in _POLICY_CONFIG_FIELDS[policy]}


def get_policy(name: str) -> PolicyFn:
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown schedule policy {name!r}; "
                         f"available: {available_policies()}") from None


def available_policies():
    return sorted(_POLICIES)


def build_schedule(indices: jnp.ndarray, n_experts: int, block_m: int,
                   policy: str = "fixed", **kwargs) -> BlockSchedule:
    """Construct a block schedule under the named policy.

    indices: (T, k) int32 expert assignment per token.  Defaults to the
    paper's ``fixed`` policy, so existing positional call sites
    (``build_schedule(idx, E, M)``) keep their exact behavior.
    """
    return get_policy(policy)(indices, n_experts, block_m, **kwargs)
