"""``fixed`` policy: the paper's tile-aligned schedule (Algorithm 1, TPU form).

The paper computes the (expert_id, token_offset) block list on the host (its
Limitation 2 — a host/device sync per layer).  On TPU the schedule is built
with jnp primitives and consumed by the grouped-GEMM kernels as
scalar-prefetch operands, so there is no host round-trip.

TPU grids are static, so instead of the paper's dynamic block list we use
*tile-aligned expert segments*: the permutation places expert ``e``'s tokens
at a ``block_m``-aligned base offset.  Every M-tile then belongs to exactly
one expert and the static worst-case capacity is

    capacity = round_up(T*k, block_m) + n_experts * block_m

(each expert can waste at most one partial tile — the same asymptotic waste
as the paper's masked partial tiles).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.scheduling.base import BlockSchedule, register_policy, round_up


def schedule_capacity(n_tokens: int, top_k: int, n_experts: int,
                      block_m: int) -> int:
    return round_up(n_tokens * top_k, block_m) + n_experts * block_m


@register_policy("fixed")
def build_fixed_schedule(indices: jnp.ndarray, n_experts: int,
                         block_m: int) -> BlockSchedule:
    """indices: (T, k) int32 expert assignment per token. All on-device."""
    T, k = indices.shape
    E, M = n_experts, block_m
    capacity = schedule_capacity(T, k, E, M)
    num_blocks = capacity // M

    flat = indices.reshape(-1).astype(jnp.int32)              # (T*k,)
    sort_idx = jnp.argsort(flat, stable=True)                 # expanded ids by expert
    counts = jnp.bincount(flat, length=E).astype(jnp.int32)   # (E,)
    padded_counts = (counts + M - 1) // M * M
    padded_starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded_counts)]).astype(jnp.int32)
    unpadded_starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)]).astype(jnp.int32)

    ranks = jnp.arange(T * k, dtype=jnp.int32)
    expert_sorted = flat[sort_idx]
    dest = (padded_starts[expert_sorted]
            + ranks - unpadded_starts[expert_sorted])          # (T*k,) padded rows

    pos = jnp.zeros((T * k,), jnp.int32).at[sort_idx].set(dest).reshape(T, k)
    src_tok = jnp.full((capacity,), -1, jnp.int32).at[dest].set(
        sort_idx // k, mode="drop")

    block_starts = jnp.arange(num_blocks, dtype=jnp.int32) * M
    padded_ends = jnp.cumsum(padded_counts)                   # (E,)
    block_expert = jnp.searchsorted(
        padded_ends, block_starts, side="right").astype(jnp.int32)
    total_padded = padded_ends[-1] if E > 0 else jnp.int32(0)
    block_active = (block_starts < total_padded).astype(jnp.int32)
    block_expert = jnp.minimum(block_expert, E - 1)

    return BlockSchedule(
        counts=counts,
        group_offsets=padded_starts,
        src_tok=src_tok,
        pos=pos,
        block_expert=block_expert,
        block_active=block_active,
        capacity=capacity,
        block_m=M,
        seg_start=padded_starts[:-1],
    )
