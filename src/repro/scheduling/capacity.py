"""``capacity_factor`` policy: bounded per-expert capacity with overflow drops.

The distributed-dispatch analogue of skew handling (GShard semantics, already
sketched by the EP sharded path and benchmarks/skew_sensitivity.py): every
expert gets a *static* tile-aligned bucket of

    cap = round_up(max(1, T * k * capacity_factor / E), block_m)

rows; assignments beyond an expert's bucket are dropped first-come-first-kept
(stable in token order).  Dropped assignments contribute exactly zero to the
layer output — their ``pos`` points at a permanently-inactive sentinel block —
so the model's residual stream passes the token through unchanged (the
"residual pass-through": y = x + moe(x) degrades to y = x for fully-dropped
tokens rather than corrupting them).

Unlike ``fixed``, total capacity is load-independent (E * cap + block_m), so
a rank's memory and grid never vary with routing — the property the EP
all-to-all layout requires.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.scheduling.base import BlockSchedule, register_policy, round_up


def expert_capacity(n_tokens: int, top_k: int, n_experts: int, block_m: int,
                    capacity_factor: float) -> int:
    """Static tile-aligned per-expert row budget (shared with the EP path)."""
    return round_up(max(1, int(n_tokens * top_k * capacity_factor
                               / n_experts)), block_m)


def capacity_slots(flat: jnp.ndarray, n_experts: int):
    """Rank of each expanded assignment within its expert, stable in token
    order.  flat: (T*k,) int32 -> (slot (T*k,) int32, counts (E,) int32).

    ``slot < cap`` is the keep mask under a bucket of ``cap`` rows — the
    exact first-come-first-kept semantics of the EP send-buffer layout
    (core/distributed.py), factored here so single-device and distributed
    dispatch share one definition of "which token gets dropped".
    """
    n = flat.shape[0]
    sort_idx = jnp.argsort(flat, stable=True)
    counts = jnp.bincount(flat, length=n_experts).astype(jnp.int32)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)]).astype(jnp.int32)
    ranks = jnp.arange(n, dtype=jnp.int32)
    slot_sorted = ranks - starts[flat[sort_idx]]
    slot = jnp.zeros((n,), jnp.int32).at[sort_idx].set(slot_sorted)
    return slot, counts


@register_policy("capacity_factor", config_fields=("capacity_factor",))
def build_capacity_schedule(indices: jnp.ndarray, n_experts: int,
                            block_m: int, *,
                            capacity_factor: float = 2.0,
                            cap: int | None = None) -> BlockSchedule:
    """``cap`` overrides the derived per-expert bucket — used by the EP
    replicated path, where the bucket must be sized over the GLOBAL expert
    count, not the rank-local experts + sentinel."""
    T, k = indices.shape
    E, M = n_experts, block_m
    if cap is None:
        cap = expert_capacity(T, k, E, M, capacity_factor)
    capacity = E * cap + M              # + one sentinel block for drops
    num_blocks = capacity // M
    bpe = cap // M                      # blocks per expert bucket

    flat = indices.reshape(-1).astype(jnp.int32)
    slot, counts = capacity_slots(flat, E)
    keep = slot < cap
    dest = jnp.where(keep, flat * cap + slot, E * cap)     # drops -> sentinel
    pos = dest.reshape(T, k)

    src_rows = jnp.arange(T * k, dtype=jnp.int32) // k
    src_tok = jnp.full((capacity,), -1, jnp.int32).at[
        jnp.where(keep, dest, capacity)].set(src_rows, mode="drop")

    bidx = jnp.arange(num_blocks, dtype=jnp.int32)
    block_expert = jnp.minimum(bidx // bpe, E - 1)
    kept_counts = jnp.minimum(counts, cap)
    start_in_bucket = bidx * M - block_expert * cap
    block_active = ((bidx < E * bpe)
                    & (start_in_bucket < kept_counts[block_expert])
                    ).astype(jnp.int32)

    group_offsets = (jnp.arange(E + 1, dtype=jnp.int32) * cap)
    return BlockSchedule(
        counts=counts,
        group_offsets=group_offsets,
        src_tok=src_tok,
        pos=pos,
        block_expert=block_expert,
        block_active=block_active,
        capacity=capacity,
        block_m=M,
        seg_start=group_offsets[:-1],
    )
