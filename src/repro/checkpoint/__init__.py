"""repro.checkpoint subpackage."""
