"""Checkpointing: versioned, atomic, async, elastic.

* Atomic: each checkpoint is written to ``<dir>/tmp.<step>`` and renamed to
  ``<dir>/ckpt_<step>`` only after every file is flushed — a crash mid-write
  never corrupts the latest checkpoint.
* Async: ``save`` returns immediately; serialization runs on a background
  thread (the caller passes host arrays — jax.device_get happens on the
  training thread only for the leaves, cheap relative to a step).
* Elastic: checkpoints store FULL (unsharded) arrays + treedef, so a restore
  may target a DIFFERENT mesh / device count — ``restore(..., shardings=)``
  re-shards on load (tests cover 1-device -> 8-device round-trips).
* Self-describing: manifest.json carries step, leaf paths/dtypes/shapes.
* Quantization-aware: scheme-tagged `QuantTensor` params (repro.
  quantization) are ordinary pytree nodes — their compressed ``q``/``s``
  leaves serialize as-is (int8 payloads stay int8 on disk, so a quantized
  checkpoint is ~4x smaller) and the static scheme/dtype tags live in the
  caller's target treedef.  Restoring a quantized checkpoint into a dense
  target (or the reverse) is a *structure* mismatch; ``restore`` reports
  it as such instead of dying on a missing-leaf KeyError.

Multi-host note: in a real pod deployment each host would write its
process-local shards (jax.experimental.multihost_utils); this single-process
container writes full arrays from process 0 — the manager API is the same.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3,
                 async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._pool = ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any) -> None:
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        if self._pool is None:
            self._write(step, host_leaves)
            return
        self.wait()                       # one in flight at a time
        self._pending = self._pool.submit(self._write, step, host_leaves)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_leaves) -> None:
        tmp = self.dir / f"tmp.{step}"
        final = self.dir / f"ckpt_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "leaves.npz",
                 **{f"leaf_{i}": l for i, l in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)}
                       for l in host_leaves],
        }
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                 # atomic publish
        self._gc()

    def _gc(self) -> None:
        with self._lock:
            ckpts = sorted(self.dir.glob("ckpt_*"))
            for old in ckpts[:-self.keep_last]:
                shutil.rmtree(old, ignore_errors=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("ckpt_*"))
        if not ckpts:
            return None
        return int(ckpts[-1].name.split("_")[1])

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Load into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for elastic re-shard on a (possibly different) mesh."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"ckpt_{step:08d}"
        data = np.load(path / "leaves.npz")
        leaves, treedef = _flatten(target)
        if len(data.files) != len(leaves):
            raise ValueError(
                f"checkpoint {path.name} holds {len(data.files)} leaves "
                f"but the restore target flattens to {len(leaves)} — the "
                f"tree STRUCTURES differ (e.g. a quantized checkpoint "
                f"restored into a dense target, or vice versa; build the "
                f"target with the same quantize_params_tree scheme it was "
                f"saved under)")
        loaded = [data[f"leaf_{i}"] for i in range(len(leaves))]
        for i, (l, tgt) in enumerate(zip(loaded, leaves)):
            if tuple(l.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {l.shape} != target "
                    f"{tgt.shape} (elastic restore reshards devices, "
                    f"not logical shapes)")
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
            loaded = [jax.device_put(l, s)
                      for l, s in zip(loaded, shard_leaves)]
        else:
            loaded = [jax.device_put(np.asarray(l)) for l in loaded]
        return jax.tree_util.tree_unflatten(treedef, loaded)
