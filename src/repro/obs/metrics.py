"""Low-overhead metrics registry: labeled counters, gauges, histograms.

The serve/train telemetry this repo accumulated over five PRs is scattered
— per-plan ``ScheduleStats`` ride the jit aux, ``PagedKVCache.stats()``
returns a dict nobody aggregates, admission/drop counts live on engine
attributes.  ``MetricsRegistry`` is the one host-side sink they all land
in (DESIGN.md §10), mirroring the PR 1/2/4 registry idiom at the
instrument level: a metric is addressed by ``(name, labels)``, created on
first touch, and exported as one JSON snapshot.

Three instrument kinds, chosen for what the serve path actually needs:

* **counter** — monotone accumulation (requests admitted, slow steps,
  recompiles, evictions).  ``inc(name, value, **labels)``.
* **gauge** — last-write-wins level (blocks in use, quantized expert
  payload bytes).  ``set_gauge(name, value, **labels)``.
* **histogram** — raw-sample distribution with percentile summary
  (TTFT, TPOT, step wall-time, per-plan pad waste).  ``observe(name,
  value, **labels)``; the snapshot reports count/sum/min/max/mean and
  the p50/p99 production MoE serving is judged on (MoE-Inference-Bench).

Everything is plain host-side python over floats — safe to call from
inside a jitted function body ONLY at trace time (no traced values), and
cheap enough to call once per engine step.  The zero-cost-when-off
contract is carried by ``NullMetrics``: same API, empty bodies — the
default sink everywhere, so instrumented code never branches on
"is observability on".
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: dict) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) — matches what the
    benchmark tables report; no interpolation surprises at small n."""
    if not values:
        return float("nan")
    s = sorted(values)
    rank = max(1, min(len(s), math.ceil(q / 100.0 * len(s))))
    return float(s[rank - 1])


def summarize(values: List[float]) -> dict:
    """count/sum/min/max/mean + p50/p99 of a raw sample list."""
    if not values:
        return {"count": 0}
    return {"count": len(values), "sum": float(sum(values)),
            "min": float(min(values)), "max": float(max(values)),
            "mean": float(sum(values) / len(values)),
            "p50": percentile(values, 50.0),
            "p99": percentile(values, 99.0)}


class MetricsRegistry:
    """Host-side instrument store; see module docstring for the model."""

    def __init__(self):
        self._counters: Dict[Tuple[str, LabelKey], float] = {}
        self._gauges: Dict[Tuple[str, LabelKey], float] = {}
        self._hists: Dict[Tuple[str, LabelKey], List[float]] = {}

    # -- instruments ---------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        k = (name, _label_key(labels))
        self._counters[k] = self._counters.get(k, 0.0) + float(value)

    def set_gauge(self, name: str, value: float, **labels) -> None:
        self._gauges[(name, _label_key(labels))] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        self._hists.setdefault((name, _label_key(labels)),
                               []).append(float(value))

    def observe_many(self, prefix: str, values: dict, **labels) -> None:
        """Absorb a scalar dict (e.g. a retired request's ``sched/*``
        plan stats) as one histogram sample per key."""
        for k, v in values.items():
            self.observe(f"{prefix}{k}", float(v), **labels)

    # -- export --------------------------------------------------------
    def counter_value(self, name: str, **labels) -> float:
        return self._counters.get((name, _label_key(labels)), 0.0)

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        return self._gauges.get((name, _label_key(labels)))

    def histogram_values(self, name: str, **labels) -> List[float]:
        return list(self._hists.get((name, _label_key(labels)), []))

    def snapshot(self) -> dict:
        """One JSON-ready dict of everything recorded so far."""
        def rows(store, render):
            return [{"name": n, "labels": dict(lk), **render(v)}
                    for (n, lk), v in sorted(store.items())]
        return {
            "counters": rows(self._counters, lambda v: {"value": v}),
            "gauges": rows(self._gauges, lambda v: {"value": v}),
            "histograms": rows(self._hists, summarize),
        }

    def to_json(self, path=None, *, extra: Optional[dict] = None) -> str:
        """Serialize the snapshot (plus an optional ``extra`` section —
        the serve launcher adds its aggregated per-request latency
        block); writes to ``path`` when given, returns the JSON text."""
        doc = self.snapshot()
        if extra:
            doc.update(extra)
        text = json.dumps(doc, indent=1, sort_keys=True)
        if path is not None:
            import pathlib
            p = pathlib.Path(path)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(text)
        return text


class NullMetrics(MetricsRegistry):
    """The default sink: same API, no state, no work.  Instrumented code
    calls it unconditionally — zero-cost-when-off lives here, not in
    ``if obs`` branches at every call site."""

    def __init__(self):
        super().__init__()

    def inc(self, name, value=1.0, **labels):
        pass

    def set_gauge(self, name, value, **labels):
        pass

    def observe(self, name, value, **labels):
        pass

    def observe_many(self, prefix, values, **labels):
        pass


NULL_METRICS = NullMetrics()
