"""Step-timeline span tracer emitting Chrome-trace / Perfetto JSON.

``SpanTracer`` records HOST-side wall-clock spans of the serve/train
loops — admission, prefix-hash probe, step assembly, the jitted forward
dispatch, the one-per-step host sync, retirement — plus instant events
for the things that happen *to* the loop: recompiles (first call at a new
shape), straggler-flagged slow steps, block evictions/compactions.  The
artifact (``results/trace/*.json``) loads directly in ``chrome://tracing``
/ https://ui.perfetto.dev.

Overhead contract (DESIGN.md §10): a span is two ``clock()`` calls and
one dict append; nothing here touches a device value, inserts an op into
a traced computation, or forces a sync — the forward span measures
DISPATCH cost (jax is async), the host_sync span measures where blocking
actually happens.  Greedy tokens are asserted bitwise-identical with
tracing on vs off (tests/test_obs.py).  The default sink is
``NullTracer`` (shared no-op context manager, no state).

The optional device-side view is ``device_trace()`` — a bracket around
``jax.profiler.start_trace``/``stop_trace`` producing XLA's own profile
into a separate directory; it is best-effort (profiler availability
varies by backend) and never fails the run.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Callable, List, Optional


class _Span:
    """Context manager for one complete ("ph": "X") event."""
    __slots__ = ("tracer", "name", "args", "t0")

    def __init__(self, tracer, name, args):
        self.tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        self.t0 = self.tracer.clock()
        return self

    def __exit__(self, *exc):
        t1 = self.tracer.clock()
        self.tracer._emit(self.name, "X", self.t0, dur=t1 - self.t0,
                          args=self.args)
        return False


class SpanTracer:
    """Chrome-trace event collector (host-side spans + instants)."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 process_name: str = "repro-serve"):
        self.clock = clock
        self.process_name = process_name
        self._t_origin = clock()
        self.events: List[dict] = []

    # -- recording -----------------------------------------------------
    def _us(self, t: float) -> float:
        return (t - self._t_origin) * 1e6

    def _emit(self, name: str, ph: str, t: float, *, dur: float = None,
              args: Optional[dict] = None) -> None:
        ev = {"name": name, "ph": ph, "ts": self._us(t),
              "pid": 0, "tid": 0}
        if dur is not None:
            ev["dur"] = dur * 1e6
        if ph == "i":
            ev["s"] = "t"                       # thread-scoped instant
        if args:
            ev["args"] = {k: (v if isinstance(v, (int, float, str, bool))
                              else repr(v)) for k, v in args.items()}
        self.events.append(ev)

    def span(self, name: str, **args) -> _Span:
        """``with tracer.span("serve/forward", tokens=T): ...``"""
        return _Span(self, name, args or None)

    def instant(self, name: str, **args) -> None:
        self._emit(name, "i", self.clock(), args=args or None)

    # -- export --------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Events sorted by timestamp (viewers require monotone order
        within a track) under the standard ``traceEvents`` envelope."""
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": self.process_name}}]
        return {"traceEvents":
                meta + sorted(self.events, key=lambda e: e["ts"]),
                "displayTimeUnit": "ms"}

    def save(self, path) -> str:
        import pathlib
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_chrome_trace(), indent=1))
        return str(p)


class NullTracer(SpanTracer):
    """Default sink: ``span()`` hands back one shared do-nothing context
    manager and ``instant``/``save`` are empty — no clock reads, no
    allocation, no file."""

    def __init__(self):
        super().__init__(clock=lambda: 0.0)
        self._null = contextlib.nullcontext()

    def span(self, name, **args):
        return self._null

    def instant(self, name, **args):
        pass

    def save(self, path):
        return None


NULL_TRACER = NullTracer()


@contextlib.contextmanager
def device_trace(logdir: Optional[str]):
    """Optional ``jax.profiler`` bracket: profiles DEVICE-side execution
    into ``logdir`` (TensorBoard/XPlane format, independent of the host
    span artifact).  No-op when ``logdir`` is falsy; best-effort —
    profiler failures degrade to a warning, never a crashed serve run."""
    if not logdir:
        yield
        return
    import jax
    started = False
    try:
        jax.profiler.start_trace(logdir)
        started = True
    except Exception as e:                      # pragma: no cover - backend
        print(f"[obs] device trace unavailable ({e!r}); continuing without")
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception as e:              # pragma: no cover - backend
                print(f"[obs] device trace stop failed ({e!r})")


def validate_chrome_trace(doc: dict, *, required_names=()) -> dict:
    """Structural validation used by tests and the CI artifact check:
    ``traceEvents`` envelope, complete events carry ts+dur, timestamps
    monotone after the declared sort, required span names present.
    Returns {"events": n, "names": set} on success, raises otherwise."""
    assert isinstance(doc, dict) and "traceEvents" in doc, \
        "not a chrome-trace envelope"
    evs = [e for e in doc["traceEvents"] if e.get("ph") != "M"]
    assert evs, "trace has no events"
    names = set()
    last_ts = None
    for e in evs:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e), e
        if e["ph"] == "X":
            assert "dur" in e and e["dur"] >= 0, e
        assert last_ts is None or e["ts"] >= last_ts, \
            f"non-monotone ts: {e}"
        last_ts = e["ts"]
        names.add(e["name"])
    missing = set(required_names) - names
    assert not missing, f"required span names missing from trace: {missing}"
    return {"events": len(evs), "names": names}
