"""Serve-path observability: metrics registry + span tracer + latency.

One bundle (DESIGN.md §10) threads through the serve engine, the jitted
step constructors, the paged KV pool, the executor plan hook, and the
train loop:

* ``Observability.metrics`` — the labeled counter/gauge/histogram sink
  (obs/metrics.py) absorbing the previously-scattered telemetry:
  per-plan ``sched/*`` stats at retirement, ``PagedKVCache.stats()``
  per step, admission/drop counts, quantized expert payload bytes.
* ``Observability.tracer`` — Chrome-trace spans of the step timeline
  (obs/trace.py): admit / prefix probe / assemble / forward dispatch /
  host sync / retire, plus instants for recompiles, slow steps, block
  evictions and compactions.
* ``Observability.straggler`` — the PR 2 ``StragglerMonitor`` wired as
  a serve-side slow-step detector (injectable clock): flagged steps
  become ``serve/slow_steps`` counts and ``slow_step`` trace instants.
* per-request latency accounting (obs/latency.py) is ALWAYS on — it is
  a handful of host clock reads per step and fills ``Request.stats``
  ``lat/*`` whether or not a sink is attached.

The default is ``NOOP`` — null sinks whose methods are empty, so
instrumented code never branches and the off-path costs nothing.
Tracing adds NO device-side ops anywhere (host wall-clock and already-
materialized host values only): greedy tokens are bitwise-identical
with observability on or off, asserted in tests/test_obs.py.

Following the PR 1/2/4 registry idiom, sinks are registered by name
(``null`` and ``memory`` ship built-in) so launchers select one by flag.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

from repro.obs.latency import (LAT_KEYS, RequestTimeline, aggregate,
                               drop_summary, latency_summary)
from repro.obs.metrics import (NULL_METRICS, MetricsRegistry, NullMetrics,
                               percentile, summarize)
from repro.obs.trace import (NULL_TRACER, NullTracer, SpanTracer,
                             device_trace, validate_chrome_trace)

__all__ = [
    "Observability", "NOOP", "MetricsRegistry", "NullMetrics",
    "SpanTracer", "NullTracer", "RequestTimeline", "LAT_KEYS",
    "aggregate", "drop_summary", "latency_summary", "percentile",
    "summarize",
    "device_trace", "validate_chrome_trace", "register_sink", "get_sink",
    "available_sinks", "NULL_METRICS", "NULL_TRACER",
]


class Observability:
    """Metrics + tracer + optional straggler monitor, one shared clock.

    ``enabled`` is False only for the null bundle: call sites that would
    do real work to FEED a sink (walking a params tree for byte counts,
    converting a stats dict) gate on it; plain span/counter calls do not
    — the null sinks absorb those for free."""

    def __init__(self, metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[SpanTracer] = None,
                 straggler=None,
                 clock: Callable[[], float] = time.perf_counter):
        self.metrics = NULL_METRICS if metrics is None else metrics
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.straggler = straggler
        self.clock = clock
        self.enabled = not (self.metrics is NULL_METRICS
                            and self.tracer is NULL_TRACER
                            and straggler is None)

    @classmethod
    def memory(cls, clock: Callable[[], float] = time.perf_counter,
               straggler_window: int = 32, straggler_factor: float = 2.0):
        """The full in-memory bundle: fresh registry + tracer + straggler
        monitor on one injectable clock (tests drive a virtual clock)."""
        from repro.runtime.fault import StragglerMonitor
        return cls(metrics=MetricsRegistry(),
                   tracer=SpanTracer(clock=clock),
                   straggler=StragglerMonitor(window=straggler_window,
                                              factor=straggler_factor,
                                              clock=clock),
                   clock=clock)

    # -- step bracket (engine/train loops) -----------------------------
    def step_begin(self, step: int) -> None:
        if self.straggler is not None:
            self.straggler.start_step(step)

    def step_end(self, step: int, *, scope: str = "serve") -> None:
        """Close the straggler window for ``step``; a flagged step (>
        factor x rolling median) becomes a ``<scope>/slow_steps`` count
        and a ``slow_step`` trace instant."""
        if self.straggler is None:
            return
        flag = self.straggler.end_step()
        if flag:
            self.metrics.inc(f"{scope}/slow_steps")
            self.tracer.instant(
                "slow_step", scope=scope, step=flag["step"],
                duration_s=flag["duration"],
                slowdown=round(flag["slowdown"], 3))

    # -- trace-time hooks ----------------------------------------------
    def on_trace(self, kind: str, **static) -> None:
        """Recompile-event detection: called from INSIDE a jitted step
        body, which python-executes only while jax traces — i.e. exactly
        once per distinct input shape.  Host-side only; adds no ops to
        the traced computation."""
        self.metrics.inc("serve/recompiles", kind=kind)
        self.tracer.instant("recompile", kind=kind, **static)

    def on_plan(self, *, tokens: int, executor: str, policy: str) -> None:
        """Executor plan-stats hook (execution/base.py): one call per
        TRACED ``plan_dispatch`` — counts how many distinct plan shapes
        each MoE layer compiled and tags them by backend/policy."""
        self.metrics.inc("moe/plans_traced", executor=executor,
                         policy=policy)
        self.tracer.instant("plan_trace", tokens=tokens,
                            executor=executor, policy=policy)


NOOP = Observability()


# ----------------------------------------------------------------------
# Sink registry (PR 1/2/4 idiom): name -> Observability factory
# ----------------------------------------------------------------------
_SINKS: Dict[str, Callable[..., Observability]] = {}


def register_sink(name: str):
    def deco(fn: Callable[..., Observability]):
        _SINKS[name] = fn
        return fn
    return deco


def get_sink(name: str, **kw) -> Observability:
    if name not in _SINKS:
        raise ValueError(f"unknown observability sink {name!r}; "
                         f"registered: {available_sinks()}")
    return _SINKS[name](**kw)


def available_sinks():
    return sorted(_SINKS)


@register_sink("null")
def _null_sink(**kw) -> Observability:
    return NOOP


@register_sink("memory")
def _memory_sink(**kw) -> Observability:
    return Observability.memory(**kw)
