"""Per-request latency accounting: TTFT, TPOT, queue wait, E2E.

MoE-Inference-Bench (PAPERS.md, 2508.17467) scores production MoE serving
on per-request latency distributions — time-to-first-token and time-per-
output-token at p50/p99 — which nothing in this repo measured before this
layer.  The engine keeps one ``RequestTimeline`` per in-flight rid
(host wall-clock stamps only: submit at ``run()`` entry, admit when a
slot is claimed, one stamp per engine step shared by every token that
step produced) and materializes it into ``Request.stats`` at retirement
under the ``lat/*`` key family — the same dict that already carries the
``sched/*`` plan stats and ``serve/*`` engine counters, so one schema
covers all per-request telemetry (key parity between the paged and
contiguous engines is asserted in tests/test_obs.py).

Aggregation helpers turn a batch of retired requests into the p50/p99
table ``benchmarks/serving_throughput.py`` records and
``analysis/report.py`` renders.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.obs.metrics import percentile

# the contract: every retired request carries exactly these lat/* keys
# (both engines, dense and MoE) — tests assert schema parity on them
LAT_KEYS = ("lat/queue_wait_s", "lat/ttft_s", "lat/tpot_s", "lat/e2e_s",
            "lat/decode_tokens")


@dataclasses.dataclass
class RequestTimeline:
    """Host timestamps for one request's serve lifetime.

    ``token_times`` holds one stamp per OUTPUT token (the step's shared
    post-sync stamp — all tokens of one engine step are produced by the
    same forward, so finer granularity would be fiction)."""
    submit: float                       # entered the pending queue
    admit: float = 0.0                  # claimed a slot
    first_token: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)

    def on_token(self, t: float) -> None:
        if self.first_token is None:
            self.first_token = t
        self.token_times.append(t)

    def finalize(self, *, end: Optional[float] = None) -> dict:
        """-> the ``lat/*`` entries for ``Request.stats``.

        TPOT is the mean inter-token gap over DECODE tokens (first token
        excluded — its cost is prefill and belongs to TTFT); a request
        with a single output token has no decode gap and reports 0.0 so
        every value stays finite (the churn test asserts finiteness)."""
        tt = self.token_times
        first = self.first_token if self.first_token is not None \
            else (end if end is not None else self.admit)
        last = tt[-1] if tt else first
        tpot = (last - first) / (len(tt) - 1) if len(tt) > 1 else 0.0
        return {
            "lat/queue_wait_s": self.admit - self.submit,
            "lat/ttft_s": first - self.submit,
            "lat/tpot_s": tpot,
            "lat/e2e_s": (end if end is not None else last) - self.submit,
            "lat/decode_tokens": float(len(tt)),
        }


def aggregate(samples: List[float]) -> Optional[dict]:
    """p50/p99/mean/n of one latency series; None on an empty one (so
    consumers gate on truthiness instead of probing for keys)."""
    if not samples:
        return None
    return {"n": len(samples),
            "mean": float(sum(samples) / len(samples)),
            "p50": percentile(samples, 50.0),
            "p99": percentile(samples, 99.0)}


def latency_summary(requests) -> dict:
    """Aggregate retired requests' ``lat/*`` stats into the percentile
    block recorded in ``results/serve/*.json`` and rendered by
    ``analysis/report.py``:

        {"ttft_s": {"n", "mean", "p50", "p99"}, "tpot_s": {...},
         "queue_wait_s": {...}, "e2e_s": {...}}

    Any request carrying ``lat/*`` stats contributes — including dropped
    or preempted-unfinished requests, whose CENSORED stats the engine
    finalizes at drop time (``ServeEngine.finalize_drops``).  Callers
    reporting completion latencies should pass only completed requests
    and report the censored remainder via ``drop_summary``.
    """
    done = [r for r in requests if getattr(r, "stats", None)]
    out = {}
    for key in ("ttft_s", "tpot_s", "queue_wait_s", "e2e_s"):
        out[key] = aggregate([r.stats[f"lat/{key}"] for r in done
                              if f"lat/{key}" in r.stats])
    return out


def drop_summary(requests) -> Optional[dict]:
    """Roll up requests that never completed (dropped at the step budget
    or preempted without resume).  Their ``lat/*`` stats are censored —
    stamped finite at drop time, measuring time spent, not time to
    completion — so they are reported HERE instead of polluting the
    completion percentiles.  None when every request finished, so
    consumers gate on truthiness (the all-dropped serve run used to
    print nothing at all)."""
    undone = [r for r in requests
              if not getattr(r, "done", False) and getattr(r, "stats", None)]
    if not undone:
        return None
    return {
        "n": len(undone),
        "dropped": sum(1 for r in undone
                       if r.stats.get("serve/dropped", 0.0)),
        "preempted": sum(1 for r in undone
                         if r.stats.get("serve/preempted", 0.0)),
        "rids": [r.rid for r in undone],
        "tokens_out": int(sum(r.stats.get("lat/decode_tokens", 0.0)
                              for r in undone)),
        "wait_s": aggregate([r.stats["lat/e2e_s"] for r in undone
                             if "lat/e2e_s" in r.stats]),
    }
