"""Pluggable executor backends for MoE dispatch (plan/execute split).

See execution/base.py for the `DispatchPlan` / `Executor` contract and
DESIGN.md §6 for the design.  Importing this package registers the three
built-in executors: ``pallas``, ``xla``, ``dense``.
"""
from repro.execution.base import (DispatchPlan, Executor,  # noqa: F401
                                  available_executors, combine_scale_rows,
                                  execute, get_executor, plan_dispatch,
                                  plan_schedule, register_executor,
                                  router_aux_losses, set_plan_hook)
from repro.execution.dense import DenseExecutor  # noqa: F401
from repro.execution.pallas import PallasExecutor  # noqa: F401
from repro.execution.xla import (XlaExecutor, fused_gate_up_xla,  # noqa: F401
                                 grouped_gemm_xla)
