"""Plan/execute split for MoE dispatch: `DispatchPlan` + executor registry.

The paper's pipeline (router -> schedule -> permute -> grouped GEMMs ->
combine) used to live as one monolithic function with string-compare
backend branches.  It is now two phases with one contract (DESIGN.md §6):

* **Plan** — `plan_dispatch(x, w_router, cfg)` runs the router, builds the
  configured `BlockSchedule`, scatters the combine-scale rows, and collects
  aux/telemetry.  Everything routing-dependent is computed exactly once per
  batch and is backend-independent: any executor can consume any plan.
* **Execute** — an `Executor` turns a plan into the layer output, either
  through the phase methods (`permute` / `expert_ffn` / `unpermute` — the
  granularity the EP paths compose) or the whole-plan `run` (backends such
  as `dense` that have no permuted layout at all).

Backends register under a name (`pallas`, `xla`, `dense` ship built-in);
``MoEDispatchConfig.executor`` selects one.  Adding a backend — a future
ragged-dot executor, a CPU-offload executor — is one registered module, not
another ``elif`` in core code.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.scheduling import (BlockSchedule, build_schedule,
                              policy_config_kwargs, schedule_stats)


class DispatchPlan(NamedTuple):
    """Everything per-batch and routing-dependent, built once by
    `plan_dispatch` and consumable by every executor.

    ``schedule`` / ``combine_scale`` are None when the plan was built
    without a schedule (``with_schedule=False`` — the EP paths derive their
    own rank-local layouts from ``indices``) or when the selected executor
    declares ``needs_schedule = False`` (the dense oracle)."""

    weights: jnp.ndarray                   # (T, k) f32 combine weights
    indices: jnp.ndarray                   # (T, k) i32 expert assignment
    logits: jnp.ndarray                    # (T, E) f32 router logits
    schedule: Optional[BlockSchedule]      # the configured policy's layout
    combine_scale: Optional[jnp.ndarray]   # (capacity,) f32 epilogue rows
    aux: dict                              # lb/z losses (+ sched/* stats)


def router_aux_losses(logits: jnp.ndarray, indices: jnp.ndarray, cfg):
    """Load-balance + router-z losses (training substrate; the paper is
    inference-only so these sit outside its measured pipeline)."""
    probs = jax.nn.softmax(logits, axis=-1)
    E = cfg.n_experts
    frac = jnp.mean(
        jax.nn.one_hot(indices, E, dtype=jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=0)
    lb = E * jnp.sum(frac * mean_prob)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return {"lb_loss": lb, "router_z": z}


def combine_scale_rows(sched: BlockSchedule, weights: jnp.ndarray):
    """Scatter the (T, k) combine weights onto padded rows for the fused
    down-projection epilogue. Padding rows get 0."""
    scale = jnp.zeros((sched.capacity,), jnp.float32)
    return scale.at[sched.pos.reshape(-1)].set(
        weights.reshape(-1).astype(jnp.float32), mode="drop")


def plan_schedule(indices: jnp.ndarray, cfg) -> BlockSchedule:
    """The configured policy's schedule for this batch's routing.  Each
    policy declares which config fields it consumes (scheduling/base.py).

    Under ``autotune=True`` a policy consuming ``block_m_min`` (the
    dynamic policy's sub-block floor) gets it overridden by a swept
    ``sub_block`` tune-cache record for this routing shape, when one
    exists — the same trace-time consult idiom as the kernel tiles
    (repro.tuning, DESIGN.md §12)."""
    kw = policy_config_kwargs(cfg.schedule_policy, cfg)
    if getattr(cfg, "autotune", False) and "block_m_min" in kw:
        from repro.tuning import lookup_block_sizes
        rec = lookup_block_sizes(
            "sub_block", M=int(indices.shape[0]) * cfg.top_k,
            K=cfg.block_m, N=0, E=cfg.n_experts,
            executor=cfg.executor)
        if rec is not None and "block_m_min" in rec:
            kw["block_m_min"] = int(rec["block_m_min"])
    return build_schedule(
        indices, cfg.n_experts, cfg.block_m, policy=cfg.schedule_policy,
        **kw)


# ----------------------------------------------------------------------
# Executor protocol + registry
# ----------------------------------------------------------------------
class Executor:
    """Backend contract for the grouped expert compute.

    Phase methods (`permute` / `expert_ffn` / `unpermute`) operate on a
    `BlockSchedule` and are what the EP layer composes rank-locally; the
    whole-plan `run` is what single-device dispatch calls and is the only
    entry a schedule-free backend (dense) must provide.  ``w`` is always
    the expert-weight mapping {"w_gate", "w_up", "w_down"} of (E, K, N)
    arrays or scheme-tagged QuantTensors (repro.quantization).

    Quantization capability is part of the contract (DESIGN.md §8):
    ``supports_scheme`` declares which registered schemes the backend can
    consume, and ``prepare_weights`` is the hook between the plan and the
    grouped compute — the base implementation materializes QuantTensors
    to dense stacks (correct for any backend, e.g. the dense oracle); the
    in-scan backends (xla, pallas) override it to pass compressed weights
    through and dequantize each gathered block inside the grouped-GEMM
    scan instead.
    """

    name: str = "?"
    needs_schedule: bool = True       # plan carries a BlockSchedule

    # -- quantization capability --------------------------------------
    def supports_scheme(self, scheme: str) -> bool:
        """Whether this backend can consume expert weights quantized
        under ``scheme``.  The default covers every registered scheme via
        the materializing ``prepare_weights``; a backend with a narrower
        contract (a future fused-int8-only kernel) overrides this."""
        from repro.quantization import available_schemes
        return scheme in available_schemes()

    def prepare_weights(self, w: dict, cfg) -> dict:
        """Adapt the expert-weight mapping to this backend, called once
        per plan execution.  Default: materialize QuantTensors to dense
        (E, K, N) stacks.  In-scan backends override to the identity and
        dequantize per gathered block instead."""
        from repro.quantization import QuantTensor
        return {k: (v.materialize() if isinstance(v, QuantTensor) else v)
                for k, v in w.items()}

    # -- routing ------------------------------------------------------
    def route(self, logits: jnp.ndarray, cfg):
        """(T, E) f32 logits -> (weights (T, k) f32, indices (T, k) i32)."""
        from repro.kernels import ref
        return ref.router_ref(logits, cfg.top_k, gating=cfg.gating,
                              norm_topk=cfg.norm_topk,
                              routed_scale=cfg.routed_scale)

    # -- phases -------------------------------------------------------
    def permute(self, x, sched: BlockSchedule, cfg):
        raise NotImplementedError(
            f"executor {self.name!r} has no phase-level permute")

    def expert_ffn(self, xp, w: dict, sched: BlockSchedule, cfg,
                   row_scale=None):
        """Grouped gate+up activation and down projection on a schedule."""
        raise NotImplementedError(
            f"executor {self.name!r} has no phase-level expert_ffn")

    def unpermute(self, y, sched: BlockSchedule, weights, cfg):
        raise NotImplementedError(
            f"executor {self.name!r} has no phase-level unpermute")

    # -- whole plan ---------------------------------------------------
    def run(self, x, w: dict, plan: DispatchPlan, cfg):
        """x: (T, d) -> y: (T, d) under the plan's routing + schedule."""
        sched = plan.schedule
        if sched is None:
            raise ValueError(
                f"executor {self.name!r} needs a schedule, but this plan "
                "carries none (built with with_schedule=False or by a "
                "needs_schedule=False executor) — rebuild it with "
                "plan_dispatch(..., with_schedule=True)")
        w = self.prepare_weights(w, cfg)
        xp = constrain("moe_dispatch", self.permute(x, sched, cfg))
        scale = plan.combine_scale if cfg.fold_combine else None
        y = self.expert_ffn(xp, w, sched, cfg, row_scale=scale)
        return self.unpermute(
            y, sched, None if cfg.fold_combine else plan.weights, cfg)


_EXECUTORS: Dict[str, Executor] = {}


def register_executor(name: str) -> Callable[[type], type]:
    """Class decorator: instantiate and register an Executor under `name`."""
    def deco(cls: type) -> type:
        cls.name = name
        _EXECUTORS[name] = cls()
        return cls
    return deco


def get_executor(name) -> Executor:
    if isinstance(name, Executor):
        return name
    try:
        return _EXECUTORS[name]
    except KeyError:
        raise ValueError(f"unknown executor {name!r}; "
                         f"available: {available_executors()}") from None


def available_executors():
    return sorted(_EXECUTORS)


# ----------------------------------------------------------------------
# Plan-stats hook (observability)
# ----------------------------------------------------------------------
# Called once per TRACED plan construction with host-static facts
# (token count, executor, policy).  plan_dispatch python-executes only
# while jax traces, so the hook fires exactly at (re)compile events —
# repro.obs wires it to a `moe/plans_traced` counter and a `plan_trace`
# span instant.  Process-global by design (one observability bundle per
# process); the default None costs a single identity check per trace.
_PLAN_HOOK: Optional[Callable[..., None]] = None


def set_plan_hook(hook: Optional[Callable[..., None]]):
    """Install ``hook(tokens=..., executor=..., policy=...)``; returns
    the previous hook so callers (tests, short-lived engines) can
    restore it."""
    global _PLAN_HOOK
    prev, _PLAN_HOOK = _PLAN_HOOK, hook
    return prev


# ----------------------------------------------------------------------
# The two API entry points
# ----------------------------------------------------------------------
def plan_dispatch(x: jnp.ndarray, w_router: jnp.ndarray, cfg, *,
                  with_schedule: Optional[bool] = None) -> DispatchPlan:
    """Phase 1: route + schedule + combine rows + aux, once per batch.

    x: (T, d).  The router projection stays XLA (near-optimal small-N GEMM,
    as in the paper); gating/top-k selection is the executor's (the pallas
    executor runs its fused router kernel).  ``with_schedule`` overrides the
    executor's ``needs_schedule`` — the EP paths pass False and derive
    rank-local layouts from ``plan.indices`` instead.
    """
    ex = get_executor(cfg.executor)
    if _PLAN_HOOK is not None:
        _PLAN_HOOK(tokens=int(x.shape[0]), executor=str(cfg.executor),
                   policy=str(cfg.schedule_policy))
    logits = jnp.dot(x.astype(jnp.float32), w_router.astype(jnp.float32))
    weights, indices = ex.route(logits, cfg)
    aux = router_aux_losses(logits, indices, cfg)

    build = ex.needs_schedule if with_schedule is None else with_schedule
    sched = combine = None
    if build:
        sched = plan_schedule(indices, cfg)
        combine = combine_scale_rows(sched, weights) \
            if cfg.fold_combine else None
        if cfg.emit_stats:
            aux.update({f"sched/{k}": v for k, v
                        in schedule_stats(sched)._asdict().items()})
    return DispatchPlan(weights=weights, indices=indices, logits=logits,
                        schedule=sched, combine_scale=combine, aux=aux)


def execute(plan: DispatchPlan, x: jnp.ndarray, w: dict, cfg,
            executor=None) -> jnp.ndarray:
    """Phase 2: run a plan through a backend.  ``executor`` (name or
    instance) defaults to ``cfg.executor`` — pass another registered name to
    re-execute the SAME plan on a different backend (tested parity)."""
    ex = get_executor(cfg.executor if executor is None else executor)
    return ex.run(x, w, plan, cfg)
