"""``xla`` executor: the paper's block schedule as a `lax.scan` over M-tiles.

Per-step expert-weight gathers, pure jnp: differentiable (the training
path), memory-lean (no (blocks, K, N) weight gather blow-up), compiles at
full scale on any backend — this is what the multi-pod dry-run lowers.
Structurally identical traffic to the Pallas kernel, so its roofline terms
are representative.  Quantized expert weights pass through
``prepare_weights`` untouched: the per-step ``w[be]`` gather IS the
per-block dequant hook — ``QuantTensor.__getitem__`` routes through the
scheme's ``dequantize``, so each scan step gathers compressed bytes and
expands one expert block in-register (any registered scheme).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.execution.base import Executor, register_executor
from repro.kernels import ref
from repro.scheduling import BlockSchedule


def _gemm_blocks_xla(x: jnp.ndarray, sched: BlockSchedule, step_fn):
    M = sched.block_m
    nb = sched.capacity // M
    xb = x.reshape(nb, M, x.shape[-1])

    def step(_, inp):
        xblk, be, active = inp
        out = step_fn(xblk, be)
        out = out * active.astype(out.dtype)
        return None, out

    _, out = jax.lax.scan(step, None,
                          (xb, sched.block_expert, sched.block_active))
    return out.reshape(sched.capacity, -1)


def fused_gate_up_xla(x, w_gate, w_up, sched: BlockSchedule):
    def step(xblk, be):
        wg = w_gate[be]
        wu = w_up[be]
        g = jnp.dot(xblk, wg, preferred_element_type=jnp.float32)
        u = jnp.dot(xblk, wu, preferred_element_type=jnp.float32)
        return ((g * jax.nn.sigmoid(g)) * u).astype(x.dtype)
    return _gemm_blocks_xla(x, sched, step)


def grouped_gemm_xla(x, w, sched: BlockSchedule, row_scale=None):
    out = _gemm_blocks_xla(
        x, sched,
        lambda xblk, be: jnp.dot(xblk, w[be],
                                 preferred_element_type=jnp.float32
                                 ).astype(x.dtype))
    if row_scale is not None:
        out = out * row_scale[:, None].astype(out.dtype)
    return out


@register_executor("xla")
class XlaExecutor(Executor):

    def prepare_weights(self, w, cfg):
        return w            # in-scan dequant: w[be] expands per block

    def permute(self, x, sched, cfg):
        return ref.permute_ref(x, sched)

    def expert_ffn(self, xp, w, sched, cfg, row_scale=None):
        if cfg.fuse_gate_up:
            h = fused_gate_up_xla(xp, w["w_gate"], w["w_up"], sched)
        else:
            g = grouped_gemm_xla(xp, w["w_gate"], sched)
            u = grouped_gemm_xla(xp, w["w_up"], sched)
            gf = g.astype(jnp.float32)
            h = ((gf * jax.nn.sigmoid(gf)) * u.astype(jnp.float32)
                 ).astype(xp.dtype)
        return grouped_gemm_xla(h, w["w_down"], sched, row_scale=row_scale)

    def unpermute(self, y, sched, weights, cfg):
        return ref.unpermute_ref(y, sched, weights)
