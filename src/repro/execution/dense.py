"""``dense`` executor: one-hot dense-over-all-experts oracle.

The paper's "PyTorch reference" baseline — every expert computed on every
token, combined with a routing mask.  O(T*E*ffn) compute, exact semantics;
the correctness ground truth for tests and small benchmarks.  Consumes
only ``plan.weights`` / ``plan.indices``: no permuted layout exists, so the
plan carries no schedule (``needs_schedule = False``) and the phase-level
methods are intentionally unavailable (the EP paths require a
schedule-capable executor such as ``xla`` or ``pallas``).  Quantized
expert weights are materialized to dense stacks up front (the base
``prepare_weights``) — there is no per-block gather to hook a dequant
into, and the oracle's job is exact dense semantics.
"""
from __future__ import annotations

from repro.execution.base import DispatchPlan, Executor, register_executor
from repro.kernels import ref


@register_executor("dense")
class DenseExecutor(Executor):
    needs_schedule = False

    def run(self, x, w, plan: DispatchPlan, cfg):
        w = self.prepare_weights(w, cfg)
        return ref.moe_ffn_dense_ref(x, w["w_gate"], w["w_up"], w["w_down"],
                                     plan.weights, plan.indices)
