"""``pallas`` executor: the paper's technique as Pallas TPU kernels.

The schedule arrays are scalar-prefetch operands, so block-to-expert lookup
happens in SMEM with no host round-trip.  Runs in interpret mode off-TPU
(this container validates on CPU); the compiled target is TPU v5e.
Inference path (forward only).  Routing uses the fused router_topk kernel.

Quantized expert weights pass through ``prepare_weights`` untouched: the
grouped-GEMM kernels take the compressed payload + per-channel scales as
operands and dequantize each DMA'd weight block in-kernel (int8 scale
multiply, or int4 nibble unpack + scale) right before its MXU issue — the
full dense stack never exists in HBM (kernels/ops.py adapts QuantTensors
to the kernel operands).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.execution.base import Executor, register_executor
from repro.kernels import ops


@register_executor("pallas")
class PallasExecutor(Executor):

    def prepare_weights(self, w, cfg):
        return w            # in-kernel dequant: ops.py splits q/s operands

    def route(self, logits, cfg):
        return ops.router_topk(
            logits, top_k=cfg.top_k, gating=cfg.gating,
            norm_topk=cfg.norm_topk, routed_scale=cfg.routed_scale,
            interpret=cfg.interpret)

    def permute(self, x, sched, cfg):
        return ops.permute(x, sched, interpret=cfg.interpret)

    def expert_ffn(self, xp, w, sched, cfg, row_scale=None):
        # cfg.autotune: every kernel call consults the persistent tune
        # cache for its shape key's swept block sizes (repro.tuning)
        at = getattr(cfg, "autotune", False)
        if cfg.fuse_gate_up:
            h = ops.fused_gate_up(xp, w["w_gate"], w["w_up"], sched,
                                  autotune=at, interpret=cfg.interpret)
        else:
            g = ops.grouped_gemm(xp, w["w_gate"], sched, autotune=at,
                                 interpret=cfg.interpret)
            u = ops.grouped_gemm(xp, w["w_up"], sched, autotune=at,
                                 interpret=cfg.interpret)
            gf = g.astype(jnp.float32)
            h = ((gf * jax.nn.sigmoid(gf)) * u.astype(jnp.float32)
                 ).astype(xp.dtype)
        return ops.grouped_gemm(h, w["w_down"], sched, row_scale=row_scale,
                                autotune=at, interpret=cfg.interpret)

    def unpermute(self, y, sched, weights, cfg):
        return ops.unpermute(y, sched, weights, interpret=cfg.interpret)
