"""Admission policies: which pending request gets the next free slot.

Mirrors the schedule-policy registry (repro.scheduling): a policy is a
function ``(pending, *, engine=None) -> int`` returning the index of the
request to admit, registered under a name the engine/launcher select by
flag.  Policies see the whole pending queue so they can reorder (e.g.
shortest-prompt-first reduces head-of-line blocking from long prefills)
and, since the paged cache, the ENGINE — so a policy can consult serving
state such as the prefix-cache index.  Admission never disturbs running
decodes: the engine claims a slot (paged: attaches prefix hits and lets
the prompt chunk-prefill inside the shared step; contiguous: prefills
only its slot's cache row).

* ``fcfs``        — first-come-first-served (submission order; the
                    pre-refactor engine's behavior)
* ``sjf``         — shortest-prompt-first (minimizes time-to-first-token
                    for short requests under prefill contention; FCFS
                    tie-break)
* ``prefix_hit``  — most-cached-prefix-first (paged engine): prefer the
                    request whose prompt has the longest run of blocks
                    already in the prefix-cache index, so warm requests
                    ride their shared blocks before eviction can claim
                    them; ties (including every request on a cold cache,
                    or the contiguous engine) fall back to FCFS.  Probes
                    are memoized per rid until the prefix pool mutates —
                    re-probing every scheduling pass used to re-hash
                    every pending prompt from scratch.
* ``slo``         — TTFT-deadline feasibility (MoE-Inference-Bench
                    framing: goodput under SLO, not raw throughput).
                    Pending requests that can still meet their TTFT
                    deadline are admitted earliest-deadline-first;
                    no-deadline requests follow; deadline-blown requests
                    go last (work-conserving: served only when nothing
                    at-risk waits).  The policy also exposes the
                    ``preempt`` hook the engine's scheduling pass calls:
                    active requests that blew their TTFT deadline before
                    producing a first token, or whose running TPOT is
                    over budget, are preempted (paged: host-side table
                    park; contiguous: resume re-prefills) — but only
                    while a feasible deadline-holder is waiting for the
                    slot, so preemption never burns work speculatively.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Sequence

AdmissionPolicy = Callable[..., int]

_POLICIES: Dict[str, AdmissionPolicy] = {}


def register_admission(name: str):
    def deco(fn: AdmissionPolicy) -> AdmissionPolicy:
        _POLICIES[name] = fn
        return fn
    return deco


def get_admission(name: str) -> AdmissionPolicy:
    if name not in _POLICIES:
        raise ValueError(f"unknown admission policy {name!r}; "
                         f"registered: {sorted(_POLICIES)}")
    return _POLICIES[name]


def available_admission_policies():
    return sorted(_POLICIES)


@register_admission("fcfs")
def fcfs(pending: Sequence, *, engine=None) -> int:
    return 0


@register_admission("sjf")
def shortest_prompt_first(pending: Sequence, *, engine=None) -> int:
    return min(range(len(pending)), key=lambda i: (len(pending[i].prompt), i))


@register_admission("prefix_hit")
def most_cached_prefix_first(pending: Sequence, *, engine=None) -> int:
    """Longest currently-cached prefix wins; FCFS tie-break.  Falls back
    to FCFS when no paged prefix index is available.  Probes memoize per
    rid inside the cache (invalidated when the hash index mutates), so a
    stable queue costs one chained-sha256 walk per request, not one per
    scheduling pass."""
    kv = getattr(engine, "kv", None)
    if kv is None or not getattr(kv, "prefix_cache", False):
        return 0
    return min(range(len(pending)),
               key=lambda i: (-kv.probe_prefix(pending[i].prompt,
                                               memo_key=pending[i].rid), i))


# ----------------------------------------------------------------------
# SLO-aware admission + preemption (the serving front-end's policy)
# ----------------------------------------------------------------------
def _prefill_steps(engine, prompt) -> int:
    """Engine steps from slot claim to first token for ``prompt``."""
    if engine is None or not getattr(engine, "paged", False):
        return 1                       # contiguous: one admission prefill
    kv = engine.kv
    cached = kv.probe_prefix(prompt, memo_key=None) if kv.prefix_cache \
        else 0
    todo = max(1, len(prompt) - cached)   # >= 1: final token always runs
    return math.ceil(todo / engine.prefill_chunk)


def _ttft_feasible(engine, req, now: float) -> bool:
    """Can ``req`` still meet its TTFT deadline if admitted right now?"""
    if req.slo_ttft is None:
        return True
    submit = engine._submit.get(req.rid, now)
    est = _prefill_steps(engine, req.prompt) * engine.step_time_estimate()
    return now + est <= submit + req.slo_ttft


def _tpot_feasible(engine, req) -> bool:
    """Can the engine's current decode pace meet ``req``'s TPOT budget?

    One decode token costs one engine step, so the ``step_time_hint`` /
    measured-EWMA estimate IS the expected TPOT — a request demanding a
    faster pace than the engine delivers is infeasible at admit time, not
    just at the post-hoc preemption check.  A 0.0 estimate (no step timed
    yet, no hint) prices every budget as feasible."""
    if req.slo_tpot is None:
        return True
    return engine.step_time_estimate() <= req.slo_tpot


@register_admission("slo")
def slo(pending: Sequence, *, engine=None) -> int:
    """Earliest-feasible-deadline first, pricing BOTH SLO families.

    Rank groups: (0) deadline-holders whose TTFT deadline is reachable
    AND whose TPOT budget the engine's current pace can hold, by
    deadline; (1) requests with no deadline, FCFS; (2) blown/hopeless
    requests — TTFT unreachable or TPOT infeasible — by deadline
    (work-conserving backfill: served only when nothing at-risk waits).
    Feasibility prices remaining prefill steps and decode pace at the
    engine's measured (or hinted) step cost."""
    if engine is None:
        return 0
    now = engine._clock()

    def key(i):
        r = pending[i]
        if r.slo_ttft is None and r.slo_tpot is None:
            return (1, 0.0, i)
        feasible = _ttft_feasible(engine, r, now) \
            and _tpot_feasible(engine, r)
        deadline = engine._submit.get(r.rid, now) + r.slo_ttft \
            if r.slo_ttft is not None else now
        return (0 if feasible else 2, deadline, i)

    return min(range(len(pending)), key=key)


def _slo_preempt(engine, pending: Sequence) -> List[int]:
    """Slots to preempt this scheduling pass (engine.schedule hook).

    A victim is an active request that already lost its own SLO — TTFT
    deadline unreachable with no first token out yet, or running TPOT
    over budget — and preemption is throttled to the number of FEASIBLE
    deadline-holders waiting, so an empty (or hopeless) queue never
    triggers it."""
    if engine is None or engine.n_active < engine.slots:
        return []                      # a free slot exists: just admit
    now = engine._clock()
    demand = sum(1 for r in pending
                 if (r.slo_ttft is not None or r.slo_tpot is not None)
                 and _ttft_feasible(engine, r, now)
                 and _tpot_feasible(engine, r))
    if demand == 0:
        return []
    step_s = engine.step_time_estimate()
    victims = []
    for s in range(engine.n_active):
        r = engine.active[s]
        tl = engine._timing.get(r.rid)
        if tl is None:
            continue
        if r.slo_ttft is not None and not r.out:
            # still prefilling: is the first token now unreachable?
            seq = engine._seq[s]
            left = len(seq) - int(engine._prefill_next[s])
            steps = math.ceil(max(1, left) / engine.prefill_chunk)
            if now + steps * step_s > tl.submit + r.slo_ttft:
                victims.append(s)
                continue
        if r.slo_tpot is not None and len(tl.token_times) > 1:
            pace = (tl.token_times[-1] - tl.first_token) \
                / (len(tl.token_times) - 1)
            if pace > r.slo_tpot:
                victims.append(s)
    return victims[:demand]


slo.preempt = _slo_preempt
