"""Admission policies: which pending request gets the next free slot.

Mirrors the schedule-policy registry (repro.scheduling): a policy is a
function ``(pending: Sequence[Request]) -> int`` returning the index of the
request to admit, registered under a name the engine/launcher select by
flag.  Policies see the whole pending queue so they can reorder (e.g.
shortest-prompt-first reduces head-of-line blocking from long prefills),
but admission never disturbs running decodes: the engine prefills into a
free slot row of the batched cache while the other slots' rows are
untouched.

* ``fcfs``  — first-come-first-served (submission order; the pre-refactor
              engine's behavior)
* ``sjf``   — shortest-prompt-first (minimizes time-to-first-token for
              short requests under prefill contention; FCFS tie-break)
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence

AdmissionPolicy = Callable[[Sequence], int]

_POLICIES: Dict[str, AdmissionPolicy] = {}


def register_admission(name: str):
    def deco(fn: AdmissionPolicy) -> AdmissionPolicy:
        _POLICIES[name] = fn
        return fn
    return deco


def get_admission(name: str) -> AdmissionPolicy:
    if name not in _POLICIES:
        raise ValueError(f"unknown admission policy {name!r}; "
                         f"registered: {sorted(_POLICIES)}")
    return _POLICIES[name]


def available_admission_policies():
    return sorted(_POLICIES)


@register_admission("fcfs")
def fcfs(pending: Sequence) -> int:
    return 0


@register_admission("sjf")
def shortest_prompt_first(pending: Sequence) -> int:
    return min(range(len(pending)), key=lambda i: (len(pending[i].prompt), i))
