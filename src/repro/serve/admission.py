"""Admission policies: which pending request gets the next free slot.

Mirrors the schedule-policy registry (repro.scheduling): a policy is a
function ``(pending, *, engine=None) -> int`` returning the index of the
request to admit, registered under a name the engine/launcher select by
flag.  Policies see the whole pending queue so they can reorder (e.g.
shortest-prompt-first reduces head-of-line blocking from long prefills)
and, since the paged cache, the ENGINE — so a policy can consult serving
state such as the prefix-cache index.  Admission never disturbs running
decodes: the engine claims a slot (paged: attaches prefix hits and lets
the prompt chunk-prefill inside the shared step; contiguous: prefills
only its slot's cache row).

* ``fcfs``        — first-come-first-served (submission order; the
                    pre-refactor engine's behavior)
* ``sjf``         — shortest-prompt-first (minimizes time-to-first-token
                    for short requests under prefill contention; FCFS
                    tie-break)
* ``prefix_hit``  — most-cached-prefix-first (paged engine): prefer the
                    request whose prompt has the longest run of blocks
                    already in the prefix-cache index, so warm requests
                    ride their shared blocks before eviction can claim
                    them; ties (including every request on a cold cache,
                    or the contiguous engine) fall back to FCFS.
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence

AdmissionPolicy = Callable[..., int]

_POLICIES: Dict[str, AdmissionPolicy] = {}


def register_admission(name: str):
    def deco(fn: AdmissionPolicy) -> AdmissionPolicy:
        _POLICIES[name] = fn
        return fn
    return deco


def get_admission(name: str) -> AdmissionPolicy:
    if name not in _POLICIES:
        raise ValueError(f"unknown admission policy {name!r}; "
                         f"registered: {sorted(_POLICIES)}")
    return _POLICIES[name]


def available_admission_policies():
    return sorted(_POLICIES)


@register_admission("fcfs")
def fcfs(pending: Sequence, *, engine=None) -> int:
    return 0


@register_admission("sjf")
def shortest_prompt_first(pending: Sequence, *, engine=None) -> int:
    return min(range(len(pending)), key=lambda i: (len(pending[i].prompt), i))


@register_admission("prefix_hit")
def most_cached_prefix_first(pending: Sequence, *, engine=None) -> int:
    """Longest currently-cached prefix wins; FCFS tie-break.  Falls back
    to FCFS when no paged prefix index is available."""
    kv = getattr(engine, "kv", None)
    if kv is None or not getattr(kv, "prefix_cache", False):
        return 0
    return min(range(len(pending)),
               key=lambda i: (-kv.probe_prefix(pending[i].prompt), i))
