"""Open-stream serving front-end: request queue + token streaming.

``ServeEngine.run()`` is a CLOSED batch API — hand it every request up
front, get the finished batch back.  Production traffic is an open
stream: requests arrive while others decode, and callers want tokens as
they are produced, not at retirement.  This module is that front end
(DESIGN.md §11), deliberately thin over the engine:

* **submit()** stamps the request's queue-wait origin (the engine's
  ``lat/queue_wait_s`` measures from here) and registers an optional
  per-request streaming callback.  Nothing runs — admission happens
  inside the next ``poll()``, under whatever admission policy the engine
  was built with (the ``slo`` policy preempts through the same pass).
* **poll()** drives one (or more) scheduling pass + engine step and
  returns the requests that finished during it.  Token callbacks fire
  from the engine's ``on_token`` hook — the moment the step's ONE host
  sync retires each token into ``Request.out``.  Streaming therefore
  adds ZERO device syncs, and the streamed sequence is bitwise-identical
  to what a closed-batch ``run()`` would produce (asserted in
  tests/test_serve.py across dense/MoE x paged/contiguous).
* **drain()** polls until the queue is empty or a step budget runs out,
  finalizing censored ``lat/*`` stats on anything still unfinished —
  the open-stream analogue of ``run()``'s drop handling.

One frontend owns one engine: constructing it installs the engine's
``on_token`` hook.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.serve.engine import Request, ServeEngine

TokenCallback = Callable[[Request, int], None]


class ServingFrontend:
    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self.pending: List[Request] = []
        self._inflight: Dict[int, Request] = {}     # rid -> submitted req
        self._callbacks: Dict[int, TokenCallback] = {}
        self._rids = itertools.count()
        engine.on_token = self._on_token

    # -- submission ----------------------------------------------------
    def submit(self, prompt, *, max_new: int = 16, eos: Optional[int] = None,
               rid: Optional[int] = None,
               slo_ttft: Optional[float] = None,
               slo_tpot: Optional[float] = None,
               seed: Optional[int] = None,
               on_token: Optional[TokenCallback] = None) -> Request:
        """Enter one request into the open queue; returns the Request as
        the caller's handle (poll ``.done`` / ``.out``, or stream via
        ``on_token(req, tok)``).  The queue-wait clock starts HERE.
        ``seed`` keys this request's stochastic sampling stream
        (repro.sampling); None derives one from the engine base + rid."""
        if rid is None:
            rid = next(self._rids)
            while rid in self._inflight:
                rid = next(self._rids)
        elif rid in self._inflight:
            raise ValueError(f"rid {rid} is already in flight")
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new=max_new, eos=eos,
                      slo_ttft=slo_ttft, slo_tpot=slo_tpot, seed=seed)
        self.engine.enqueue([req])     # stamps lat/queue_wait_s origin
        self.pending.append(req)
        self._inflight[rid] = req
        if on_token is not None:
            self._callbacks[rid] = on_token
        return req

    def _on_token(self, req: Request, tok: int) -> None:
        cb = self._callbacks.get(req.rid)
        if cb is not None:
            cb(req, tok)

    # -- introspection -------------------------------------------------
    @property
    def outstanding(self) -> int:
        """Requests submitted but not yet finished (queued + active +
        preempted-awaiting-resume)."""
        return sum(1 for r in self._inflight.values() if not r.done)

    # -- driving -------------------------------------------------------
    def poll(self, steps: int = 1) -> List[Request]:
        """Advance the engine by up to ``steps`` scheduling passes +
        engine steps; fire streaming callbacks; return the requests that
        COMPLETED during this poll (retired handles leave the in-flight
        table, so each completion is reported exactly once)."""
        done: List[Request] = []
        for _ in range(max(1, steps)):
            self.engine.schedule(self.pending)
            n = self.engine.step()
            for rid in [rid for rid, r in self._inflight.items() if r.done]:
                done.append(self._inflight.pop(rid))
                self._callbacks.pop(rid, None)
            if n == 0 and not self.pending:
                break                  # idle: nothing left to schedule
        return done

    def drain(self, max_steps: int = 512) -> List[Request]:
        """Poll until every submitted request finished or the step budget
        runs out.  Unfinished requests get finite censored ``lat/*``
        stats (engine.finalize_drops) and stay resumable via a later
        poll/drain."""
        done: List[Request] = []
        for _ in range(max_steps):
            done.extend(self.poll())
            if not self.outstanding:
                break
        leftovers = [r for r in self._inflight.values() if not r.done]
        if leftovers:
            self.engine.finalize_drops(leftovers)
        return done
