"""Multi-host serving: per-host admission feeding ONE global decode step.

The SPMD serving pattern (X-MoE): every process runs the same host-side
control flow over the same deterministic request partition, so the jitted
global step — whose collectives (EP all_to_all, psum) span hosts — is
entered by all processes in lockstep with identical slot assignments.
Anything nondeterministic in admission would desynchronize the mesh, so
this loop is built from deterministic pieces only:

* ``partition_requests`` — stable round-robin assignment of requests to
  host queues (by submission index, not hash seeds).
* per-host admission — each host queue gets its OWN admission-policy
  instance (the registered policies are pure functions of queue + engine
  state, so every process computes the same choice for every host).
* one global engine — ``DistributedServeLoop`` drains the host queues
  round-robin into the single ``ServeEngine``'s free slots and drives its
  step loop; the engine's decode step is the one global computation.

On a real multi-host mesh each process feeds only tokens for its local
shard, but the control flow here is identical; the CPU fallback (forced
host device count, one process) runs the same code on a local mesh —
``launch.mesh.multiprocess_compute_supported`` decides which one the
launcher builds.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.serve.admission import get_admission
from repro.serve.engine import Request, ServeEngine


def partition_requests(requests: Sequence[Request],
                       n_hosts: int) -> List[List[Request]]:
    """Deterministic round-robin partition of ``requests`` into
    ``n_hosts`` queues (submission order preserved inside each queue).
    Every process must compute the SAME partition, so the rule is a pure
    function of submission index — never of hash seeds or clocks."""
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    parts: List[List[Request]] = [[] for _ in range(n_hosts)]
    for i, r in enumerate(requests):
        parts[i % n_hosts].append(r)
    return parts


class DistributedServeLoop:
    """Drive one global ``ServeEngine`` from per-host admission queues.

    ``run`` mirrors ``ServeEngine.run``'s contract (returns completed
    requests, strands the rest in ``engine.dropped``) but admission is
    two-level: each host's queue is ordered by its own admission policy,
    and free slots rotate across hosts round-robin so no host starves
    even when another's queue is long.  With ``n_hosts=1`` this is
    exactly the single-host engine loop."""

    def __init__(self, engine: ServeEngine, *, n_hosts: int = 1,
                 admission: str = "fcfs"):
        self.engine = engine
        self.n_hosts = n_hosts
        self._admission = [get_admission(admission)
                           for _ in range(n_hosts)]
        self._rr = 0          # next host to offer a slot to

    def schedule(self, queues: List[List[Request]]) -> None:
        """Fill free engine slots, one per non-empty host queue in
        round-robin order; each host's pick comes from ITS admission
        policy over ITS queue."""
        eng = self.engine
        while eng.n_active < eng.slots and any(queues):
            for _ in range(self.n_hosts):
                h = self._rr % self.n_hosts
                self._rr += 1
                if queues[h]:
                    pick = self._admission[h](queues[h], engine=eng)
                    eng.admit(queues[h].pop(pick))
                    break

    def run(self, requests: Sequence[Request], max_steps: int = 512,
            parts: Optional[List[List[Request]]] = None):
        """Partition, admit per host, step the global engine to
        completion (or the step budget).  ``parts`` overrides the default
        round-robin partition (e.g. a locality-aware router)."""
        eng = self.engine
        if parts is None:
            parts = partition_requests(requests, self.n_hosts)
        queues = [eng.enqueue(p) for p in parts]
        eng.dropped = []
        for _ in range(max_steps):
            self.schedule(queues)
            if eng.step() == 0 and not any(queues):
                break
        eng.dropped = [r for r in requests if not r.done]
        if eng.dropped:
            eng.finalize_drops(eng.dropped)
            eng.obs.metrics.inc("serve/dropped", len(eng.dropped))
        return [r for r in requests if r.done]
