"""Paged KV cache: a global block pool + per-slot block tables.

vLLM's memory model scaled to this container (DESIGN.md §9).  The serving
cache is no longer one contiguous ``(slots, capacity)`` buffer: the device
holds a pool of ``n_blocks`` fixed-size blocks per cache leaf — the SAME
pytree ``init_cache`` builds, with (batch=n_blocks, seq=block_size) — and
each slot owns a host-side *block table* mapping its logical block index
``pos // block_size`` to a physical block id.  The forward pass reads
through the table with plain jnp gathers (models/attention.py
``gather_block_kv``) and writes with per-token block-granular scatters
(``scatter_block_rows``, the paged sibling of ``scatter_decode_row``).

Control plane is host-side numpy/python (allocation, refcounts, hashes);
data plane is device arrays.  That split is deliberate: block management
runs once per engine step over a handful of ints, while every traced step
sees only dense int32 table rows — no host sync inside jit.

**Prefix caching.**  Full prompt blocks are content-addressed by a CHAINED
hash (block i's digest covers tokens [0, (i+1)*block_size)), so a hit
means the entire prefix matches, not just one block's tokens.  Hit blocks
are attached to the new slot's table and refcounted; their KV is never
recomputed and the tokens they cover never enter a dispatch plan (the
engine starts chunked prefill at ``n_cached``).  Only FULL prompt blocks
are ever shared, so shared blocks are immutable — decode appends always
land in slot-private blocks and copy-on-write is never needed.  Retirement
decrements refcounts; refcount-0 blocks that carry a registered hash are
parked in an LRU "cached free" pool (contents preserved for future hits)
and are evicted only when a fresh allocation finds the free list empty.

**Invariant.**  ``n_blocks = slots * ceil(capacity / block_size)`` — the
worst case (no sharing) is exactly the contiguous layout's footprint, and
sharing strictly frees blocks, so allocation can never fail.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import group_structure, init_cache

# block kinds whose caches are positional KV rows — the only thing a block
# pool can page.  Recurrent state (rwkv/ssm) and the fixed image KV of the
# vlm cross blocks have no sequence axis to page over; those families fall
# back to the contiguous engine.
PAGED_KINDS = frozenset(
    {"attn", "attn_local", "attn_global", "moe", "moe_dense"})


def paged_supported(cfg: ModelConfig) -> bool:
    """True when every layer's cache is positional KV (pageable)."""
    prefix, body, _, suffix = group_structure(cfg)
    return all(k in PAGED_KINDS for k in (*prefix, *body, *suffix))


def _chain_digest(prev: bytes, block_tokens: np.ndarray) -> bytes:
    """Chained content hash: covers the whole prefix up to this block."""
    return hashlib.sha256(prev + np.ascontiguousarray(
        block_tokens.astype(np.int32)).tobytes()).digest()


class PagedKVCache:
    """Block pool + per-slot tables + refcounted prefix index."""

    def __init__(self, cfg: ModelConfig, slots: int, capacity: int,
                 block_size: int, *, prefix_cache: bool = True,
                 dtype=jnp.float32):
        if block_size <= 0:
            raise ValueError(f"block_size must be positive, got {block_size}")
        if not paged_supported(cfg):
            raise ValueError(
                "paged KV cache needs every layer cache to be positional "
                f"KV; {cfg.name!r} has non-pageable (recurrent/cross) "
                "block caches — use the contiguous engine (kv_block_size=0)")
        self.cfg = cfg
        self.slots = slots
        self.capacity = capacity
        self.block_size = block_size
        self.blocks_per_slot = -(-capacity // block_size)
        self.n_blocks = slots * self.blocks_per_slot
        # the pool IS an init_cache pytree with (batch=n_blocks,
        # seq=block_size): every slot-view helper and the forward scan
        # consume it unchanged — the block axis simply replaces the slot
        # axis (0 for prefix/suffix leaves, 1 for the stacked body).
        self.pools = init_cache(cfg, self.n_blocks, block_size, dtype)
        self.tables = np.zeros((slots, self.blocks_per_slot), np.int32)
        self.n_alloc = np.zeros(slots, np.int32)      # allocated entries/slot
        self.refcount = np.zeros(self.n_blocks, np.int64)
        self.free: List[int] = list(range(self.n_blocks - 1, -1, -1))
        self.prefix_cache = prefix_cache
        self._hash_to_block: Dict[bytes, int] = {}
        self._block_hash: Dict[int, bytes] = {}
        # refcount-0 blocks with preserved contents, LRU eviction order
        self._cached_free: "OrderedDict[int, None]" = OrderedDict()
        # per-slot chained-hash cursor for registering blocks as they fill:
        # (next block index to register, digest of the chain before it)
        self._chain: Dict[int, tuple] = {}
        # preempted requests' parked tables (key -> {table, n_alloc, chain}),
        # LRU order: blocks stay refcounted (contents pinned) until the
        # request resumes or allocation pressure reclaims the record
        self._parked: "OrderedDict[object, dict]" = OrderedDict()
        # read-only probe memo (admission policies re-probe every step):
        # key -> (index generation, cached token count); any hash-index
        # mutation bumps the generation and drops the whole memo
        self._probe_gen = 0
        self._probe_memo: Dict[object, tuple] = {}
        self.hits = self.misses = self.evictions = 0
        self.park_reclaims = 0
        self.hit_tokens = 0
        # observability sinks (repro.obs; null by default — bind_obs()):
        # block alloc/evict/compaction become counters + trace instants
        from repro.obs import NULL_METRICS, NULL_TRACER
        self._metrics = NULL_METRICS
        self._tracer = NULL_TRACER

    def bind_obs(self, metrics, tracer) -> None:
        """Attach metrics/tracer sinks (the engine binds its bundle).
        Pool events are host-side control-plane work, so instrumenting
        them never touches the traced step."""
        self._metrics = metrics
        self._tracer = tracer

    # -- allocation ----------------------------------------------------
    def _index_mutated(self) -> None:
        """The hash index changed: read-only probe results are stale."""
        self._probe_gen += 1
        self._probe_memo.clear()

    def _alloc_block(self) -> int:
        if self.free:
            self._metrics.inc("kv/blocks_allocated")
            return self.free.pop()
        while not self._cached_free and self._parked:
            self._reclaim_parked()     # may refill free OR cached_free
            if self.free:
                self._metrics.inc("kv/blocks_allocated")
                return self.free.pop()
        if not self._cached_free:
            raise RuntimeError("paged pool exhausted — broken refcounting "
                               "(n_blocks guarantees worst-case capacity)")
        b, _ = self._cached_free.popitem(last=False)   # evict LRU
        digest = self._block_hash.pop(b)
        del self._hash_to_block[digest]
        self._index_mutated()
        self.evictions += 1
        self._metrics.inc("kv/blocks_allocated")
        self._metrics.inc("kv/evictions")
        self._tracer.instant("kv/evict", block=b)
        return b

    def _release_blocks(self, table: np.ndarray, n_alloc: int) -> None:
        for j in range(n_alloc):
            b = int(table[j])
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                if b in self._block_hash:
                    self._cached_free[b] = None    # park: contents reusable
                else:
                    self.free.append(b)

    def _reclaim_parked(self) -> None:
        """Allocation pressure: sacrifice the LRU parked (preempted) table
        so decoding slots never starve.  The victim's resume will find no
        record and falls back to re-prefill — strictly a latency cost,
        never a correctness one."""
        key, rec = self._parked.popitem(last=False)
        self._release_blocks(rec["table"], rec["n_alloc"])
        self.park_reclaims += 1
        self._metrics.inc("kv/park_reclaims")
        self._tracer.instant("kv/park_reclaim", key=str(key))

    def ensure_allocated(self, slot: int, last_pos: int) -> None:
        """Grow ``slot``'s table so position ``last_pos`` is addressable.

        Positions at/past the slot's addressable capacity get no block —
        their writes are DROPPED by ``scatter_block_rows`` (OOB scatter
        semantics), exactly like the contiguous cache's out-of-bounds
        decode write at the capacity edge; the engine's ``capacity - 1``
        retirement rule fires on the same step.  Whole prompts are
        validated against capacity at admission instead."""
        need = min(last_pos // self.block_size + 1, self.blocks_per_slot)
        while self.n_alloc[slot] < need:
            b = self._alloc_block()
            self.tables[slot, self.n_alloc[slot]] = b
            self.refcount[b] += 1
            self.n_alloc[slot] += 1

    # -- prefix caching ------------------------------------------------
    def attach_prefix(self, slot: int, prompt: np.ndarray) -> int:
        """Admission-time lookup: attach the longest run of hash-hit full
        prompt blocks to ``slot``; returns the number of cached TOKENS.

        At least one prompt token is always left uncached — its logits
        seed the first generated token — so a fully-cached prompt still
        runs a one-token chunk."""
        bs = self.block_size
        prompt = np.asarray(prompt)
        max_full = min((len(prompt) - 1) // bs, self.blocks_per_slot)
        digest = b""
        n_hit = 0
        if self.prefix_cache:
            with self._tracer.span("serve/prefix_probe", slot=slot,
                                   prompt_tokens=len(prompt)):
                for i in range(max_full):
                    nxt = _chain_digest(digest, prompt[i * bs:(i + 1) * bs])
                    b = self._hash_to_block.get(nxt)
                    if b is None:
                        self.misses += 1
                        self._metrics.inc("kv/prefix_misses")
                        break
                    digest = nxt
                    if self.refcount[b] == 0:           # revive parked block
                        self._cached_free.pop(b)
                    self.refcount[b] += 1
                    self.tables[slot, i] = b
                    self.n_alloc[slot] += 1
                    self.hits += 1
                    self._metrics.inc("kv/prefix_hits")
                    n_hit = i + 1
        self._chain[slot] = (n_hit, digest)
        self.hit_tokens += n_hit * bs
        self._metrics.inc("kv/prefix_hit_tokens", n_hit * bs)
        return n_hit * bs

    def probe_prefix(self, prompt: np.ndarray, *, memo_key=None) -> int:
        """Read-only lookup: how many TOKENS of ``prompt`` the index can
        currently serve from shared blocks (no attach, no refcounts) —
        what admission policies consult to prefer warm-prefix requests.

        ``memo_key`` (typically the request's rid) memoizes the answer
        until the hash index next mutates: admission policies probe every
        pending request every scheduling pass, and without the memo each
        pass re-hashes every pending prompt from scratch."""
        if not self.prefix_cache:
            return 0
        if memo_key is not None:
            hit = self._probe_memo.get(memo_key)
            if hit is not None and hit[0] == self._probe_gen:
                return hit[1]
        bs = self.block_size
        prompt = np.asarray(prompt)
        max_full = min((len(prompt) - 1) // bs, self.blocks_per_slot)
        digest = b""
        n = 0
        for i in range(max_full):
            digest = _chain_digest(digest, prompt[i * bs:(i + 1) * bs])
            if digest not in self._hash_to_block:
                break
            n = i + 1
        if memo_key is not None:
            self._probe_memo[memo_key] = (self._probe_gen, n * bs)
        return n * bs

    def register_filled(self, slot: int, prompt: np.ndarray,
                        n_processed: int) -> None:
        """Register every newly FULL prompt block of ``slot`` (called after
        a prefill chunk lands; ``n_processed`` counts prompt tokens whose
        KV is now written).  Content-addressing stays valid because block
        KV depends only on the token prefix (greedy, fixed params)."""
        if not self.prefix_cache or slot not in self._chain:
            return
        bs = self.block_size
        i, digest = self._chain[slot]
        while (i + 1) * bs <= n_processed:
            digest = _chain_digest(digest, prompt[i * bs:(i + 1) * bs])
            b = int(self.tables[slot, i])
            if digest not in self._hash_to_block:
                self._hash_to_block[digest] = b
                self._block_hash[b] = digest
                self._index_mutated()
            i += 1
        self._chain[slot] = (i, digest)

    def truncate_slot(self, slot: int, n_tokens: int) -> int:
        """Roll ``slot``'s logical sequence back to its first ``n_tokens``
        positions — the speculative-decoding rollback (DESIGN.md §13):
        rejected draft tokens' KV lives in positions >= n_tokens, and
        dropping it is pure host-side bookkeeping.

        Whole blocks past ``ceil(n_tokens / block_size)`` are released
        (refcount decrement; a refcount-0 block with a registered hash
        parks in the LRU cached-free pool exactly like retirement).  The
        kept tail block may still hold stale rows past ``n_tokens`` —
        harmless: reads mask by each row's own kv_limit and the next
        write at that position scatters over them in place.  Prefix-hash
        registration past the truncation point is invalidated by
        rewinding the slot's chain cursor (registered hashes only ever
        cover full PROMPT blocks, which a speculative rollback never
        cuts into — the defensive drop below covers direct callers).
        Returns the number of blocks freed."""
        keep = 0 if n_tokens <= 0 else min(-(-n_tokens // self.block_size),
                                           self.blocks_per_slot)
        na = int(self.n_alloc[slot])
        if keep >= na:
            return 0
        for j in range(keep, na):
            b = int(self.tables[slot, j])
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                if b in self._block_hash:
                    self._cached_free[b] = None
                else:
                    self.free.append(b)
        self.tables[slot, keep:na] = 0
        self.n_alloc[slot] = keep
        ch = self._chain.get(slot)
        if ch is not None and ch[0] > keep:
            # the chain digest past ``keep`` covers tokens that no longer
            # exist; it cannot be rewound (digests chain forward only) —
            # stop registering for this slot rather than register stale
            # content
            del self._chain[slot]
        freed = na - keep
        self._metrics.inc("kv/blocks_truncated", freed)
        self._tracer.instant("kv/truncate", slot=slot, n_tokens=n_tokens,
                             freed=freed)
        return freed

    # -- release / park / views ----------------------------------------
    def release_slot(self, slot: int) -> None:
        self._release_blocks(self.tables[slot], int(self.n_alloc[slot]))
        self.tables[slot, :] = 0
        self.n_alloc[slot] = 0
        self._chain.pop(slot, None)

    def park_slot(self, slot: int, key) -> None:
        """Preemption: detach ``slot``'s table into a parked record under
        ``key`` (the request's rid).  Blocks KEEP their refcounts, so the
        request's KV survives intact for a host-side-only resume; under
        allocation pressure the LRU record is reclaimed instead (the
        resume then re-prefills).  The slot itself leaves empty."""
        self._parked[key] = {"table": self.tables[slot].copy(),
                             "n_alloc": int(self.n_alloc[slot]),
                             "chain": self._chain.get(slot)}
        self.tables[slot, :] = 0
        self.n_alloc[slot] = 0
        self._chain.pop(slot, None)
        self._metrics.inc("kv/tables_parked")
        self._tracer.instant("kv/park", slot=slot, key=str(key))

    def resume_slot(self, slot: int, key) -> bool:
        """Re-attach the parked table under ``key`` to (empty) ``slot``.
        False when the record was reclaimed for allocation pressure — the
        caller must re-prefill instead."""
        rec = self._parked.pop(key, None)
        if rec is None:
            return False
        assert self.n_alloc[slot] == 0, "resume target slot must be empty"
        self.tables[slot] = rec["table"]
        self.n_alloc[slot] = rec["n_alloc"]
        if rec["chain"] is not None:
            self._chain[slot] = rec["chain"]
        self._metrics.inc("kv/tables_resumed")
        self._tracer.instant("kv/resume", slot=slot, key=str(key))
        return True

    def drop_parked(self, key) -> None:
        """Discard a parked record (the request will never resume)."""
        rec = self._parked.pop(key, None)
        if rec is not None:
            self._release_blocks(rec["table"], rec["n_alloc"])

    def move_slot(self, dst: int, src: int) -> None:
        """Host-side slot compaction (the paged analogue of the contiguous
        engine's device row swap): tables are bookkeeping, so moving a
        request between slots is two numpy row writes."""
        self.tables[dst] = self.tables[src]
        self.n_alloc[dst] = self.n_alloc[src]
        if src in self._chain:
            self._chain[dst] = self._chain.pop(src)
        elif dst in self._chain:
            del self._chain[dst]
        self.tables[src] = 0
        self.n_alloc[src] = 0
        self._metrics.inc("kv/compactions")
        self._tracer.instant("kv/compaction", src=src, dst=dst)

    def table_rows(self, slot_ids) -> np.ndarray:
        """(len(slot_ids), blocks_per_slot) int32 rows for a step batch."""
        return self.tables[np.asarray(slot_ids, np.int64)]

    # -- metamorphic helper (tests/benchmarks) -------------------------
    def permute_physical_blocks(self, perm) -> None:
        """Relabel physical block ids: new id of block ``b`` is
        ``perm[b]``.  Pool contents move with their ids (device gather) and
        every host structure is remapped — greedy tokens must be invariant
        (asserted in tests/test_serve.py): the table indirection is the
        ONLY consumer of physical ids."""
        perm = np.asarray(perm, np.int64)
        assert sorted(perm.tolist()) == list(range(self.n_blocks))
        inv = jnp.asarray(np.argsort(perm), jnp.int32)
        from repro.models.lm import _map_cache
        self.pools = _map_cache(
            lambda ax, l: jnp.take(l, inv, axis=ax), self.pools)
        self.tables = perm[self.tables].astype(np.int32)
        self.refcount = self.refcount[np.argsort(perm)]
        self.free = [int(perm[b]) for b in self.free]
        self._hash_to_block = {h: int(perm[b])
                               for h, b in self._hash_to_block.items()}
        self._block_hash = {int(perm[b]): h
                            for b, h in self._block_hash.items()}
        self._cached_free = OrderedDict(
            (int(perm[b]), None) for b in self._cached_free)
        for rec in self._parked.values():
            rec["table"] = perm[rec["table"]].astype(np.int32)

    # -- introspection -------------------------------------------------
    def stats(self) -> dict:
        in_use = int((self.refcount > 0).sum())
        return {"blocks_total": self.n_blocks, "blocks_in_use": in_use,
                "blocks_parked": len(self._cached_free),
                "prefix_hits": self.hits, "prefix_misses": self.misses,
                "prefix_hit_tokens": self.hit_tokens,
                "evictions": self.evictions,
                "parked_tables": len(self._parked),
                "park_reclaims": self.park_reclaims}
