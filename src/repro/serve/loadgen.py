"""Trace-driven load generator: replay open-stream arrival patterns
against the serving front-end and score goodput under SLO.

MoE-Inference-Bench (PAPERS.md, 2508.17467) characterizes production MoE
serving by its arrival patterns — Poisson steady state, bursts, fleets
of shared-prefix requests, long-tail prompt lengths — and the MoE
inference survey (2412.14219) argues the number production buys is
GOODPUT: completions that met their latency SLOs, per second.  This
module turns those shapes into deterministic, seeded traces and replays
them through ``ServingFrontend``, recording exactly that.

**Virtual time.**  Replays run on a ``VirtualClock`` injected as the
observability clock: every engine step advances it by a fixed
``step_time``, and arrivals/deadlines/latency stamps all read it.  The
whole replay — tokens, admission order, preemptions, TTFT/TPOT
percentiles, goodput — is then a pure function of (trace seed, engine
config), so benchmark assertions like "``slo`` admission beats ``fcfs``
on the burst workload" are reproducible in CI instead of racing the
host's scheduler.

**Wall-clock calibration.**  ``step_time=None`` keeps the virtual
timeline but scales it by MEASUREMENT: each engine step is timed with
``time.perf_counter`` and the clock advances by an EWMA of the measured
step wall time (the engine's own ``_ewma_step_s`` is useless here — it
reads the injected virtual clock).  Goodput/SLO numbers then reflect
the host's real step cost while arrivals stay trace-deterministic; the
measured EWMA and the calibration mode are recorded in the artifact's
config block so a reader can tell the two timelines apart.

Artifacts land in ``results/serve/loadgen_<arch>.json`` via
``benchmarks/serve_loadgen.py`` / ``repro.launch.serve --loadgen``;
``analysis/report.py`` renders the goodput table.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.obs import Observability, latency_summary
from repro.serve.frontend import ServingFrontend

PATTERNS = ("poisson", "burst", "shared_prefix", "longtail")


class VirtualClock:
    """A deterministic clock the replay advances by hand (one engine
    step = ``step_time`` virtual seconds).  Inject as the engine's
    observability clock so every latency stamp reads replay time."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        self.now += dt
        return self.now


@dataclasses.dataclass
class TraceEvent:
    t: float                           # arrival time (virtual seconds)
    prompt: np.ndarray                 # (P,) int32
    max_new: int
    slo_ttft: Optional[float] = None
    slo_tpot: Optional[float] = None


def synth_trace(pattern: str, *, seed: int, n: int, rate: float,
                vocab: int, max_new: int = 8,
                slo_ttft: Optional[float] = None,
                slo_tpot: Optional[float] = None,
                prompt_lo: int = 4, prompt_hi: int = 12,
                burst_size: int = 4, prefix_len: int = 16,
                tail_len: int = 48, tail_frac: float = 0.1
                ) -> List[TraceEvent]:
    """One seeded arrival trace of ``n`` requests at offered rate
    ``rate`` req/s (virtual time):

    * ``poisson``       — exponential interarrivals, uniform prompts.
    * ``burst``         — Poisson epochs each delivering ``burst_size``
                          near-simultaneous requests (rate counts
                          REQUESTS, so epochs come at rate/burst_size).
    * ``shared_prefix`` — bursty fleets sharing a common prompt prefix
                          (the prefix-cache + slo interaction workload).
    * ``longtail``      — Poisson arrivals, but ``tail_frac`` of prompts
                          are ``tail_len`` tokens (head-of-line blockers).
    """
    if pattern not in PATTERNS:
        raise ValueError(f"unknown trace pattern {pattern!r}; "
                         f"known: {PATTERNS}")
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, prefix_len).astype(np.int32)

    def plen() -> int:
        if pattern == "longtail" and rng.random() < tail_frac:
            return tail_len
        return int(rng.integers(prompt_lo, prompt_hi))

    events: List[TraceEvent] = []
    t = 0.0
    while len(events) < n:
        if pattern in ("burst", "shared_prefix"):
            t += rng.exponential(burst_size / rate)
            k = min(burst_size, n - len(events))
        else:
            t += rng.exponential(1.0 / rate)
            k = 1
        for j in range(k):
            body = rng.integers(0, vocab, plen()).astype(np.int32)
            prompt = (np.concatenate([shared, body])
                      if pattern == "shared_prefix" else body)
            # intra-burst arrivals are distinct but tightly packed
            events.append(TraceEvent(t=t + j * 1e-3, prompt=prompt,
                                     max_new=max_new, slo_ttft=slo_ttft,
                                     slo_tpot=slo_tpot))
    return events


def _met_slo(r) -> bool:
    ttft_ok = r.slo_ttft is None \
        or r.stats.get("lat/ttft_s", float("inf")) <= r.slo_ttft
    tpot_ok = r.slo_tpot is None \
        or r.stats.get("lat/tpot_s", float("inf")) <= r.slo_tpot
    return bool(r.done) and ttft_ok and tpot_ok


def replay(engine, trace: List[TraceEvent], *, clock: VirtualClock,
           step_time: Optional[float], max_steps: int = 4096,
           seed: Optional[int] = None, pattern: Optional[str] = None,
           on_token=None, ewma_alpha: float = 0.3) -> dict:
    """Replay ``trace`` through a fresh front-end on ``engine`` and
    score it.  ``clock`` must be the engine's observability clock (the
    replay advances it ``step_time`` per engine step); ``engine`` should
    be freshly constructed (no live slots).

    ``step_time=None`` enables wall-clock calibration: each engine step
    is timed for real and the clock advances by the running EWMA of the
    measured step seconds (``ewma_alpha`` weights the newest sample).
    When the engine idles before the next arrival the clock fast-forwards
    to it — real deployments sleep there; spinning virtual steps through
    the gap would just exhaust ``max_steps``.

    Returns the artifact record: goodput-under-SLO, slo attainment,
    p50/p99 TTFT/TPOT, preemption/resume counts, per-phase obs counters
    (when a metrics sink is attached), and the self-describing cell
    config."""
    fe = ServingFrontend(engine)
    calibrated = step_time is None
    est: Optional[float] = None        # EWMA of measured step seconds
    if not calibrated:
        engine.step_time_hint = step_time  # price feasibility in replay time
    handles = []
    i = steps = 0
    while (i < len(trace) or fe.outstanding) and steps < max_steps:
        if calibrated:
            if not fe.outstanding and i < len(trace):
                # idle gap: jump to the next arrival instead of spinning
                clock.advance(max(0.0, trace[i].t - clock.now))
            clock.advance(est or 0.0)  # the step about to run, estimated
        else:
            clock.advance(step_time)   # time the step about to run takes
        while i < len(trace) and trace[i].t <= clock.now:
            ev = trace[i]
            handles.append(fe.submit(ev.prompt, max_new=ev.max_new,
                                     slo_ttft=ev.slo_ttft,
                                     slo_tpot=ev.slo_tpot,
                                     on_token=on_token))
            i += 1
        if calibrated:
            t0 = time.perf_counter()
            fe.poll()
            dt = time.perf_counter() - t0
            est = dt if est is None else \
                (1.0 - ewma_alpha) * est + ewma_alpha * dt
            engine.step_time_hint = est
        else:
            fe.poll()
        steps += 1
    # censored stats for anything unfinished at budget exhaustion
    leftovers = [r for r in handles if not r.done]
    if leftovers:
        engine.finalize_drops(leftovers)
    n_done = sum(1 for r in handles if r.done)
    n_good = sum(1 for r in handles if _met_slo(r))
    makespan = max(clock.now, step_time or est or 0.0, 1e-9)
    lat = latency_summary([r for r in handles if r.done])
    rec = {
        "pattern": pattern,
        "n_requests": len(handles),
        "offered": len(trace),
        "steps": steps,
        "step_time_s": step_time if not calibrated else est,
        "step_time_mode": "calibrated" if calibrated else "fixed",
        "makespan_s": makespan,
        "completed": n_done,
        "dropped": len(handles) - n_done,
        "slo_good": n_good,
        "slo_attainment": n_good / max(1, len(handles)),
        "goodput_rps": n_good / makespan,
        "throughput_rps": n_done / makespan,
        "preempted": engine.n_preempted,
        "resumed": engine.n_resumed,
        "latency": lat,
        "ttft_p50_s": lat["ttft_s"]["p50"] if lat["ttft_s"] else None,
        "ttft_p99_s": lat["ttft_s"]["p99"] if lat["ttft_s"] else None,
        "tpot_p50_s": lat["tpot_s"]["p50"] if lat["tpot_s"] else None,
        "tpot_p99_s": lat["tpot_s"]["p99"] if lat["tpot_s"] else None,
        "config": engine.describe(seed=seed),
        "outputs": {r.rid: list(r.out) for r in handles},
    }
    # calibration provenance lives with the rest of the cell config: a
    # reader of the artifact must be able to tell measured-wall-scaled
    # timelines from fixed virtual ones
    rec["config"]["step_calibration"] = {
        "mode": rec["step_time_mode"],
        "ewma_alpha": ewma_alpha if calibrated else None,
        "measured_step_ewma_s": est,
    }
    if engine.paged:
        rec["kv_stats"] = engine.kv.stats()
    obs = engine.obs
    if obs.enabled:
        # per-phase counters: scheduling/preemption/streaming activity
        snap = obs.metrics.snapshot()
        rec["obs_counters"] = {c["name"]: c["value"]
                               for c in snap["counters"] if not c["labels"]}
        obs.metrics.set_gauge("slo/goodput_rps", rec["goodput_rps"])
        obs.metrics.set_gauge("slo/attainment", rec["slo_attainment"])
        obs.metrics.set_gauge("slo/deadline_misses",
                              len(handles) - n_good)
    return rec


def make_virtual_obs(enabled: bool = False):
    """A (clock, Observability) pair on one virtual timeline: the full
    in-memory bundle when ``enabled`` (loadgen artifacts then include
    obs counters), else null sinks reading the same clock."""
    clock = VirtualClock()
    obs = Observability.memory(clock=clock) if enabled \
        else Observability(clock=clock)
    return clock, obs
