"""Jitted serving steps: prefill (build caches) and decode (one token).

These are the entry points the decode_*/long_* dry-run cells lower; the
serve loop in serve/engine.py drives them for real batched requests."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import RunConfig, forward


def make_prefill_step(cfg: ModelConfig, rc: RunConfig):
    def prefill_step(params, batch, cache):
        logits, new_cache, _ = forward(params, cfg, rc, batch,
                                       mode="prefill", cache=cache)
        return logits, new_cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, rc: RunConfig, *, greedy: bool = True):
    def decode_step(params, batch, cache, pos):
        logits, new_cache, _ = forward(params, cfg, rc, batch,
                                       mode="decode", cache=cache, pos=pos)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (token if greedy else logits), logits, new_cache
    return decode_step


def make_forward_only(cfg: ModelConfig, rc: RunConfig):
    """Encoder forward (hubert prefill_32k cell): full-seq hidden states."""
    def encode_step(params, batch):
        h, _, _ = forward(params, cfg, rc, batch, mode="train")
        return h
    return encode_step
