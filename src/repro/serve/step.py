"""Jitted serving steps: prefill (build caches) and decode (one token).

Two families live here:

* ``make_prefill_step`` / ``make_decode_step`` — single-sequence steps over
  a standalone cache (the decode_*/long_* dry-run cells lower these).
* ``make_slot_prefill_step`` / ``make_slot_decode_step`` — slot-row steps
  over ONE batched ``(slots, capacity)`` cache (serve/engine.py).  Prefill
  writes a single slot's row; decode advances the active-slot *prefix*
  [0, n) in one forward with per-slot positions, argmax + EOS detection
  on device (the engine syncs once per step for all slots).  ``n`` is a
  Python int baked into the jitted step: each distinct active-slot count
  compiles once (bounded by the slot count), exactly like bucketed batch
  sizes in production engines.

Observability (repro.obs): the slot/paged constructors accept an
``Observability`` bundle and call ``obs.on_trace(...)`` INSIDE the step
body — python executes there only while jax traces, so the call fires
exactly once per distinct compiled shape, turning recompile events into
trace instants + a ``serve/recompiles`` counter.  It records host-static
facts only (shapes) and inserts no ops into the traced computation:
compiled artifacts and greedy tokens are bitwise-identical with
observability on or off (tests/test_obs.py).

Sampling (repro.sampling, DESIGN.md §13): the slot/paged constructors
take a ``SamplingConfig``; stochastic methods replace the argmax with a
per-row categorical draw keyed by (request seed, output index, role) —
still on device, still ONE host sync per step.  ``method="greedy"``
keeps the literal pre-sampling argmax path (a trace-time branch), so
greedy tokens stay bitwise-identical.

Speculative decoding (repro.spec): ``make_spec_draft_step`` chains k
draft proposals with NO host sync between them, and
``make_spec_verify_step`` scores all k+1 positions of every slot in ONE
target forward (each MoE layer builds a single DispatchPlan covering
them) and runs the accept/rejection math on device — the engine syncs
once per speculative round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import (RunConfig, forward, slice_cache_slots,
                             update_cache_slots)
from repro.obs import NOOP
from repro.sampling import (ROLE_DRAFT, ROLE_RESIDUAL, ROLE_SAMPLE,
                            SamplingConfig, process_logits, row_key,
                            sample_rows, uniform_rows)


def make_prefill_step(cfg: ModelConfig, rc: RunConfig):
    def prefill_step(params, batch, cache):
        logits, new_cache, _ = forward(params, cfg, rc, batch,
                                       mode="prefill", cache=cache)
        return logits, new_cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, rc: RunConfig, *, greedy: bool = True):
    def decode_step(params, batch, cache, pos):
        logits, new_cache, _ = forward(params, cfg, rc, batch,
                                       mode="decode", cache=cache, pos=pos)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (token if greedy else logits), logits, new_cache
    return decode_step


def make_forward_only(cfg: ModelConfig, rc: RunConfig):
    """Encoder forward (hubert prefill_32k cell): full-seq hidden states."""
    def encode_step(params, batch):
        h, _, _ = forward(params, cfg, rc, batch, mode="train")
        return h
    return encode_step


# ----------------------------------------------------------------------
# Slot steps over the batched serving cache
# ----------------------------------------------------------------------
def make_slot_prefill_step(cfg: ModelConfig, rc: RunConfig, obs=None,
                           sampling: SamplingConfig = None):
    """Prefill one request into slot row ``slot`` of the batched cache.

    Returns jitted ``(params, cache, batch, slot) -> (tok, cache', aux)``:
    the prompt's KV rows land in ``cache[slot], rows [0, P)``; the first
    greedy token is argmaxed on device.  The other slots' rows are passed
    through untouched, so admission never disturbs running decodes.

    The slot row is zeroed before the prefill (every cache leaf inits to
    zeros) — positional KV rows beyond the prompt are masked by kv_limit
    anyway, but recurrent state (rwkv shift/state, ssm conv/state) has no
    position masking and would otherwise leak from the row's retired
    previous occupant into the new request."""
    obs = obs or NOOP
    sampling = sampling or SamplingConfig()

    def prefill_step(params, cache, batch, slot, seed):
        obs.on_trace("prefill_step",
                     prompt_tokens=int(batch["tokens"].shape[-1]))
        sub = jax.tree.map(jnp.zeros_like, slice_cache_slots(cache, slot, 1))
        logits, new_sub, aux = forward(params, cfg, rc, batch,
                                       mode="prefill", cache=sub)
        cache = update_cache_slots(cache, new_sub, slot)
        if sampling.method == "greedy":
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (1,)
        else:
            # the prefill's logits seed output index 0
            tok = sample_rows(logits, sampling, seed[None],
                              jnp.zeros((1,), jnp.int32))
        return tok, cache, aux
    # donation (in-place cache update) is a TPU win but warns on CPU where
    # XLA can't alias the buffers; leave the flag off in this container
    return jax.jit(prefill_step)


def make_paged_step(cfg: ModelConfig, rc: RunConfig, obs=None,
                    sampling: SamplingConfig = None):
    """ONE step function for the paged engine: decode tokens and prefill-
    chunk tokens ride in the SAME token batch, so every MoE layer builds a
    single DispatchPlan covering all of them.

    Returns jitted ``(params, pools, batch, pos, tables, eos, seeds,
    counters) -> (tok, eos_hit, pools', aux)`` where each row of
    ``batch["tokens"]`` (T, 1) is one token — a slot's decode token or one
    token of a prompt chunk — with its own position ``pos[t]`` and its
    slot's block-table row ``tables[t]``.  KV writes scatter block-
    granular into the pools; reads gather each row's logical view
    (models/attention.py).  ``seeds``/``counters`` (T,) key stochastic
    draws per row (repro.sampling); greedy never reads them.  jit re-
    specializes per distinct T (decode-only steps reuse T = n_active,
    bounded by slots; chunk steps add one shape per distinct chunk
    layout)."""
    obs = obs or NOOP
    sampling = sampling or SamplingConfig()

    def paged_step(params, pools, batch, pos, tables, eos, seeds, counters):
        obs.on_trace("paged_step", tokens=int(batch["tokens"].shape[0]))
        logits, pools, aux = forward(params, cfg, rc, batch, mode="decode",
                                     cache=pools, pos=pos,
                                     block_tables=tables)
        if sampling.method == "greedy":
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (T,)
        else:
            tok = sample_rows(logits, sampling, seeds, counters)
        return tok, tok == eos, pools, aux
    return jax.jit(paged_step)


def make_slot_decode_step(cfg: ModelConfig, rc: RunConfig, n: int,
                          obs=None, sampling: SamplingConfig = None):
    """One decode step for the ``n`` active slots (prefix rows [0, n)).

    Returns jitted ``(params, cache, batch, pos, eos, seeds, counters) ->
    (tok, eos_hit, cache', aux)`` where ``pos``/``eos``/``seeds``/
    ``counters`` are (n,) per-slot vectors (``eos`` -1 = no EOS token).
    One forward covers all active slots — every MoE layer plans/dispatches
    the n decode tokens together — and the token selection (argmax or
    keyed categorical) plus the EOS comparison stay on device: the engine
    performs a single host transfer per step."""
    obs = obs or NOOP
    sampling = sampling or SamplingConfig()

    def decode_step(params, cache, batch, pos, eos, seeds, counters):
        obs.on_trace("decode_step", active_slots=n)
        sub = slice_cache_slots(cache, 0, n)
        logits, new_sub, aux = forward(params, cfg, rc, batch,
                                       mode="decode", cache=sub, pos=pos)
        cache = update_cache_slots(cache, new_sub, 0)
        if sampling.method == "greedy":
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (n,)
        else:
            tok = sample_rows(logits, sampling, seeds, counters)
        return tok, tok == eos, cache, aux
    return jax.jit(decode_step)


# ----------------------------------------------------------------------
# Speculative decoding steps (repro.spec drives these)
# ----------------------------------------------------------------------
def make_spec_draft_step(cfg: ModelConfig, rc: RunConfig,
                         sampling: SamplingConfig = None, obs=None):
    """One draft-model proposal step over the paged draft pools.

    Returns jitted ``(params, pools, batch, pos, tables, seeds, counters)
    -> (tok, qdist, pools', aux)`` where ``tok`` (n,) is the proposal for
    each slot and ``qdist`` (n, V) is the draft distribution q it was
    drawn from (softmax of the processed logits — the verify step needs
    q(draft_token) for rejection sampling).  Under greedy sampling the
    proposal is the draft argmax and q degenerates to the same softmax
    (the verify step's greedy path only compares token ids, never reads
    q).  The engine chains k of these with NO host sync in between."""
    obs = obs or NOOP
    sampling = sampling or SamplingConfig()

    def draft_step(params, pools, batch, pos, tables, seeds, counters):
        obs.on_trace("spec_draft_step", tokens=int(batch["tokens"].shape[0]))
        logits, pools, aux = forward(params, cfg, rc, batch, mode="decode",
                                     cache=pools, pos=pos,
                                     block_tables=tables)
        proc = process_logits(logits, sampling)
        qdist = jax.nn.softmax(proc, axis=-1)                    # (n, V)
        if sampling.method == "greedy":
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (n,)
        else:
            tok = sample_rows(logits, sampling, seeds, counters,
                              role=ROLE_DRAFT)
        return tok, qdist, pools, aux
    return jax.jit(draft_step)


def make_spec_verify_step(cfg: ModelConfig, rc: RunConfig,
                          sampling: SamplingConfig = None, k: int = 4,
                          obs=None):
    """Target-verify all k draft proposals of every slot in ONE forward.

    Returns jitted ``(params, pools, batch, pos, tables, draft_tok,
    draft_q, seeds, counters) -> (emitted, n_emit, pools', aux)``.  The
    batch holds n·(k+1) rows — slot s contributes its last emitted token
    plus its k proposals at positions [pos_s, pos_s + k], all sharing
    slot s's block-table row — so every MoE layer builds a single
    DispatchPlan covering the whole verify sweep (asserted in
    tests/test_spec.py).  Row j's logits are the target distribution p
    for output index counter_s + j.

    Accept/rejection math (on device; ONE host sync returns ``emitted``
    (n, k+1) + ``n_emit`` (n,)):

    * greedy — integer comparison: accept_j = (draft_j == argmax p_j);
      the accepted prefix length a is the run of leading accepts; the
      bonus token is argmax p_a.  Token-identical to non-speculative
      greedy by induction: each accepted/bonus token equals the argmax
      the baseline engine would have produced at that output index.
    * stochastic — standard rejection sampling: accept_j while
      u_j · q_j(d_j) ≤ p_j(d_j) with u_j the ROLE_ACCEPT uniform for
      output index counter_s + j; on first rejection resample from the
      residual norm(max(p_a − q_a, 0)) (falling back to p_a when the
      residual has no mass — q ≥ p everywhere); if all k accepted the
      bonus is a ROLE_SAMPLE draw from p_k.

    ``emitted[s]`` = the a accepted drafts then the bonus/residual token
    then zero padding; ``n_emit[s]`` = a + 1.  The engine truncates both
    KV pools back to the new length — rejected rows die as a host-side
    block-table rollback."""
    obs = obs or NOOP
    sampling = sampling or SamplingConfig()

    def verify_step(params, pools, batch, pos, tables, draft_tok, draft_q,
                    seeds, counters):
        n = draft_tok.shape[0]
        obs.on_trace("spec_verify_step", tokens=int(batch["tokens"].shape[0]),
                     k=k)
        logits, pools, aux = forward(params, cfg, rc, batch, mode="decode",
                                     cache=pools, pos=pos,
                                     block_tables=tables)
        L = logits.reshape(n, k + 1, -1)                   # (n, k+1, V)
        if sampling.method == "greedy":
            tgt = jnp.argmax(L, axis=-1).astype(jnp.int32)  # (n, k+1)
            accept = (draft_tok == tgt[:, :k]).astype(jnp.int32)
            a = jnp.sum(jnp.cumprod(accept, axis=1), axis=1)     # (n,)
            bonus = jnp.take_along_axis(tgt, a[:, None], axis=1)[:, 0]
        else:
            proc = process_logits(L, sampling)
            p = jax.nn.softmax(proc, axis=-1)               # (n, k+1, V)
            u = uniform_rows(seeds, counters, k)            # (n, k)
            p_d = jnp.take_along_axis(p[:, :k], draft_tok[..., None],
                                      axis=-1)[..., 0]      # (n, k)
            q_d = jnp.take_along_axis(draft_q, draft_tok[..., None],
                                      axis=-1)[..., 0]      # (n, k)
            accept = (u * q_d <= p_d).astype(jnp.int32)
            a = jnp.sum(jnp.cumprod(accept, axis=1), axis=1)     # (n,)
            p_a = jnp.take_along_axis(
                p, a[:, None, None], axis=1)[:, 0]          # (n, V)
            q_pad = jnp.concatenate(
                [draft_q, jnp.zeros_like(draft_q[:, :1])], axis=1)
            q_a = jnp.take_along_axis(
                q_pad, a[:, None, None], axis=1)[:, 0]      # (n, V)
            res = jnp.maximum(p_a - q_a, 0.0)
            mass = jnp.sum(res, axis=-1, keepdims=True)
            res = jnp.where(mass > 0.0, res / jnp.maximum(mass, 1e-20), p_a)
            res_key = jax.vmap(
                lambda s, c, aa: row_key(s, c + aa, ROLE_RESIDUAL))(
                    seeds, counters, a)
            tok_res = jax.vmap(
                lambda kk, r: jax.random.categorical(kk, jnp.log(
                    jnp.maximum(r, 1e-20))))(res_key, res).astype(jnp.int32)
            bonus_key = jax.vmap(
                lambda s, c: row_key(s, c + k, ROLE_SAMPLE))(seeds, counters)
            bonus_full = jax.vmap(
                lambda kk, pr: jax.random.categorical(kk, jnp.log(
                    jnp.maximum(pr, 1e-20))))(
                        bonus_key, p[:, k]).astype(jnp.int32)
            bonus = jnp.where(a == k, bonus_full, tok_res)
        dpad = jnp.concatenate(
            [draft_tok, jnp.zeros_like(draft_tok[:, :1])], axis=1)
        idx = jnp.arange(k + 1)[None, :]                    # (1, k+1)
        emitted = jnp.where(idx < a[:, None], dpad,
                            jnp.where(idx == a[:, None], bonus[:, None], 0))
        return emitted, a + 1, pools, aux
    return jax.jit(verify_step)
