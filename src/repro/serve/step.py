"""Jitted serving steps: prefill (build caches) and decode (one token).

Two families live here:

* ``make_prefill_step`` / ``make_decode_step`` — single-sequence steps over
  a standalone cache (the decode_*/long_* dry-run cells lower these).
* ``make_slot_prefill_step`` / ``make_slot_decode_step`` — slot-row steps
  over ONE batched ``(slots, capacity)`` cache (serve/engine.py).  Prefill
  writes a single slot's row; decode advances the active-slot *prefix*
  [0, n) in one forward with per-slot positions, argmax + EOS detection
  on device (the engine syncs once per step for all slots).  ``n`` is a
  Python int baked into the jitted step: each distinct active-slot count
  compiles once (bounded by the slot count), exactly like bucketed batch
  sizes in production engines.

Observability (repro.obs): the slot/paged constructors accept an
``Observability`` bundle and call ``obs.on_trace(...)`` INSIDE the step
body — python executes there only while jax traces, so the call fires
exactly once per distinct compiled shape, turning recompile events into
trace instants + a ``serve/recompiles`` counter.  It records host-static
facts only (shapes) and inserts no ops into the traced computation:
compiled artifacts and greedy tokens are bitwise-identical with
observability on or off (tests/test_obs.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import (RunConfig, forward, slice_cache_slots,
                             update_cache_slots)
from repro.obs import NOOP


def make_prefill_step(cfg: ModelConfig, rc: RunConfig):
    def prefill_step(params, batch, cache):
        logits, new_cache, _ = forward(params, cfg, rc, batch,
                                       mode="prefill", cache=cache)
        return logits, new_cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, rc: RunConfig, *, greedy: bool = True):
    def decode_step(params, batch, cache, pos):
        logits, new_cache, _ = forward(params, cfg, rc, batch,
                                       mode="decode", cache=cache, pos=pos)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (token if greedy else logits), logits, new_cache
    return decode_step


def make_forward_only(cfg: ModelConfig, rc: RunConfig):
    """Encoder forward (hubert prefill_32k cell): full-seq hidden states."""
    def encode_step(params, batch):
        h, _, _ = forward(params, cfg, rc, batch, mode="train")
        return h
    return encode_step


# ----------------------------------------------------------------------
# Slot steps over the batched serving cache
# ----------------------------------------------------------------------
def make_slot_prefill_step(cfg: ModelConfig, rc: RunConfig, obs=None):
    """Prefill one request into slot row ``slot`` of the batched cache.

    Returns jitted ``(params, cache, batch, slot) -> (tok, cache', aux)``:
    the prompt's KV rows land in ``cache[slot], rows [0, P)``; the first
    greedy token is argmaxed on device.  The other slots' rows are passed
    through untouched, so admission never disturbs running decodes.

    The slot row is zeroed before the prefill (every cache leaf inits to
    zeros) — positional KV rows beyond the prompt are masked by kv_limit
    anyway, but recurrent state (rwkv shift/state, ssm conv/state) has no
    position masking and would otherwise leak from the row's retired
    previous occupant into the new request."""
    obs = obs or NOOP

    def prefill_step(params, cache, batch, slot):
        obs.on_trace("prefill_step",
                     prompt_tokens=int(batch["tokens"].shape[-1]))
        sub = jax.tree.map(jnp.zeros_like, slice_cache_slots(cache, slot, 1))
        logits, new_sub, aux = forward(params, cfg, rc, batch,
                                       mode="prefill", cache=sub)
        cache = update_cache_slots(cache, new_sub, slot)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)      # (1,)
        return tok, cache, aux
    # donation (in-place cache update) is a TPU win but warns on CPU where
    # XLA can't alias the buffers; leave the flag off in this container
    return jax.jit(prefill_step)


def make_paged_step(cfg: ModelConfig, rc: RunConfig, obs=None):
    """ONE step function for the paged engine: decode tokens and prefill-
    chunk tokens ride in the SAME token batch, so every MoE layer builds a
    single DispatchPlan covering all of them.

    Returns jitted ``(params, pools, batch, pos, tables, eos) -> (tok,
    eos_hit, pools', aux)`` where each row of ``batch["tokens"]`` (T, 1) is
    one token — a slot's decode token or one token of a prompt chunk —
    with its own position ``pos[t]`` and its slot's block-table row
    ``tables[t]``.  KV writes scatter block-granular into the pools; reads
    gather each row's logical view (models/attention.py).  jit re-
    specializes per distinct T (decode-only steps reuse T = n_active,
    bounded by slots; chunk steps add one shape per distinct chunk
    layout)."""
    obs = obs or NOOP

    def paged_step(params, pools, batch, pos, tables, eos):
        obs.on_trace("paged_step", tokens=int(batch["tokens"].shape[0]))
        logits, pools, aux = forward(params, cfg, rc, batch, mode="decode",
                                     cache=pools, pos=pos,
                                     block_tables=tables)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (T,)
        return tok, tok == eos, pools, aux
    return jax.jit(paged_step)


def make_slot_decode_step(cfg: ModelConfig, rc: RunConfig, n: int,
                          obs=None):
    """One decode step for the ``n`` active slots (prefix rows [0, n)).

    Returns jitted ``(params, cache, batch, pos, eos) -> (tok, eos_hit,
    cache', aux)`` where ``pos``/``eos`` are (n,) per-slot vectors (``eos``
    -1 = no EOS token).  One forward covers all active slots — every MoE
    layer plans/dispatches the n decode tokens together — and both the
    argmax and the EOS comparison stay on device: the engine performs a
    single host transfer per step."""
    obs = obs or NOOP

    def decode_step(params, cache, batch, pos, eos):
        obs.on_trace("decode_step", active_slots=n)
        sub = slice_cache_slots(cache, 0, n)
        logits, new_sub, aux = forward(params, cfg, rc, batch,
                                       mode="decode", cache=sub, pos=pos)
        cache = update_cache_slots(cache, new_sub, 0)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)      # (n,)
        return tok, tok == eos, cache, aux
    return jax.jit(decode_step)
