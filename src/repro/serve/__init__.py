"""repro.serve subpackage: batched continuous-batching serving.

engine.py    — ServeEngine: one decode dispatch per step across all slots
admission.py — pluggable admission policies (fcfs / sjf / prefix_hit / slo)
kv_cache.py  — paged KV cache: block pool, prefix cache, parked tables
frontend.py  — open-stream front-end: submit()/poll() + token streaming
loadgen.py   — trace-driven load generator: goodput under SLO
step.py      — jitted prefill/decode steps (single-sequence + slot-row)
"""
from repro.serve.admission import (available_admission_policies,  # noqa: F401
                                   get_admission, register_admission)
from repro.serve.distributed import (DistributedServeLoop,  # noqa: F401
                                     partition_requests)
from repro.serve.engine import Request, ServeEngine  # noqa: F401
from repro.serve.frontend import ServingFrontend  # noqa: F401
from repro.serve.loadgen import (PATTERNS, TraceEvent,  # noqa: F401
                                 VirtualClock, make_virtual_obs, replay,
                                 synth_trace)
