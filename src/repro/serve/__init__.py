"""repro.serve subpackage."""
