"""repro.serve subpackage: batched continuous-batching serving.

engine.py    — ServeEngine: one decode dispatch per step across all slots
admission.py — pluggable admission policies (fcfs / sjf)
step.py      — jitted prefill/decode steps (single-sequence + slot-row)
"""
from repro.serve.admission import (available_admission_policies,  # noqa: F401
                                   get_admission, register_admission)
from repro.serve.engine import Request, ServeEngine  # noqa: F401
