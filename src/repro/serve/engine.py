"""Batched continuous-batching engine: one decode dispatch per step.

All active slots decode in ONE jitted forward over a single
``(slots, capacity)`` KV cache — this is where the paper's throughput
story meets serving: every MoE layer sees the whole decode batch and
builds exactly one ``DispatchPlan`` per step covering all active tokens,
so the schedule policies (repro.scheduling) finally have a real batch to
schedule at serve time.  Control flow (vLLM-style, scaled to this
container):

* **Slots are a contiguous prefix.**  Active requests occupy cache rows
  [0, n_active); retirement swaps the freed row with the last active one
  (a device-side row swap), so the decode step is a fixed-shape forward
  over the prefix — no masking, no garbage tokens in the dispatch plan.
* **One sync per step.**  Argmax and EOS detection run on device
  (serve/step.py); the engine performs a single host transfer per decode
  step for all slots, instead of one per slot.
* **Admission never disturbs decodes.**  Prefill writes only its slot's
  cache row; which pending request is admitted is a pluggable policy
  (serve/admission.py: fcfs / sjf).
* **Telemetry.**  The step's shared plan aux (router losses + sched/*
  ScheduleStats summed over MoE layers) is kept per request rid and
  materialized into ``Request.stats`` at retirement, tagged with the
  decode-batch size the request last shared.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import RunConfig, init_cache, swap_cache_slots
from repro.serve.admission import get_admission
from repro.serve.step import make_slot_decode_step, make_slot_prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new: int = 16
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # dispatch-plan telemetry, set at retirement from the request's final
    # step (router aux + sched/* ScheduleStats when the model is MoE and
    # stats are enabled), summed over the MoE layers of that step; the
    # plan is shared by every slot decoding in that step, and
    # ``serve/decode_batch`` records how many
    stats: dict = dataclasses.field(default_factory=dict)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 capacity: int = 256, rc: Optional[RunConfig] = None,
                 admission: str = "fcfs"):
        self.cfg = cfg
        # serving default: the dynamic schedule policy — production traffic
        # is skewed and decode batches are small, exactly the regime where
        # the fixed tile layout pads worst (DESIGN.md §3) — with per-plan
        # telemetry on so operators see padding/drop behavior per request
        self.rc = rc or RunConfig(q_chunk=64, kv_chunk=64,
                                  schedule_policy="dynamic", moe_stats=True)
        if self.rc.quant != "none" and cfg.is_moe:
            # load-time transform: routed experts compressed under the
            # selected scheme (idempotent if params already carry the tag)
            from repro.quantization import quantize_params_tree
            params = quantize_params_tree(params, self.rc.quant)
        self.params = params
        self.slots = slots
        self.capacity = capacity
        # ONE batched cache; slot s owns row s (batch axis of every leaf)
        self.cache = init_cache(cfg, slots, capacity)
        self.pos = np.zeros(slots, np.int64)          # per-slot positions
        # active requests occupy slots [0, n_active) — prefix invariant
        self.active: List[Optional[Request]] = [None] * slots
        self.n_active = 0
        # per-active-request shared step aux (device scalars; materialized
        # into Request.stats at retirement), keyed by rid — id(req) of a
        # retired request can be recycled by the allocator
        self._last_aux: Dict[int, dict] = {}
        # requests still in flight/pending when run()'s step budget ran out
        self.dropped: List[Request] = []
        self._admission = get_admission(admission)

        self._prefill = make_slot_prefill_step(cfg, self.rc)
        # one compiled decode step per distinct active-slot count (<= slots)
        self._decode_steps: Dict[int, object] = {}
        self._swap = jax.jit(swap_cache_slots)

    # ------------------------------------------------------------------
    def _batch(self, toks):
        b = {"tokens": toks}
        if self.cfg.cross_attn_every:
            b["image_embeds"] = jnp.zeros(
                (toks.shape[0], self.cfg.n_image_tokens, self.cfg.d_model),
                jnp.float32)
        return b

    def admit(self, req: Request) -> bool:
        """Prefill ``req`` into the first free slot row; False if full."""
        if self.n_active >= self.slots:
            return False
        if any(r is not None and r.rid == req.rid for r in self.active):
            # telemetry is keyed by rid; two live requests sharing one
            # would silently cross their stats and crash at retirement
            raise ValueError(f"rid {req.rid} is already active")
        s = self.n_active
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        tok, self.cache, aux = self._prefill(
            self.params, self.cache, self._batch(toks), jnp.int32(s))
        self.pos[s] = len(req.prompt)
        req.out.append(int(tok[0]))
        self._last_aux[req.rid] = aux
        self.active[s] = req
        self.n_active += 1
        return True

    def step(self) -> int:
        """One decode step across ALL active slots: one jit call, one host
        sync.  Returns the number of slots that decoded."""
        n = self.n_active
        if n == 0:
            return 0
        reqs = self.active[:n]
        last = jnp.asarray([[r.out[-1]] for r in reqs], jnp.int32)   # (n, 1)
        pos = jnp.asarray(self.pos[:n], jnp.int32)                   # (n,)
        eos = jnp.asarray([-1 if r.eos is None else r.eos for r in reqs],
                          jnp.int32)
        fn = self._decode_steps.get(n)
        if fn is None:
            fn = self._decode_steps[n] = make_slot_decode_step(
                self.cfg, self.rc, n)
        tok, eos_hit, self.cache, aux = fn(
            self.params, self.cache, self._batch(last), pos, eos)
        tok_np, eos_np = jax.device_get((tok, eos_hit))  # the ONE host sync
        for s, r in enumerate(reqs):
            r.out.append(int(tok_np[s]))
            self.pos[s] += 1
            self._last_aux[r.rid] = aux
        # retire top-down so the swap-with-last compaction never moves a
        # slot we still have to examine
        for s in range(n - 1, -1, -1):
            r = self.active[s]
            if bool(eos_np[s]) or len(r.out) >= r.max_new \
                    or self.pos[s] >= self.capacity - 1:
                self._retire(s, decode_batch=n)
        return n

    def _retire(self, s: int, *, decode_batch: int) -> None:
        """Free slot ``s``: materialize telemetry, swap the freed cache row
        with the last active one to keep the active prefix contiguous."""
        req = self.active[s]
        req.stats = {k: float(v)
                     for k, v in self._last_aux.pop(req.rid).items()}
        req.stats["serve/decode_batch"] = float(decode_batch)
        req.done = True
        last = self.n_active - 1
        if s != last:
            self.cache = self._swap(self.cache, jnp.int32(s),
                                    jnp.int32(last))
            self.active[s] = self.active[last]
            self.pos[s] = self.pos[last]
        self.active[last] = None
        self.pos[last] = 0
        self.n_active -= 1

    def run(self, requests: List[Request], max_steps: int = 512):
        """Drive admission + decode until done (or the step budget runs
        out).  Returns the completed requests in submission order; requests
        still in flight or never admitted keep ``done=False`` (with any
        partial ``out``) and are collected in ``self.dropped``.  A later
        ``run`` may resume them: requests already occupying a slot (or
        already done) are excluded from admission so they are never
        re-prefilled, but active slots keep decoding."""
        live = {id(r) for r in self.active if r is not None}
        pending = [r for r in requests if not r.done and id(r) not in live]
        self.dropped = []
        for _ in range(max_steps):
            while pending and self.n_active < self.slots:
                self.admit(pending.pop(self._admission(pending)))
            if self.step() == 0 and not pending:
                break
        self.dropped = [r for r in requests if not r.done]
        return [r for r in requests if r.done]
