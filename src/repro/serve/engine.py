"""Batched serving engine: slot-based continuous batching (lite).

Fixed decode slots over a shared KV cache; requests are admitted into free
slots, prefilled one request at a time (prefill writes its slot's cache
rows), then all active slots decode in lock-step with per-slot positions
and EOS/max-token retirement.  This is the real control-flow skeleton of a
production server (vLLM-style), scaled to this container."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import RunConfig, forward, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new: int = 16
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # dispatch-plan telemetry, set at retirement from the request's final
    # forward (router aux + sched/* ScheduleStats when the model is MoE
    # and stats are enabled), summed over the MoE layers of that step
    stats: dict = dataclasses.field(default_factory=dict)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 capacity: int = 256, rc: Optional[RunConfig] = None):
        self.cfg = cfg
        self.params = params
        # serving default: the dynamic schedule policy — production traffic
        # is skewed and decode batches are small, exactly the regime where
        # the fixed tile layout pads worst (DESIGN.md §3) — with per-plan
        # telemetry on so operators see padding/drop behavior per request
        self.rc = rc or RunConfig(q_chunk=64, kv_chunk=64,
                                  schedule_policy="dynamic", moe_stats=True)
        self.slots = slots
        self.capacity = capacity
        # one single-sequence cache per slot (slot caches stay independent
        # so admission never disturbs running decodes)
        self.caches = [init_cache(cfg, 1, capacity) for _ in range(slots)]
        self.pos = [0] * slots
        self.active: List[Optional[Request]] = [None] * slots
        # per-active-request raw aux from its latest forward (device
        # scalars; materialized into Request.stats at retirement)
        self._last_aux: Dict[int, dict] = {}

        self._prefill = jax.jit(
            lambda p, b, c: forward(p, self.cfg, self.rc, b, mode="prefill",
                                    cache=c))
        self._decode = jax.jit(
            lambda p, b, c, pos: forward(p, self.cfg, self.rc, b,
                                         mode="decode", cache=c,
                                         pos=pos))

    # ------------------------------------------------------------------
    def admit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.active[s] is None:
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, cache, aux = self._prefill(
                    self.params, self._batch(toks), self.caches[s])
                self.caches[s] = cache
                self.pos[s] = len(req.prompt)
                tok = int(jnp.argmax(logits, -1)[0])
                req.out.append(tok)
                self._last_aux[id(req)] = aux
                self.active[s] = req
                return True
        return False

    def _batch(self, toks):
        b = {"tokens": toks}
        if self.cfg.cross_attn_every:
            b["image_embeds"] = jnp.zeros(
                (toks.shape[0], self.cfg.n_image_tokens, self.cfg.d_model),
                jnp.float32)
        return b

    def step(self) -> int:
        """One decode step across all active slots; returns #active."""
        n = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            n += 1
            last = jnp.asarray([[req.out[-1]]], jnp.int32)
            logits, cache, aux = self._decode(self.params, self._batch(last),
                                              self.caches[s],
                                              jnp.int32(self.pos[s]))
            self.caches[s] = cache
            self.pos[s] += 1
            tok = int(jnp.argmax(logits, -1)[0])
            req.out.append(tok)
            # keep the raw device scalars; only the retiring step pays the
            # host transfer (intermediate steps are overwritten anyway)
            self._last_aux[id(req)] = aux
            if (req.eos is not None and tok == req.eos) \
                    or len(req.out) >= req.max_new \
                    or self.pos[s] >= self.capacity - 1:
                req.stats = {k: float(v) for k, v
                             in self._last_aux.pop(id(req)).items()}
                req.done = True
                self.active[s] = None       # retire -> slot reusable
        return n

    def run(self, requests: List[Request], max_steps: int = 512):
        """Drive admission + decode until done (or the step budget runs out);
        returns the completed requests in submission order."""
        pending = list(requests)
        for _ in range(max_steps):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            if self.step() == 0 and not pending:
                break
        return [r for r in requests if r.done]
