"""Batched continuous-batching engine over a PAGED KV cache.

All active slots decode in ONE jitted forward — this is where the paper's
throughput story meets serving: every MoE layer sees the whole decode
batch and builds exactly one ``DispatchPlan`` per step.  On top of the
PR 3 batched step, the cache is now *paged* (DESIGN.md §9, vLLM-style,
scaled to this container):

* **Paged pool, host block tables.**  The device holds a global pool of
  fixed-size KV blocks (serve/kv_cache.py); each slot owns a block table.
  Reads gather the slot's logical view through the table, writes scatter
  block-granular — the contiguous ``(slots, capacity)`` buffer and its
  device row swaps are gone (slot compaction is a host-side table move).
* **Chunked prefill rides the decode plan.**  Admission assigns a slot
  and nothing else; the prompt is processed as fixed-size chunks of
  tokens that join the decode step's token batch — one forward, one
  DispatchPlan per MoE layer covering decode tokens AND chunk tokens
  together.  Prefill never stalls decoding slots, and MoE plans see
  larger, better-balanced batches (asserted via plan_dispatch counting).
* **Prefix caching.**  Full prompt blocks are content-hashed (chained) at
  admission; hit blocks are refcount-shared, their tokens skip both
  attention prefill and MoE dispatch entirely (chunking starts after the
  cached prefix).  Retired blocks park in an LRU pool for future hits.
* **One sync per step** (unchanged): argmax + EOS compare on device, one
  host transfer for the whole token batch.

Families whose caches are not positional KV (rwkv/ssm recurrent state,
the vlm image-KV cross blocks, zamba2's mamba layers) fall back to the
pre-paging contiguous engine — same public behavior, selected
automatically (or force with ``kv_block_size=0``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import RunConfig, init_cache, swap_cache_slots
from repro.serve.admission import get_admission
from repro.serve.kv_cache import PagedKVCache, paged_supported
from repro.serve.step import (make_paged_step, make_slot_decode_step,
                              make_slot_prefill_step)

DEFAULT_KV_BLOCK = 16


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new: int = 16
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # dispatch-plan telemetry, set at retirement from the request's final
    # step (router aux + sched/* ScheduleStats when the model is MoE and
    # stats are enabled), summed over the MoE layers of that step; the
    # plan is shared by every token in that step, and ``serve/decode_batch``
    # records how many slots decoded in it.  Paged runs add
    # ``serve/prefix_hit_tokens`` (prompt tokens served from shared
    # blocks, never dispatched) and ``serve/prefill_forwards`` (chunk
    # steps this request's prompt rode in).
    stats: dict = dataclasses.field(default_factory=dict)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 capacity: int = 256, rc: Optional[RunConfig] = None,
                 admission: str = "fcfs",
                 kv_block_size: Optional[int] = None,
                 prefix_cache: bool = True, prefill_chunk: int = 32):
        self.cfg = cfg
        # serving default: the dynamic schedule policy — production traffic
        # is skewed and decode batches are small, exactly the regime where
        # the fixed tile layout pads worst (DESIGN.md §3) — with per-plan
        # telemetry on so operators see padding/drop behavior per request
        self.rc = rc or RunConfig(q_chunk=64, kv_chunk=64,
                                  schedule_policy="dynamic", moe_stats=True)
        if self.rc.quant != "none" and cfg.is_moe:
            # load-time transform: routed experts compressed under the
            # selected scheme (idempotent if params already carry the tag)
            from repro.quantization import quantize_params_tree
            params = quantize_params_tree(params, self.rc.quant)
        self.params = params
        self.slots = slots
        self.capacity = capacity
        if kv_block_size is None:       # auto: paged wherever pageable
            kv_block_size = DEFAULT_KV_BLOCK if paged_supported(cfg) else 0
        self.kv_block_size = kv_block_size
        self.paged = kv_block_size > 0
        self.prefill_chunk = max(1, prefill_chunk)
        self.pos = np.zeros(slots, np.int64)          # per-slot positions
        # active requests occupy slots [0, n_active) — prefix invariant
        # (paged keeps it too: compaction is a host-side table move)
        self.active: List[Optional[Request]] = [None] * slots
        self.n_active = 0
        # per-active-request shared step aux (device scalars; materialized
        # into Request.stats at retirement), keyed by rid — id(req) of a
        # retired request can be recycled by the allocator
        self._last_aux: Dict[int, dict] = {}
        # requests still in flight/pending when run()'s step budget ran out
        self.dropped: List[Request] = []
        self._admission = get_admission(admission)

        if self.paged:
            self.kv = PagedKVCache(cfg, slots, capacity, kv_block_size,
                                   prefix_cache=prefix_cache)
            self.cache = None
            self._pstep = make_paged_step(cfg, self.rc)
            # prompt-prefill cursor: prompt tokens whose KV is written
            self._prefill_next = np.zeros(slots, np.int64)
            self._prefix_hit = np.zeros(slots, np.int64)
            self._prefill_forwards = np.zeros(slots, np.int64)
        else:
            # ONE batched contiguous cache; slot s owns row s of every leaf
            self.kv = None
            self.cache = init_cache(cfg, slots, capacity)
            self._prefill = make_slot_prefill_step(cfg, self.rc)
            # one compiled decode step per distinct active count (<= slots)
            self._decode_steps: Dict[int, object] = {}
            self._swap = jax.jit(swap_cache_slots)

    # ------------------------------------------------------------------
    def _batch(self, toks):
        b = {"tokens": toks}
        if self.cfg.cross_attn_every:
            b["image_embeds"] = jnp.zeros(
                (toks.shape[0], self.cfg.n_image_tokens, self.cfg.d_model),
                jnp.float32)
        return b

    def admit(self, req: Request) -> bool:
        """Claim a free slot for ``req``; False if full.

        Contiguous mode prefills the whole prompt here (one forward).
        Paged mode only attaches prefix-cache hits and sets the chunk
        cursor — the prompt is processed chunk-by-chunk inside subsequent
        ``step()`` token batches, so admission never runs a forward."""
        if self.n_active >= self.slots:
            return False
        if any(r is not None and r.rid == req.rid for r in self.active):
            # telemetry is keyed by rid; two live requests sharing one
            # would silently cross their stats and crash at retirement
            raise ValueError(f"rid {req.rid} is already active")
        s = self.n_active
        if self.paged:
            # capacity governs, not the block-rounded table size: a
            # prompt in the rounding slack would fit the blocks but
            # diverge from the contiguous engine's (slots, capacity) rows
            limit = min(self.capacity,
                        self.kv.blocks_per_slot * self.kv.block_size)
            if len(req.prompt) > limit:
                # fail loudly BEFORE claiming a slot (a mid-step failure
                # would take every active request's state down with it)
                raise ValueError(
                    f"prompt of {len(req.prompt)} tokens exceeds slot "
                    f"capacity {limit} ({self.kv.blocks_per_slot} blocks "
                    f"of {self.kv.block_size})")
            n_cached = self.kv.attach_prefix(s, req.prompt)
            self.pos[s] = n_cached
            self._prefill_next[s] = n_cached
            self._prefix_hit[s] = n_cached
            self._prefill_forwards[s] = 0
            self._last_aux[req.rid] = {}
        else:
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            tok, self.cache, aux = self._prefill(
                self.params, self.cache, self._batch(toks), jnp.int32(s))
            self.pos[s] = len(req.prompt)
            req.out.append(int(tok[0]))
            self._last_aux[req.rid] = aux
        self.active[s] = req
        self.n_active += 1
        return True

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine step: ONE jit call, ONE host sync, covering every
        active slot.  Returns the number of TOKENS processed (== active
        slots in a pure-decode step; larger while prompts are chunk-
        prefilling in paged mode; 0 when idle)."""
        return self._step_paged() if self.paged else self._step_contig()

    # -- paged ---------------------------------------------------------
    def _step_paged(self) -> int:
        n = self.n_active
        if n == 0:
            return 0
        # assemble the step's token batch: per slot either its decode
        # token or the next chunk of its prompt
        rows = []                       # (slot, token, position, kind)
        for s in range(n):
            r = self.active[s]
            nx = int(self._prefill_next[s])
            P = len(r.prompt)
            if nx < P:
                c = min(self.prefill_chunk, P - nx)
                for j in range(c):
                    kind = "final" if nx + j == P - 1 else "chunk"
                    rows.append((s, int(r.prompt[nx + j]), nx + j, kind))
            else:
                rows.append((s, r.out[-1], int(self.pos[s]), "decode"))
        for s in {row[0] for row in rows}:
            self.kv.ensure_allocated(
                s, max(p for sl, _, p, _ in rows if sl == s))
        tables = jnp.asarray(self.kv.table_rows([row[0] for row in rows]))
        toks = jnp.asarray([[t] for _, t, _, _ in rows], jnp.int32)
        pos = jnp.asarray([p for _, _, p, _ in rows], jnp.int32)
        eos = jnp.asarray(
            [(-1 if (k != "decode" or self.active[s].eos is None)
              else self.active[s].eos) for s, _, _, k in rows], jnp.int32)
        tok, eos_hit, self.kv.pools, aux = self._pstep(
            self.params, self.kv.pools, self._batch(toks), pos, tables, eos)
        tok_np, eos_np = jax.device_get((tok, eos_hit))  # the ONE host sync

        decode_row: Dict[int, int] = {}
        chunks = np.zeros(n, np.int64)
        for i, (s, _t, _p, kind) in enumerate(rows):
            self._last_aux[self.active[s].rid] = aux
            if kind == "decode":
                self.active[s].out.append(int(tok_np[i]))
                self.pos[s] += 1
                decode_row[s] = i
            else:
                chunks[s] += 1
                if kind == "final":       # prompt complete: first token
                    self.active[s].out.append(int(tok_np[i]))
        for s in np.nonzero(chunks)[0]:
            self._prefill_next[s] += chunks[s]
            self.pos[s] += chunks[s]
            self._prefill_forwards[s] += 1
            self.kv.register_filled(int(s), self.active[s].prompt,
                                    int(self._prefill_next[s]))
        # retire top-down so compaction (move-last-into-freed) never moves
        # a slot we still have to examine
        n_decode = len(decode_row)
        for s in range(n - 1, -1, -1):
            if s not in decode_row:
                continue
            r = self.active[s]
            if bool(eos_np[decode_row[s]]) or len(r.out) >= r.max_new \
                    or self.pos[s] >= self.capacity - 1:
                self._retire(s, decode_batch=n_decode)
        return len(rows)

    # -- contiguous (pre-paging fallback) ------------------------------
    def _step_contig(self) -> int:
        n = self.n_active
        if n == 0:
            return 0
        reqs = self.active[:n]
        last = jnp.asarray([[r.out[-1]] for r in reqs], jnp.int32)   # (n, 1)
        pos = jnp.asarray(self.pos[:n], jnp.int32)                   # (n,)
        eos = jnp.asarray([-1 if r.eos is None else r.eos for r in reqs],
                          jnp.int32)
        fn = self._decode_steps.get(n)
        if fn is None:
            fn = self._decode_steps[n] = make_slot_decode_step(
                self.cfg, self.rc, n)
        tok, eos_hit, self.cache, aux = fn(
            self.params, self.cache, self._batch(last), pos, eos)
        tok_np, eos_np = jax.device_get((tok, eos_hit))  # the ONE host sync
        for s, r in enumerate(reqs):
            r.out.append(int(tok_np[s]))
            self.pos[s] += 1
            self._last_aux[r.rid] = aux
        # retire top-down so the swap-with-last compaction never moves a
        # slot we still have to examine
        for s in range(n - 1, -1, -1):
            r = self.active[s]
            if bool(eos_np[s]) or len(r.out) >= r.max_new \
                    or self.pos[s] >= self.capacity - 1:
                self._retire(s, decode_batch=n)
        return n

    # ------------------------------------------------------------------
    def _retire(self, s: int, *, decode_batch: int) -> None:
        """Free slot ``s``: materialize telemetry, keep the active prefix
        contiguous (paged: host-side table move + block refcount release;
        contiguous: device row swap)."""
        req = self.active[s]
        req.stats = {k: float(v)
                     for k, v in self._last_aux.pop(req.rid).items()}
        req.stats["serve/decode_batch"] = float(decode_batch)
        last = self.n_active - 1
        if self.paged:
            req.stats["serve/prefix_hit_tokens"] = float(self._prefix_hit[s])
            req.stats["serve/prefill_forwards"] = \
                float(self._prefill_forwards[s])
            self.kv.release_slot(s)
            if s != last:
                self.kv.move_slot(s, last)
                self.active[s] = self.active[last]
                self.pos[s] = self.pos[last]
                self._prefill_next[s] = self._prefill_next[last]
                self._prefix_hit[s] = self._prefix_hit[last]
                self._prefill_forwards[s] = self._prefill_forwards[last]
            self._prefill_next[last] = 0
            self._prefix_hit[last] = 0
            self._prefill_forwards[last] = 0
        else:
            if s != last:
                self.cache = self._swap(self.cache, jnp.int32(s),
                                        jnp.int32(last))
                self.active[s] = self.active[last]
                self.pos[s] = self.pos[last]
        req.done = True
        self.active[last] = None
        self.pos[last] = 0
        self.n_active -= 1

    def run(self, requests: List[Request], max_steps: int = 512):
        """Drive admission + decode until done (or the step budget runs
        out).  Returns the completed requests in submission order; requests
        still in flight or never admitted keep ``done=False`` (with any
        partial ``out``) and are collected in ``self.dropped``.  A later
        ``run`` may resume them: requests already occupying a slot (or
        already done) are excluded from admission so they are never
        re-prefilled, but active slots keep decoding."""
        live = {id(r) for r in self.active if r is not None}
        pending = [r for r in requests if not r.done and id(r) not in live]
        self.dropped = []
        for _ in range(max_steps):
            while pending and self.n_active < self.slots:
                self.admit(pending.pop(
                    self._admission(pending, engine=self)))
            if self.step() == 0 and not pending:
                break
        self.dropped = [r for r in requests if not r.done]
        return [r for r in requests if r.done]
