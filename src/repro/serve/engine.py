"""Batched continuous-batching engine over a PAGED KV cache.

All active slots decode in ONE jitted forward — this is where the paper's
throughput story meets serving: every MoE layer sees the whole decode
batch and builds exactly one ``DispatchPlan`` per step.  On top of the
PR 3 batched step, the cache is now *paged* (DESIGN.md §9, vLLM-style,
scaled to this container):

* **Paged pool, host block tables.**  The device holds a global pool of
  fixed-size KV blocks (serve/kv_cache.py); each slot owns a block table.
  Reads gather the slot's logical view through the table, writes scatter
  block-granular — the contiguous ``(slots, capacity)`` buffer and its
  device row swaps are gone (slot compaction is a host-side table move).
* **Chunked prefill rides the decode plan.**  Admission assigns a slot
  and nothing else; the prompt is processed as fixed-size chunks of
  tokens that join the decode step's token batch — one forward, one
  DispatchPlan per MoE layer covering decode tokens AND chunk tokens
  together.  Prefill never stalls decoding slots, and MoE plans see
  larger, better-balanced batches (asserted via plan_dispatch counting).
* **Prefix caching.**  Full prompt blocks are content-hashed (chained) at
  admission; hit blocks are refcount-shared, their tokens skip both
  attention prefill and MoE dispatch entirely (chunking starts after the
  cached prefix).  Retired blocks park in an LRU pool for future hits.
* **One sync per step** (unchanged): argmax + EOS compare on device, one
  host transfer for the whole token batch.

Families whose caches are not positional KV (rwkv/ssm recurrent state,
the vlm image-KV cross blocks, zamba2's mamba layers) fall back to the
pre-paging contiguous engine — same public behavior, selected
automatically (or force with ``kv_block_size=0``).

**Observability (DESIGN.md §10).**  The engine accepts an
``Observability`` bundle (repro.obs; default: null sinks): every step is
bracketed into Chrome-trace spans (assemble / forward dispatch / the one
host sync / postprocess), admission and the prefix-hash probe are
spanned, recompile events fire from inside the jitted bodies at trace
time, per-step KV-pool occupancy lands as gauges, the PR 2
``StragglerMonitor`` flags slow steps, and retirement absorbs the
request's ``sched/*`` plan stats into histograms.  All of it is
host-side wall-clock over already-materialized values — NO device op is
added, so greedy tokens are bitwise-identical with observability on or
off (asserted in tests/test_obs.py).  Per-request latency accounting
(``lat/*`` in ``Request.stats``: queue wait, TTFT, TPOT, E2E — the
MoE-Inference-Bench axes) is always on; it costs a handful of host clock
reads per step.  The ``lat/*`` + ``serve/*`` key schema is identical
between the paged and contiguous engines.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.execution.base import set_plan_hook
from repro.models.lm import RunConfig, init_cache, swap_cache_slots
from repro.obs import NOOP, RequestTimeline
from repro.sampling import SamplingConfig
from repro.serve.admission import get_admission
from repro.serve.kv_cache import PagedKVCache, paged_supported
from repro.serve.step import (make_paged_step, make_slot_decode_step,
                              make_slot_prefill_step)

DEFAULT_KV_BLOCK = 16


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new: int = 16
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # per-request SLO deadlines (seconds on the engine clock; None = no
    # deadline).  The ``slo`` admission policy admits by TTFT-deadline
    # feasibility and preempts active requests that blew them; everything
    # else ignores these fields.
    slo_ttft: Optional[float] = None
    slo_tpot: Optional[float] = None
    # per-request sampling seed (repro.sampling); None derives a unique
    # seed from the engine's SamplingConfig base + rid.  Greedy ignores it.
    seed: Optional[int] = None
    # dispatch-plan telemetry, set at retirement from the request's final
    # step (router aux + sched/* ScheduleStats when the model is MoE and
    # stats are enabled), summed over the MoE layers of that step; the
    # plan is shared by every token in that step, and ``serve/decode_batch``
    # records how many slots decoded in it.  Paged runs add
    # ``serve/prefix_hit_tokens`` (prompt tokens served from shared
    # blocks, never dispatched) and ``serve/prefill_forwards`` (chunk
    # steps this request's prompt rode in).
    stats: dict = dataclasses.field(default_factory=dict)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 capacity: int = 256, rc: Optional[RunConfig] = None,
                 admission: str = "fcfs",
                 kv_block_size: Optional[int] = None,
                 prefix_cache: bool = True, prefill_chunk: int = 32,
                 obs=None, sampling: Optional[SamplingConfig] = None):
        self.cfg = cfg
        # sampling config (repro.sampling); the greedy default keeps the
        # literal argmax path inside the jitted steps, bitwise-identical
        # to every prior PR
        self.sampling = sampling or SamplingConfig()
        # observability bundle (repro.obs); the null default makes every
        # span/counter call a no-op — zero cost when off
        self.obs = obs or NOOP
        self._clock = self.obs.clock
        # serving default: the dynamic schedule policy — production traffic
        # is skewed and decode batches are small, exactly the regime where
        # the fixed tile layout pads worst (DESIGN.md §3) — with per-plan
        # telemetry on so operators see padding/drop behavior per request
        self.rc = rc or RunConfig(q_chunk=64, kv_chunk=64,
                                  schedule_policy="dynamic", moe_stats=True)
        if self.rc.quant != "none" and cfg.is_moe:
            # load-time transform: routed experts compressed under the
            # selected scheme (idempotent if params already carry the tag)
            from repro.quantization import quantize_params_tree
            params = quantize_params_tree(params, self.rc.quant)
        self.params = params
        self.slots = slots
        self.capacity = capacity
        if kv_block_size is None:       # auto: paged wherever pageable
            kv_block_size = DEFAULT_KV_BLOCK if paged_supported(cfg) else 0
        self.kv_block_size = kv_block_size
        self.paged = kv_block_size > 0
        self.prefill_chunk = max(1, prefill_chunk)
        self.pos = np.zeros(slots, np.int64)          # per-slot positions
        # active requests occupy slots [0, n_active) — prefix invariant
        # (paged keeps it too: compaction is a host-side table move)
        self.active: List[Optional[Request]] = [None] * slots
        self.n_active = 0
        # per-active-request shared step aux (device scalars; materialized
        # into Request.stats at retirement), keyed by rid — id(req) of a
        # retired request can be recycled by the allocator
        self._last_aux: Dict[int, dict] = {}
        # per-request latency timelines (host wall-clock; always on) —
        # keyed by rid, created at admission, popped at retirement; submit
        # stamps recorded by run() for queue-wait accounting
        self._timing: Dict[int, RequestTimeline] = {}
        self._submit: Dict[int, float] = {}
        self._step_idx = 0
        # requests still in flight/pending when run()'s step budget ran out
        self.dropped: List[Request] = []
        self._admission_name = admission
        self._admission = get_admission(admission)
        # streaming hook: called as ``on_token(req, tok)`` at the moment
        # the step's ONE host sync retires a token into ``req.out`` — the
        # front-end fans it out to per-request callbacks.  Purely host-side
        # (fires on already-materialized ints), so streaming adds no
        # device syncs and tokens are bitwise-identical to batch run().
        self.on_token = None
        # preempted requests' engine-side cursors, keyed by rid (the KV
        # table itself parks inside PagedKVCache under the same key)
        self._parked: Dict[int, dict] = {}
        # per-slot prefill source: the prompt for fresh admissions, or
        # prompt + out[:-1] when a resume must replay (re-prefill) a
        # preempted request whose parked KV is gone (contiguous mode, or
        # a reclaimed paged park)
        self._seq: List[Optional[np.ndarray]] = [None] * slots
        # preemption/resume accounting (plain ints: artifact counters must
        # not depend on an obs sink being attached)
        self.n_preempted = 0
        self.n_resumed = 0
        # step-cost estimate for SLO feasibility: measured EWMA of wall
        # seconds per step; virtual-time harnesses (the load generator)
        # override via step_time_hint
        self.step_time_hint: Optional[float] = None
        self._ewma_step_s: Optional[float] = None
        # target-model forwards executed (one per step that ran a forward;
        # the speculative engine's benchmark compares this against the
        # non-speculative baseline for its forward-count win)
        self.n_forwards = 0

        if self.paged:
            self.kv = PagedKVCache(cfg, slots, capacity, kv_block_size,
                                   prefix_cache=prefix_cache)
            self.kv.bind_obs(self.obs.metrics, self.obs.tracer)
            self.cache = None
            self._pstep = make_paged_step(cfg, self.rc, self.obs,
                                          self.sampling)
            # prompt-prefill cursor: prompt tokens whose KV is written
            self._prefill_next = np.zeros(slots, np.int64)
            self._prefix_hit = np.zeros(slots, np.int64)
            self._prefill_forwards = np.zeros(slots, np.int64)
        else:
            # ONE batched contiguous cache; slot s owns row s of every leaf
            self.kv = None
            self.cache = init_cache(cfg, slots, capacity)
            self._prefill = make_slot_prefill_step(cfg, self.rc, self.obs,
                                                   self.sampling)
            # one compiled decode step per distinct active count (<= slots)
            self._decode_steps: Dict[int, object] = {}
            self._swap = jax.jit(swap_cache_slots)

        if self.obs.enabled:
            # plan-stats hook: every TRACED plan_dispatch reports its
            # token count/backend/policy (process-global; last bundle
            # installed wins — one observability bundle per process)
            set_plan_hook(self.obs.on_plan)
            if self.rc.quant != "none" and cfg.is_moe:
                # the decode-dominant cost this serving config moves per
                # expert gather: compressed payload bytes under the scheme
                from repro.quantization import QuantTensor
                leaves = jax.tree.leaves(
                    self.params,
                    is_leaf=lambda x: isinstance(x, QuantTensor))
                self.obs.metrics.set_gauge(
                    "serve/quant_expert_bytes",
                    sum(l.nbytes for l in leaves
                        if isinstance(l, QuantTensor)),
                    scheme=self.rc.quant)

    # ------------------------------------------------------------------
    def _batch(self, toks):
        b = {"tokens": toks}
        if self.cfg.cross_attn_every:
            b["image_embeds"] = jnp.zeros(
                (toks.shape[0], self.cfg.n_image_tokens, self.cfg.d_model),
                jnp.float32)
        return b

    def _req_seed(self, req: Request) -> int:
        """The request's effective sampling seed: its own, or a unique
        per-rid derivation from the engine base — so requests in one
        batch draw independent streams by default (tests/test_sampling.py
        asserts independence and batched-vs-unbatched identity)."""
        return req.seed if req.seed is not None \
            else self.sampling.seed + req.rid

    def admit(self, req: Request) -> bool:
        """Claim a free slot for ``req``; False if full.

        Contiguous mode prefills the whole prompt here (one forward).
        Paged mode only attaches prefix-cache hits and sets the chunk
        cursor — the prompt is processed chunk-by-chunk inside subsequent
        ``step()`` token batches, so admission never runs a forward."""
        if self.n_active >= self.slots:
            return False
        if any(r is not None and r.rid == req.rid for r in self.active):
            # telemetry is keyed by rid; two live requests sharing one
            # would silently cross their stats and crash at retirement
            raise ValueError(f"rid {req.rid} is already active")
        t_admit = self._clock()
        with self.obs.tracer.span("serve/admit", rid=req.rid,
                                  prompt_tokens=len(req.prompt)):
            self._admit(req, t_admit)
        self.obs.metrics.inc("serve/admitted")
        return True

    def _emit(self, req: Request, tok: int, t: float) -> None:
        """Retire one token into ``req.out`` (post-host-sync): latency
        stamp + the streaming hook, zero additional device work."""
        req.out.append(tok)
        self._timing[req.rid].on_token(t)
        if self.on_token is not None:
            self.on_token(req, tok)

    def _admit(self, req: Request, t_admit: float) -> None:
        s = self.n_active
        # a resumed (previously preempted/dropped) request keeps its
        # original timeline so TTFT/queue-wait/E2E stay anchored at the
        # first submission; a fresh request gets a new one.  Queue wait
        # spans run()'s submit stamp -> slot claim; a request admitted
        # directly (no run()) has zero queue wait by definition.
        tl = self._timing.get(req.rid)
        resumed = tl is not None       # preempted earlier: timeline kept
        if tl is None:
            tl = RequestTimeline(submit=self._submit.pop(req.rid, t_admit),
                                 admit=t_admit)
            self._timing[req.rid] = tl
        # prefill source: fresh prompts verbatim; a resume with no parked
        # KV replays prompt + generated-so-far (minus the last token,
        # which seeds the next decode) — greedy determinism makes the
        # recomputed KV identical to what preemption threw away
        if req.out:
            seq = np.concatenate([np.asarray(req.prompt, np.int64),
                                  np.asarray(req.out[:-1], np.int64)]
                                 ).astype(np.int32)
        else:
            seq = req.prompt
        if self.paged:
            park = self._parked.pop(req.rid, None)
            if park is not None and self.kv.resume_slot(s, req.rid):
                # host-side table un-park: KV intact, nothing recomputed
                self.pos[s] = park["pos"]
                self._prefill_next[s] = park["prefill_next"]
                self._prefix_hit[s] = park["prefix_hit"]
                self._prefill_forwards[s] = park["prefill_forwards"]
                self._seq[s] = park["seq"]
            else:
                # capacity governs, not the block-rounded table size: a
                # prompt in the rounding slack would fit the blocks but
                # diverge from the contiguous engine's (slots, capacity)
                # rows
                limit = min(self.capacity,
                            self.kv.blocks_per_slot * self.kv.block_size)
                if len(seq) > limit:
                    # fail loudly BEFORE claiming a slot (a mid-step
                    # failure would take every active request's state
                    # down with it)
                    raise ValueError(
                        f"prompt of {len(seq)} tokens exceeds slot "
                        f"capacity {limit} ({self.kv.blocks_per_slot} "
                        f"blocks of {self.kv.block_size})")
                n_cached = self.kv.attach_prefix(s, seq)
                self.pos[s] = n_cached
                self._prefill_next[s] = n_cached
                self._prefix_hit[s] = n_cached
                self._prefill_forwards[s] = 0
                self._seq[s] = seq
            self._last_aux[req.rid] = {}
        else:
            toks = jnp.asarray(seq, jnp.int32)[None]
            with self.obs.tracer.span("serve/prefill", rid=req.rid,
                                      prompt_tokens=len(seq)):
                tok, self.cache, aux = self._prefill(
                    self.params, self.cache, self._batch(toks),
                    jnp.int32(s), jnp.int32(self._req_seed(req)))
                self.n_forwards += 1
                self.pos[s] = len(seq)
                first = int(tok[0])             # forces the prefill sync
            self._last_aux[req.rid] = aux
            self._seq[s] = seq
        self.active[s] = req
        self.n_active += 1
        if not self.paged and not resumed:
            # first token: TTFT stamp + stream.  A resume's prefill output
            # is a token the request already streamed (the replay's last
            # logits re-predict out[-1]) — recompute only, never re-emit.
            self._emit(req, first, self._clock())
        if resumed:
            self.n_resumed += 1
            self.obs.metrics.inc("serve/resumed")
            self.obs.tracer.instant("serve/resume", rid=req.rid, slot=s)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine step: ONE jit call, ONE host sync, covering every
        active slot.  Returns the number of TOKENS processed (== active
        slots in a pure-decode step; larger while prompts are chunk-
        prefilling in paged mode; 0 when idle)."""
        t0 = self._clock()
        n = self._step_paged() if self.paged else self._step_contig()
        if n:
            dt = self._clock() - t0
            self._ewma_step_s = dt if self._ewma_step_s is None \
                else 0.7 * self._ewma_step_s + 0.3 * dt
        return n

    def step_time_estimate(self) -> float:
        """Expected wall seconds per engine step — what the ``slo``
        admission policy prices TTFT/TPOT feasibility with.  Virtual-time
        harnesses set ``step_time_hint``; otherwise the measured EWMA
        (0.0 until the first step has been timed)."""
        if self.step_time_hint is not None:
            return self.step_time_hint
        return self._ewma_step_s or 0.0

    # -- paged ---------------------------------------------------------
    def _step_paged(self) -> int:
        n = self.n_active
        if n == 0:
            return 0
        obs, i_step = self.obs, self._step_idx
        obs.step_begin(i_step)
        with obs.tracer.span("serve/step", step=i_step, active=n):
            # assemble the step's token batch: per slot either its decode
            # token or the next chunk of its prompt
            with obs.tracer.span("serve/assemble"):
                rows = []                   # (slot, token, position, kind)
                for s in range(n):
                    r = self.active[s]
                    seq = self._seq[s]
                    nx = int(self._prefill_next[s])
                    P = len(seq)
                    if nx < P:
                        c = min(self.prefill_chunk, P - nx)
                        for j in range(c):
                            # the last prefill token seeds the request's
                            # first output ("final") — except on a resume
                            # replay, whose outputs already exist: the
                            # replay only rebuilds KV, it emits nothing
                            kind = ("final" if nx + j == P - 1
                                    and not r.out else "chunk")
                            rows.append((s, int(seq[nx + j]),
                                         nx + j, kind))
                    else:
                        rows.append((s, r.out[-1], int(self.pos[s]),
                                     "decode"))
                for s in {row[0] for row in rows}:
                    self.kv.ensure_allocated(
                        s, max(p for sl, _, p, _ in rows if sl == s))
                tables = jnp.asarray(
                    self.kv.table_rows([row[0] for row in rows]))
                toks = jnp.asarray([[t] for _, t, _, _ in rows], jnp.int32)
                pos = jnp.asarray([p for _, _, p, _ in rows], jnp.int32)
                eos = jnp.asarray(
                    [(-1 if (k != "decode" or self.active[s].eos is None)
                      else self.active[s].eos)
                     for s, _, _, k in rows], jnp.int32)
                # stochastic-draw keys: each row's (request seed, output
                # index it produces).  Chunk rows' draws are discarded;
                # keyed draws are stateless, so they disturb nothing.
                seeds = jnp.asarray(
                    [self._req_seed(self.active[s])
                     for s, _, _, _ in rows], jnp.int32)
                counters = jnp.asarray(
                    [(len(self.active[s].out) if k == "decode" else 0)
                     for s, _, _, k in rows], jnp.int32)
            with obs.tracer.span("serve/forward", tokens=len(rows)):
                tok, eos_hit, self.kv.pools, aux = self._pstep(
                    self.params, self.kv.pools, self._batch(toks), pos,
                    tables, eos, seeds, counters)
                self.n_forwards += 1
            with obs.tracer.span("serve/host_sync"):   # the ONE host sync
                tok_np, eos_np = jax.device_get((tok, eos_hit))
            # one stamp shared by every token this step produced (they
            # all come from the same forward)
            t_now = self._clock()

            with obs.tracer.span("serve/postprocess"):
                decode_row: Dict[int, int] = {}
                chunks = np.zeros(n, np.int64)
                for i, (s, _t, _p, kind) in enumerate(rows):
                    self._last_aux[self.active[s].rid] = aux
                    if kind == "decode":
                        self._emit(self.active[s], int(tok_np[i]), t_now)
                        self.pos[s] += 1
                        decode_row[s] = i
                    else:
                        chunks[s] += 1
                        if kind == "final":   # prompt complete: 1st token
                            self._emit(self.active[s], int(tok_np[i]),
                                       t_now)
                for s in np.nonzero(chunks)[0]:
                    self._prefill_next[s] += chunks[s]
                    self.pos[s] += chunks[s]
                    self._prefill_forwards[s] += 1
                    self.kv.register_filled(int(s), self._seq[s],
                                            int(self._prefill_next[s]))
                # retire top-down so compaction (move-last-into-freed)
                # never moves a slot we still have to examine
                n_decode = len(decode_row)
                for s in range(n - 1, -1, -1):
                    if s not in decode_row:
                        continue
                    r = self.active[s]
                    if bool(eos_np[decode_row[s]]) \
                            or len(r.out) >= r.max_new \
                            or self.pos[s] >= self.capacity - 1:
                        self._retire(s, decode_batch=n_decode)
        self._end_step(i_step, tokens=len(rows))
        return len(rows)

    def _end_step(self, i_step: int, *, tokens: int) -> None:
        """Close the step's observability bracket: straggler window,
        per-step counters, KV-pool occupancy gauges."""
        obs = self.obs
        obs.step_end(i_step)
        self._step_idx += 1
        if obs.enabled:
            obs.metrics.inc("serve/steps")
            obs.metrics.inc("serve/step_tokens", tokens)
            if self.paged:
                st = self.kv.stats()
                for k in ("blocks_total", "blocks_in_use",
                          "blocks_parked"):
                    obs.metrics.set_gauge(f"kv/{k}", st[k])

    # -- contiguous (pre-paging fallback) ------------------------------
    def _step_contig(self) -> int:
        n = self.n_active
        if n == 0:
            return 0
        obs, i_step = self.obs, self._step_idx
        obs.step_begin(i_step)
        with obs.tracer.span("serve/step", step=i_step, active=n):
            with obs.tracer.span("serve/assemble"):
                reqs = self.active[:n]
                last = jnp.asarray([[r.out[-1]] for r in reqs],
                                   jnp.int32)                    # (n, 1)
                pos = jnp.asarray(self.pos[:n], jnp.int32)       # (n,)
                eos = jnp.asarray([-1 if r.eos is None else r.eos
                                   for r in reqs], jnp.int32)
                fn = self._decode_steps.get(n)
                if fn is None:
                    fn = self._decode_steps[n] = make_slot_decode_step(
                        self.cfg, self.rc, n, self.obs, self.sampling)
                seeds = jnp.asarray([self._req_seed(r) for r in reqs],
                                    jnp.int32)
                counters = jnp.asarray([len(r.out) for r in reqs],
                                       jnp.int32)
            with obs.tracer.span("serve/forward", tokens=n):
                tok, eos_hit, self.cache, aux = fn(
                    self.params, self.cache, self._batch(last), pos, eos,
                    seeds, counters)
                self.n_forwards += 1
            with obs.tracer.span("serve/host_sync"):   # the ONE host sync
                tok_np, eos_np = jax.device_get((tok, eos_hit))
            t_now = self._clock()
            with obs.tracer.span("serve/postprocess"):
                for s, r in enumerate(reqs):
                    self._emit(r, int(tok_np[s]), t_now)
                    self.pos[s] += 1
                    self._last_aux[r.rid] = aux
                # retire top-down so the swap-with-last compaction never
                # moves a slot we still have to examine
                for s in range(n - 1, -1, -1):
                    r = self.active[s]
                    if bool(eos_np[s]) or len(r.out) >= r.max_new \
                            or self.pos[s] >= self.capacity - 1:
                        self._retire(s, decode_batch=n)
        self._end_step(i_step, tokens=n)
        return n

    # ------------------------------------------------------------------
    def _retire(self, s: int, *, decode_batch: int) -> None:
        """Free slot ``s``: materialize telemetry, keep the active prefix
        contiguous (paged: host-side table move + block refcount release;
        contiguous: device row swap).

        ``Request.stats`` leaves with ONE schema across both engines
        (asserted in tests/test_obs.py): the step plan's aux (``sched/*``
        when MoE stats are on), the ``serve/*`` engine counters —
        ``decode_batch``, ``prefill_forwards`` (contiguous: always 1.0,
        the whole-prompt admission prefill), ``prefix_hit_tokens``
        (contiguous: always 0.0, no prefix index) — and the ``lat/*``
        latency family (obs/latency.py)."""
        req = self.active[s]
        req.stats = {k: float(v)
                     for k, v in self._last_aux.pop(req.rid).items()}
        req.stats["serve/decode_batch"] = float(decode_batch)
        if self.paged:
            req.stats["serve/prefix_hit_tokens"] = float(self._prefix_hit[s])
            req.stats["serve/prefill_forwards"] = \
                float(self._prefill_forwards[s])
            self.kv.release_slot(s)
        else:
            req.stats["serve/prefix_hit_tokens"] = 0.0
            req.stats["serve/prefill_forwards"] = 1.0
        self._compact(s)
        tl = self._timing.pop(req.rid, None)
        if tl is not None:
            req.stats.update(tl.finalize(end=self._clock()))
        req.done = True
        obs = self.obs
        obs.tracer.instant("serve/retire", rid=req.rid)
        if obs.enabled:
            m = obs.metrics
            m.inc("serve/completed")
            for key in ("ttft_s", "tpot_s", "queue_wait_s", "e2e_s"):
                if f"lat/{key}" in req.stats:
                    m.observe(f"serve/{key}", req.stats[f"lat/{key}"])
            # SLO outcome at retirement: deadline misses by family
            if req.slo_ttft is not None \
                    and req.stats.get("lat/ttft_s", 0.0) > req.slo_ttft:
                m.inc("serve/slo_ttft_miss")
            if req.slo_tpot is not None \
                    and req.stats.get("lat/tpot_s", 0.0) > req.slo_tpot:
                m.inc("serve/slo_tpot_miss")
            # absorb the retirement-time plan stats (summed over the MoE
            # layers of the request's final step) as histogram samples
            m.observe_many("", {k: v for k, v in req.stats.items()
                                if k.startswith("sched/")})
            # under EP the skew table must stay honest: dropped-token
            # totals from the sharded/replicated dispatch accumulate into
            # a dedicated counter
            if self.rc.ep and "sched/dropped_rows" in req.stats:
                m.inc("serve/ep_dropped_tokens",
                      int(req.stats["sched/dropped_rows"]))

    def _compact(self, s: int) -> None:
        """Vacate slot ``s`` keeping the active prefix contiguous (paged:
        host-side table move; contiguous: device row swap).  The slot's KV
        must already be released or parked by the caller."""
        last = self.n_active - 1
        if self.paged:
            if s != last:
                self.kv.move_slot(s, last)
                self.active[s] = self.active[last]
                self.pos[s] = self.pos[last]
                self._prefill_next[s] = self._prefill_next[last]
                self._prefix_hit[s] = self._prefix_hit[last]
                self._prefill_forwards[s] = self._prefill_forwards[last]
                self._seq[s] = self._seq[last]
            self._prefill_next[last] = 0
            self._prefix_hit[last] = 0
            self._prefill_forwards[last] = 0
        else:
            if s != last:
                self.cache = self._swap(self.cache, jnp.int32(s),
                                        jnp.int32(last))
                self.active[s] = self.active[last]
                self.pos[s] = self.pos[last]
                self._seq[s] = self._seq[last]
        self._seq[last] = None
        self.active[last] = None
        self.pos[last] = 0
        self.n_active -= 1

    def preempt(self, s: int) -> Request:
        """Evict the request in slot ``s`` mid-flight (the SLO admission
        policy's lever against over-budget/deadline-blown requests).  The
        request keeps ``done=False`` and its partial ``out``; paged mode
        parks its block table host-side under its rid (resume is pure
        bookkeeping — no KV recompute unless allocation pressure reclaims
        the park), contiguous mode abandons the cache row (resume
        re-prefills prompt + generated tokens, token-identical by greedy
        determinism).  A finite censored ``lat/*`` snapshot lands in
        ``Request.stats`` immediately so a never-resumed victim still
        reports real latency numbers."""
        if not (0 <= s < self.n_active):
            raise ValueError(f"no active request in slot {s} "
                             f"(n_active={self.n_active})")
        req = self.active[s]
        t_now = self._clock()
        if self.paged:
            self._parked[req.rid] = {
                "pos": int(self.pos[s]),
                "prefill_next": int(self._prefill_next[s]),
                "prefix_hit": int(self._prefix_hit[s]),
                "prefill_forwards": int(self._prefill_forwards[s]),
                "seq": self._seq[s],
            }
            self.kv.park_slot(s, req.rid)
        self._compact(s)
        self._last_aux.pop(req.rid, None)
        # censored latency snapshot: finite now, overwritten wholesale if
        # the request later resumes and retires.  The timeline itself
        # stays keyed so the resume keeps the original submit anchor.
        tl = self._timing.get(req.rid)
        if tl is not None:
            req.stats = dict(tl.finalize(end=t_now))
            req.stats["serve/preempted"] = 1.0
        self.n_preempted += 1
        self.obs.metrics.inc("serve/preempted")
        self.obs.tracer.instant("serve/preempt", rid=req.rid, slot=s,
                                decode_tokens=len(req.out))
        return req

    def enqueue(self, requests: List[Request]) -> List[Request]:
        """Stamp submit times and return the sublist eligible for
        admission (not done, not already occupying a slot).  Resubmission
        keeps a request's original queue-wait origin."""
        live = {id(r) for r in self.active if r is not None}
        pending = [r for r in requests if not r.done and id(r) not in live]
        t_submit = self._clock()
        for r in pending:
            self._submit.setdefault(r.rid, t_submit)
        return pending

    def schedule(self, pending: List[Request]) -> None:
        """One scheduling pass: let the admission policy preempt (policies
        exposing a ``.preempt(engine, pending)`` hook, e.g. ``slo``), then
        fill free slots from ``pending`` (mutated in place; victims of
        preemption rejoin it, resumable)."""
        pre = getattr(self._admission, "preempt", None)
        if pre is not None and pending:
            for s in sorted(pre(self, pending), reverse=True):
                pending.append(self.preempt(s))
        while pending and self.n_active < self.slots:
            self.admit(pending.pop(self._admission(pending, engine=self)))

    def run(self, requests: List[Request], max_steps: int = 512):
        """Drive admission + decode until done (or the step budget runs
        out).  Returns the completed requests in submission order; requests
        still in flight or never admitted keep ``done=False`` (with any
        partial ``out``) and are collected in ``self.dropped``.  A later
        ``run`` may resume them: requests already occupying a slot (or
        already done) are excluded from admission so they are never
        re-prefilled, but active slots keep decoding."""
        pending = self.enqueue(requests)
        self.dropped = []
        for _ in range(max_steps):
            self.schedule(pending)
            if self.step() == 0 and not pending:
                break
        self.dropped = [r for r in requests if not r.done]
        if self.dropped:
            self.finalize_drops(self.dropped)
            self.obs.metrics.inc("serve/dropped", len(self.dropped))
            self.obs.tracer.instant("serve/step_budget_exhausted",
                                    dropped=len(self.dropped))
        return [r for r in requests if r.done]

    def finalize_drops(self, requests: List[Request]) -> None:
        """Give every unfinished request a FINITE censored ``lat/*``
        snapshot (clocks stopped now) so all-dropped runs still report
        real latency numbers instead of silently vanishing from
        ``latency_summary``.  ``serve/dropped`` marks the censoring; a
        later resume-and-retire overwrites the snapshot wholesale."""
        t_now = self._clock()
        for r in requests:
            if r.done:
                continue
            tl = self._timing.get(r.rid)
            if tl is None:      # never admitted: pure queue wait
                tl = RequestTimeline(
                    submit=self._submit.get(r.rid, t_now), admit=t_now)
            stats = dict(tl.finalize(end=t_now))
            stats["serve/dropped"] = 1.0
            if r.stats.get("serve/preempted"):
                stats["serve/preempted"] = 1.0
            r.stats = stats

    def describe(self, *, seed=None) -> dict:
        """The cell config that makes a results/serve artifact row
        self-describing (report.py renders these columns)."""
        d = {"arch": self.cfg.name, "slots": self.slots,
             "capacity": self.capacity, "admission": self._admission_name,
             "executor": self.rc.executor,
             "schedule_policy": self.rc.schedule_policy,
             "quant": self.rc.quant, "kv_block_size": self.kv_block_size,
             "prefill_chunk": self.prefill_chunk if self.paged else 0,
             "paged_attn": self.rc.paged_attn,
             "autotune": self.rc.autotune,
             "sampling": self.sampling.method,
             "temperature": self.sampling.temperature,
             "sampling_seed": self.sampling.seed}
        if seed is not None:
            d["seed"] = seed
        return d
