"""Unified LM assembly for all 10 assigned architectures.

A model is a stack of *groups* scanned with ``lax.scan`` (keeps HLO small —
one group body regardless of depth), plus optional unstacked prefix/suffix
blocks for heterogeneous leading/trailing layers:

  dense / audio            group = [attn]                      x n_layers
  moe (deepseek/moonshot)  prefix = [moe_dense] x first_dense,
                           group = [moe]                       x rest
  gemma2 (local_global)    group = [attn_local, attn_global]   x n_layers/2
  vlm (cross every 5)      group = [attn,attn,attn,cross,attn] x n_layers/5
  ssm (rwkv6)              group = [rwkv]                      x n_layers
  hybrid (zamba2)          group = [shared_attn, mamba x 6]    x 13
                           suffix = [shared_attn, mamba x 3]
                           (shared_attn params: 2 unique blocks, round-robin
                           via gi %% 2 — exactly 14 applications over 81
                           mamba layers)

Three entry modes share one code path: ``train`` (full-seq logits -> chunked
CE), ``prefill`` (build KV caches, last-position logits), ``decode`` (one
token against a seq_len cache).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.moe_layer import apply_moe, dispatch_config, init_moe_params
from repro.models import rwkv6 as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import attention_block, init_attn
from repro.models.blocks import apply_norm, dense_init, init_norm, softcap
from repro.models.ffn import apply_ffn, init_ffn
from repro.models.mla import init_mla, mla_block


class RunConfig(NamedTuple):
    """Execution options orthogonal to the architecture."""
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    executor: str = "xla"            # registered MoE backend (repro.execution)
    ep: bool = False                 # EP all-to-all dispatch over 'model' axis
    ep_axis: str = "model"
    remat: bool = False
    q_chunk: int = 512               # 0 = full sequence (CP / decode)
    kv_chunk: int = 512
    loss_chunk: int = 1024
    fuse_gate_up: bool = True
    fold_combine: bool = True
    capacity_factor: float = 2.0     # EP buffer headroom
    schedule_policy: str = "fixed"   # fixed | capacity_factor | dynamic
                                     # (serving engine defaults to dynamic)
    block_m_min: int = 8             # dynamic policy's sub-block floor
                                     # (scheduling/dynamic.py sub_block);
                                     # autotune=True lets a swept
                                     # "sub_block" cache entry override it
                                     # per shape (repro.tuning)
    quant: str = "none"              # expert-weight QuantScheme for serving
                                     # (repro.quantization registry; the
                                     # serve engine / launchers quantize
                                     # params at load under this scheme)
    moe_stats: bool = False          # surface per-plan ScheduleStats in aux
                                     # (single-device dispatch only: EP plans
                                     # carry no schedule)
    unroll: bool = False             # python-loop the layer stack (roofline
                                     # validation: cost_analysis counts scan
                                     # bodies once; unrolled counts all)
    autotune: bool = False           # consult the persistent kernel tune
                                     # cache (repro.tuning) for swept block
                                     # sizes at trace time (pallas executor)
    paged_attn: str = "auto"         # paged decode attention read path:
                                     # auto   = fused kernel iff executor
                                     #          is pallas, else gather
                                     # fused  = always the fused Pallas
                                     #          paged-attention kernel
                                     # gather = gather_block_kv + flash
                                     #          (the differential oracle)
    ep_overlap: bool = False         # software-pipeline the sharded EP
                                     # dispatch: a2a of microbatch i+1 is
                                     # issued before the expert GEMMs of i
                                     # (X-MoE double buffering); False is
                                     # bitwise the straight-line path
    ep_microbatches: int = 2         # microbatch count when ep_overlap
                                     # (clamped to a divisor of T_local)
    ep_decode_layout: str = "replicated"  # EP token layout for decode
                                     # steps: replicated (psum combine) or
                                     # sharded (padding-free a2a)


# ----------------------------------------------------------------------
# Group structure
# ----------------------------------------------------------------------
def group_structure(cfg: ModelConfig):
    """-> (prefix_kinds, body_kinds, n_groups, suffix_kinds)."""
    L = cfg.n_layers
    if cfg.family == "hybrid":
        per = cfg.attn_every
        n_groups = (L - 3) // per                        # 13 for zamba2-7b
        rem = L - n_groups * per                         # 3
        return ([], ["shared_attn"] + ["mamba"] * per, n_groups,
                ["shared_attn"] + ["mamba"] * rem)
    if cfg.family == "ssm":
        return [], ["rwkv"], L, []
    if cfg.family == "vlm":
        per = cfg.cross_attn_every
        body = ["attn"] * per
        body[per - 2] = "cross"                          # 4th of each 5
        return [], body, L // per, []
    if cfg.layer_pattern == "local_global":
        return [], ["attn_local", "attn_global"], L // 2, []
    if cfg.is_moe:
        nd = cfg.moe.first_dense_layers
        return ["moe_dense"] * nd, ["moe"], L - nd, []
    return [], ["attn"], L, []


# ----------------------------------------------------------------------
# Per-block init
# ----------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, kind: str, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {}
    if kind in ("attn", "attn_local", "attn_global", "cross",
                "moe", "moe_dense", "shared_attn"):
        p["norm1"] = init_norm(d, cfg.norm)
        p["norm2"] = init_norm(d, cfg.norm)
        if cfg.post_block_norm:
            p["post_norm1"] = init_norm(d, cfg.norm)
            p["post_norm2"] = init_norm(d, cfg.norm)
        if cfg.mla is not None and kind in ("moe", "moe_dense"):
            p["attn"] = init_mla(ks[0], d, cfg.n_heads, cfg.mla, dtype)
        else:
            p["attn"] = init_attn(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim, cfg.qkv_bias, dtype)
        if kind == "moe":
            p["moe"] = init_moe_params(ks[1], cfg.moe, d, dtype)
        elif kind == "moe_dense":
            f = cfg.moe.d_ff_dense or 4 * d
            p["ffn"] = init_ffn(ks[1], d, f, cfg.act, cfg.mlp_bias, dtype)
        else:
            p["ffn"] = init_ffn(ks[1], d, cfg.d_ff, cfg.act, cfg.mlp_bias,
                                dtype)
    elif kind == "rwkv":
        p["norm1"] = init_norm(d, cfg.norm)
        p["norm2"] = init_norm(d, cfg.norm)
        p["tm"] = rwkv_mod.init_time_mix(ks[0], d, cfg.rwkv, dtype)
        p["cm"] = rwkv_mod.init_channel_mix(ks[1], d, cfg.d_ff, dtype)
    elif kind == "mamba":
        p["norm1"] = init_norm(d, cfg.norm)
        p["ssm"] = ssm_mod.init_ssm(ks[0], d, cfg.ssm, dtype)
    else:
        raise ValueError(kind)
    return p


def init_group(key, cfg: ModelConfig, kinds, dtype):
    ks = jax.random.split(key, len(kinds))
    return {f"b{i}": init_block(ks[i], cfg, kind, dtype)
            for i, kind in enumerate(kinds)}


def init_params(cfg: ModelConfig, key, param_dtype=jnp.float32):
    prefix, body, n_groups, suffix = group_structure(cfg)
    ks = jax.random.split(key, 8)
    p: dict = {}
    d = cfg.d_model
    if cfg.encoder_only:
        p["mask_emb"] = (jax.random.normal(ks[0], (d,)) * 0.02
                         ).astype(param_dtype)
    else:
        p["embed"] = (jax.random.normal(ks[0], (cfg.vocab_size, d)) * 0.02
                      ).astype(param_dtype)
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (d, cfg.vocab_size), dtype=param_dtype)
    p["final_norm"] = init_norm(d, cfg.norm)
    if cfg.family == "ssm":
        p["ln0"] = init_norm(d, cfg.norm)
    if prefix:
        kp = jax.random.split(ks[2], len(prefix))
        p["prefix"] = [init_block(kp[i], cfg, prefix[i], param_dtype)
                       for i in range(len(prefix))]
    kg = jax.random.split(ks[3], n_groups)
    p["body"] = jax.vmap(
        lambda k: init_group(k, cfg, tuple(body), param_dtype))(kg)
    if suffix:
        kS = jax.random.split(ks[4], len(suffix))
        p["suffix"] = [init_block(kS[i], cfg, suffix[i], param_dtype)
                       for i in range(len(suffix))]
    if cfg.attn_every:  # zamba2 shared blocks (2 unique, round-robin)
        ksh = jax.random.split(ks[5], cfg.n_shared_attn_blocks)
        p["shared"] = jax.vmap(
            lambda k: init_block(k, cfg, "shared_attn", param_dtype))(ksh)
    return p


# ----------------------------------------------------------------------
# Per-block apply
# ----------------------------------------------------------------------
def _attn_kw(cfg: ModelConfig, kind: str, rc: RunConfig):
    window = cfg.local_window if kind == "attn_local" else None
    return dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, causal=cfg.causal,
                use_rope=cfg.use_rope, rope_theta=cfg.rope_theta,
                window=window, logit_softcap=cfg.attn_logit_softcap,
                q_chunk=rc.q_chunk or 10 ** 9, kv_chunk=rc.kv_chunk or 10 ** 9)


def _moe_stats_active(rc: RunConfig) -> bool:
    """Plan telemetry flows only where a schedule exists: a
    schedule-consuming executor (the dense oracle has none).  EP paths now
    emit the same ``sched/*`` keys (psum-replicated global totals) as
    single-device dispatch."""
    from repro.execution import get_executor
    return rc.moe_stats and get_executor(rc.executor).needs_schedule


def _apply_moe_ffn(bp, x, cfg: ModelConfig, rc: RunConfig, mode: str):
    dcfg = dispatch_config(cfg.moe, executor=rc.executor,
                           fuse_gate_up=rc.fuse_gate_up,
                           fold_combine=rc.fold_combine,
                           schedule_policy=rc.schedule_policy,
                           capacity_factor=rc.capacity_factor,
                           block_m_min=rc.block_m_min,
                           emit_stats=_moe_stats_active(rc),
                           autotune=rc.autotune)
    if rc.ep:
        from repro.core.distributed import apply_moe_ep
        layout = rc.ep_decode_layout if mode == "decode" else "sharded"
        return apply_moe_ep(bp["moe"], x, dcfg, axis=rc.ep_axis,
                            capacity_factor=rc.capacity_factor,
                            token_layout=layout,
                            overlap=rc.ep_microbatches if rc.ep_overlap
                            else 0)
    return apply_moe(bp["moe"], x, dcfg)


def apply_block(bp, x, kind: str, cfg: ModelConfig, rc: RunConfig, *,
                positions, mode: str, cache=None, cache_pos=None,
                block_tables=None, image_embeds=None):
    """Returns (x, new_cache, aux).  ``block_tables`` (B, nb) switches the
    decode cache access to the paged block pool (serve/kv_cache.py): KV
    writes scatter block-granular and reads gather per-row logical views —
    only positional-KV kinds support it (kv_cache.PAGED_KINDS)."""
    aux = {}
    new_cache = None
    dt = x.dtype
    if block_tables is not None and kind in ("rwkv", "mamba", "cross"):
        raise ValueError(f"block kind {kind!r} has no positional KV cache "
                         "to page (see serve/kv_cache.py PAGED_KINDS)")
    if rc.paged_attn not in ("auto", "fused", "gather"):
        raise ValueError(f"RunConfig.paged_attn={rc.paged_attn!r}; "
                         "expected auto | fused | gather")
    # fused Pallas paged-attention read path (kernels/paged_attention.py):
    # on by default whenever the serving config already runs Pallas
    # kernels; "gather" keeps gather_block_kv + flash as the oracle
    paged_fused = (block_tables is not None and mode == "decode"
                   and (rc.paged_attn == "fused"
                        or (rc.paged_attn == "auto"
                            and rc.executor == "pallas")))

    if kind == "rwkv":
        h = apply_norm(bp["norm1"], x, cfg.norm)
        c_tm = cache["tm"] if cache is not None else None
        o, nc_tm = rwkv_mod.time_mix(bp["tm"], h, cfg.rwkv, cache=c_tm)
        x = x + o.astype(dt)
        h = apply_norm(bp["norm2"], x, cfg.norm)
        c_cm = cache["cm"] if cache is not None else None
        o, nc_cm = rwkv_mod.channel_mix(bp["cm"], h, cache=c_cm)
        x = x + o.astype(dt)
        if cache is not None:
            new_cache = {"tm": nc_tm, "cm": nc_cm}
        return x, new_cache, aux

    if kind == "mamba":
        h = apply_norm(bp["norm1"], x, cfg.norm)
        o, new_cache = ssm_mod.ssm_block(bp["ssm"], h, cfg.ssm, cache=cache)
        return x + o.astype(dt), new_cache, aux

    # --- attention-style blocks ---
    h = apply_norm(bp["norm1"], x, cfg.norm)
    if cfg.mla is not None and kind in ("moe", "moe_dense"):
        o, kv_cache = mla_block(
            bp["attn"], h, n_heads=cfg.n_heads, mla=cfg.mla,
            positions=positions,
            cache=cache.get("kv") if (cache is not None
                                      and mode == "decode") else None,
            cache_pos=cache_pos, block_tables=block_tables,
            paged_fused=paged_fused,
            q_chunk=(10 ** 9 if mode == "decode" else rc.q_chunk or 10 ** 9),
            kv_chunk=(10 ** 9 if mode == "decode"
                      else rc.kv_chunk or 10 ** 9))
        if mode == "prefill":                 # write full-seq latent cache
            kv_cache = _prefill_mla_cache(bp["attn"], h, cfg, cache["kv"],
                                          positions)
    elif kind == "cross":
        if mode == "decode":
            # reuse image K/V built at prefill; no causal structure
            kv_cache = cache["kv"]
            o = _cross_decode(bp["attn"], h, cache["kv"], cfg, rc)
        else:
            img = image_embeds.astype(dt)
            o, _ = attention_block(
                bp["attn"], h, **{**_attn_kw(cfg, kind, rc),
                                  "causal": False, "use_rope": False},
                positions=positions, xkv=img)
            kv_cache = None
            if mode == "prefill":
                from repro.models.attention import project_qkv
                _, kc, vc = project_qkv(bp["attn"], h, img, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim)
                kv_cache = {"k": kc, "v": vc}
    else:
        kw = _attn_kw(cfg, kind, rc)
        if mode == "decode":
            # full-KV attention (no chunk scan): scores stay sharded on the
            # cache's sequence axis and GSPMD emits the flash-decode-style
            # psum combine; a chunked scan would all-gather the cache.
            kw = dict(kw, q_chunk=10 ** 9, kv_chunk=10 ** 9)
            o, kv_cache = attention_block(
                bp["attn"], h, **kw, positions=positions,
                cache=cache["kv"], cache_pos=cache_pos,
                block_tables=block_tables, paged_fused=paged_fused)
        elif mode == "prefill":
            o, _ = attention_block(bp["attn"], h, **kw, positions=positions)
            kv_cache = _prefill_kv_cache(bp["attn"], h, cfg, cache["kv"],
                                         positions)
        else:
            o, kv_cache = attention_block(bp["attn"], h, **kw,
                                          positions=positions)
    if cfg.post_block_norm:
        o = apply_norm(bp["post_norm1"], o, cfg.norm)
    x = x + o.astype(dt)

    h = apply_norm(bp["norm2"], x, cfg.norm)
    if kind == "moe":
        o, moe_aux = _apply_moe_ffn(bp, h, cfg, rc, mode)
        aux.update(moe_aux)
    else:
        o = apply_ffn(bp["ffn"], h, cfg.act)
    if cfg.post_block_norm:
        o = apply_norm(bp["post_norm2"], o, cfg.norm)
    x = x + o.astype(dt)

    if cache is not None:
        new_cache = {"kv": kv_cache}
    return x, new_cache, aux


def _cross_decode(p, h, kv_cache, cfg: ModelConfig, rc: RunConfig):
    """Decode-time cross attention against cached image K/V."""
    from repro.models.attention import flash_attention
    B, S, _ = h.shape
    q = jnp.dot(h, p["wq"].astype(h.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(h.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    out = flash_attention(q, kv_cache["k"].astype(h.dtype),
                          kv_cache["v"].astype(h.dtype), causal=False,
                          q_chunk=0 or 10 ** 9, kv_chunk=10 ** 9)
    return jnp.dot(out.reshape(B, S, -1), p["wo"].astype(h.dtype))


def _prefill_kv_cache(p, h, cfg: ModelConfig, cache_kv, positions):
    """Project K/V for the whole prompt and write into the cache at 0."""
    from repro.models.attention import project_qkv
    from repro.models.blocks import rope
    _, k, v = project_qkv(p, h, h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    if cfg.use_rope:
        k = rope(k, positions, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice(
        cache_kv["k"], k.astype(cache_kv["k"].dtype), (0, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache_kv["v"], v.astype(cache_kv["v"].dtype), (0, 0, 0, 0))
    return {"k": k, "v": v}


def _prefill_mla_cache(p, h, cfg: ModelConfig, cache_kv, positions):
    from repro.models.mla import _latent
    c_kv, k_rope = _latent(p, h, cfg.mla, positions)
    ckv = jax.lax.dynamic_update_slice(
        cache_kv["ckv"], c_kv.astype(cache_kv["ckv"].dtype), (0, 0, 0))
    kr = jax.lax.dynamic_update_slice(
        cache_kv["kr"], k_rope.astype(cache_kv["kr"].dtype), (0, 0, 0))
    return {"ckv": ckv, "kr": kr}


# ----------------------------------------------------------------------
# Cache init
# ----------------------------------------------------------------------
def _block_cache(cfg: ModelConfig, kind: str, batch: int, capacity: int,
                 dtype):
    if kind in ("attn", "attn_local", "attn_global", "shared_attn"):
        vd = cfg.head_dim
        return {"kv": {
            "k": jnp.zeros((batch, capacity, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((batch, capacity, cfg.n_kv_heads, vd), dtype)}}
    if kind in ("moe", "moe_dense"):
        if cfg.mla is not None:
            return {"kv": {
                "ckv": jnp.zeros((batch, capacity, cfg.mla.kv_lora_rank),
                                 dtype),
                "kr": jnp.zeros((batch, capacity, cfg.mla.qk_rope_head_dim),
                                dtype)}}
        return _block_cache(cfg, "attn", batch, capacity, dtype)
    if kind == "cross":
        return {"kv": {
            "k": jnp.zeros((batch, cfg.n_image_tokens, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((batch, cfg.n_image_tokens, cfg.n_kv_heads,
                            cfg.head_dim), dtype)}}
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_cache(batch, cfg.d_model, cfg.rwkv, dtype)
    if kind == "mamba":
        return ssm_mod.init_ssm_cache(batch, cfg.d_model, cfg.ssm, dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=jnp.float32):
    prefix, body, n_groups, suffix = group_structure(cfg)
    mk = lambda kind: _block_cache(cfg, kind, batch, capacity, dtype)
    cache = {}
    if prefix:
        cache["prefix"] = [mk(k) for k in prefix]
    one = {f"b{i}": mk(k) for i, k in enumerate(body)}
    cache["body"] = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (n_groups,) + l.shape).copy(), one)
    if suffix:
        cache["suffix"] = [mk(k) for k in suffix]
    return cache


# ----------------------------------------------------------------------
# Slot views over a batched serving cache
#
# The serve engine holds ONE (slots, capacity) cache for all requests and
# decodes every active slot in a single forward.  These helpers give the
# engine row-level access without knowing the cache pytree: the batch axis
# is 0 for prefix/suffix block caches and 1 for the stacked body (whose
# leading axis is the layer-group dim).
# ----------------------------------------------------------------------
def _map_cache(fn, cache, *rest):
    """fn(batch_axis, leaf, *other_leaves) over every part of a cache."""
    out = {}
    for part in cache:
        axis = 1 if part == "body" else 0
        out[part] = jax.tree.map(functools.partial(fn, axis), cache[part],
                                 *(r[part] for r in rest))
    return out


def slice_cache_slots(cache, start, n: int):
    """Static-size view of ``n`` consecutive slot rows (``start`` may be a
    traced scalar)."""
    return _map_cache(
        lambda ax, l: jax.lax.dynamic_slice_in_dim(l, start, n, axis=ax),
        cache)


def update_cache_slots(cache, sub, start):
    """Write an n-slot sub-cache back into the full cache at ``start``."""
    return _map_cache(
        lambda ax, l, s: jax.lax.dynamic_update_slice_in_dim(
            l, s.astype(l.dtype), start, axis=ax),
        cache, sub)


def swap_cache_slots(cache, i, j):
    """Exchange two slot rows (serve-engine compaction keeps active slots a
    contiguous prefix; ``i``/``j`` may be traced scalars)."""
    def sw(ax, l):
        ri = jax.lax.dynamic_slice_in_dim(l, i, 1, axis=ax)
        rj = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=ax)
        l = jax.lax.dynamic_update_slice_in_dim(l, ri, j, axis=ax)
        return jax.lax.dynamic_update_slice_in_dim(l, rj, i, axis=ax)
    return _map_cache(sw, cache)


# ----------------------------------------------------------------------
# Full forward
# ----------------------------------------------------------------------
def _embed(params, cfg: ModelConfig, batch, dt):
    if cfg.encoder_only:
        x = batch["features"].astype(dt)
        if "mask" in batch:
            x = jnp.where(batch["mask"][..., None],
                          params["mask_emb"].astype(dt), x)
        return x
    x = params["embed"][batch["tokens"]].astype(dt)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    return x


def _head_matrix(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["head"]


def forward(params, cfg: ModelConfig, rc: RunConfig, batch: dict,
            mode: str = "train", cache=None, pos=None, block_tables=None):
    """Returns (out, new_cache, aux):
    train  -> out = final hidden states (B, S, d)
    prefill-> out = last-position logits (B, V)
    decode -> out = logits (B, V); ``pos`` is a scalar (all rows at the
              same position) or a (B,) vector (per-row positions — the
              batched serving path)

    ``block_tables`` (B, nb) — paged decode over a block-pool cache
    (serve/kv_cache.py): row b is one TOKEN of the serving step (a decode
    token or a prefill-chunk token), writing/reading its slot's KV through
    its block table at its own position.  S must be 1 and ``pos`` a (B,)
    vector; the cache pytree holds (n_blocks, block_size) pools in place
    of (slots, capacity) rows.
    """
    from repro.distributed.ctx import constrain
    prefix, body, n_groups, suffix = group_structure(cfg)
    if block_tables is not None and mode != "decode":
        raise ValueError("block_tables is decode-only (chunked prefill "
                         "feeds prompt tokens through decode rows)")
    dt = rc.compute_dtype
    x = constrain("residual", _embed(params, cfg, batch, dt))
    B, S = x.shape[:2]
    if cfg.family == "ssm":
        x = apply_norm(params["ln0"], x, cfg.norm)

    if mode == "decode":
        # pos: scalar (shared position, classic single-sequence decode) or
        # (B,) vector (batched serving: each cache row decodes at its own
        # position — the attention/MLA cache writes scatter per row and the
        # kv_limit mask is per row).
        pos = jnp.asarray(pos, jnp.int32)
        positions = pos[None] if pos.ndim == 0 else pos[:, None]
        cache_pos = pos
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
        cache_pos = None

    image_embeds = batch.get("image_embeds")
    aux_acc = {}

    def merge_aux(a, b):
        return {k: a.get(k, 0.0) + v for k, v in b.items()} if b else a

    def run_unstacked(x, blocks, kinds, caches):
        new_caches = []
        nonlocal aux_acc
        for i, kind in enumerate(kinds):
            c = caches[i] if caches is not None else None
            x, nc, aux = apply_block(
                blocks[i], x, kind, cfg, rc, positions=positions, mode=mode,
                cache=c, cache_pos=cache_pos, block_tables=block_tables,
                image_embeds=image_embeds)
            aux_acc = merge_aux(aux_acc, aux)
            new_caches.append(nc)
        return x, new_caches

    new_cache: dict = {}
    if prefix:
        x, ncs = run_unstacked(x, params["prefix"], prefix,
                               cache.get("prefix") if cache else None)
        if cache is not None:
            new_cache["prefix"] = ncs

    shared = params.get("shared")

    def group_body(x, gp, gi, gcache):
        gaux = {}
        ncache = {}
        for i, kind in enumerate(body):
            bp = gp[f"b{i}"]
            if kind == "shared_attn":
                bp = jax.tree.map(
                    lambda p: p[gi % cfg.n_shared_attn_blocks], shared)
            c = gcache[f"b{i}"] if gcache is not None else None
            x, nc, aux = apply_block(
                bp, x, kind, cfg, rc, positions=positions, mode=mode,
                cache=c, cache_pos=cache_pos, block_tables=block_tables,
                image_embeds=image_embeds)
            gaux = {k: gaux.get(k, 0.0) + v for k, v in aux.items()}
            ncache[f"b{i}"] = nc
        from repro.distributed.ctx import constrain as _c
        return _c("residual", x), ncache, gaux

    def scan_fn(carry, xs):
        x, aux_c = carry
        if cache is not None:
            gp, gi, gcache = xs
        else:
            gp, gi = xs
            gcache = None
        if rc.remat:
            x, ncache, gaux = jax.checkpoint(
                functools.partial(group_body),
                policy=jax.checkpoint_policies.nothing_saveable,
            )(x, gp, gi, gcache)
        else:
            x, ncache, gaux = group_body(x, gp, gi, gcache)
        aux_c = {k: aux_c.get(k, 0.0) + v for k, v in gaux.items()} \
            if gaux else aux_c
        return (x, aux_c), ncache

    aux0 = {"lb_loss": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32)} \
        if (cfg.is_moe and "moe" in body) else {}
    if aux0 and _moe_stats_active(rc):
        # plan telemetry keys must pre-exist: aux is a fixed scan carry
        from repro.scheduling import ScheduleStats
        aux0.update({f"sched/{k}": jnp.zeros((), jnp.float32)
                     for k in ScheduleStats._fields})
    gi_arr = jnp.arange(n_groups, dtype=jnp.int32)
    if rc.unroll:
        aux_acc2 = aux0
        ncaches = []
        for gi in range(n_groups):
            gp = jax.tree.map(lambda p: p[gi], params["body"])
            gcache = jax.tree.map(lambda c: c[gi], cache["body"]) \
                if cache is not None else None
            x, nc, gaux = group_body(x, gp, jnp.int32(gi), gcache)
            aux_acc2 = {k: aux_acc2.get(k, 0.0) + v for k, v in gaux.items()} \
                if gaux else aux_acc2
            ncaches.append(nc)
        body_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *ncaches) \
            if cache is not None else None
    else:
        xs = (params["body"], gi_arr) if cache is None \
            else (params["body"], gi_arr, cache["body"])
        with jax.named_scope("layer_stack"):
            (x, aux_acc2), body_caches = jax.lax.scan(scan_fn, (x, aux0), xs)
    aux_acc = merge_aux(aux_acc, aux_acc2)
    if cache is not None:
        new_cache["body"] = body_caches

    if suffix:
        x, ncs = run_unstacked(x, params["suffix"], suffix,
                               cache.get("suffix") if cache else None)
        if cache is not None:
            new_cache["suffix"] = ncs

    x = apply_norm(params["final_norm"], x, cfg.norm)

    if mode == "train":
        return x, None, aux_acc

    w_head = _head_matrix(params, cfg).astype(dt)
    if mode == "prefill":
        x_last = x[:, -1]
    else:
        x_last = x[:, 0]
    logits = jnp.dot(x_last, w_head).astype(jnp.float32)
    logits = softcap(logits, cfg.final_logit_softcap)
    return logits, (new_cache if cache is not None else None), aux_acc


# ----------------------------------------------------------------------
# Loss (chunked over sequence; logits never fully materialized)
# ----------------------------------------------------------------------
def chunked_ce(x, w_head, labels, valid, *, chunk: int,
               final_cap: Optional[float]):
    """x: (B, S, d); labels/valid: (B, S). Returns (sum_ce, n_valid)."""
    B, S, d = x.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    # STRIDED chunks (token s -> chunk s % nc): under CP the sequence dim is
    # sharded over 'model'; strided chunking keeps every rank active in every
    # scan step (contiguous chunks would serialize rank-by-rank).
    xs = (jnp.moveaxis(x.reshape(B, c, nc, d), 2, 0),
          jnp.moveaxis(labels.reshape(B, c, nc), 2, 0),
          jnp.moveaxis(valid.reshape(B, c, nc), 2, 0))

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, inp):
        xc, yc, vc = inp
        logits = jnp.dot(xc, w_head).astype(jnp.float32)
        logits = softcap(logits, final_cap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        ce = jnp.where(vc, lse - gold, 0.0)
        return (carry[0] + jnp.sum(ce), carry[1] + jnp.sum(vc)), None

    (tot, n), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), xs)
    return tot, n


def loss_fn(params, cfg: ModelConfig, rc: RunConfig, batch: dict):
    """Next-token CE (LMs) or masked-prediction CE (encoder). Returns
    (loss, metrics)."""
    h, _, aux = forward(params, cfg, rc, batch, mode="train")
    w_head = _head_matrix(params, cfg).astype(h.dtype)
    if cfg.encoder_only:
        labels = batch["labels"]
        valid = batch["mask"]
        tot, n = chunked_ce(h, w_head, labels, valid, chunk=rc.loss_chunk,
                            final_cap=cfg.final_logit_softcap)
    else:
        tokens = batch["tokens"]
        labels = tokens[:, 1:]
        valid = jnp.ones_like(labels, bool)
        tot, n = chunked_ce(h[:, :-1], w_head, labels, valid,
                            chunk=rc.loss_chunk,
                            final_cap=cfg.final_logit_softcap)
    loss = tot / jnp.maximum(n, 1)
    metrics = {"ce": loss, "tokens": n.astype(jnp.float32)}
    if aux:
        metrics.update(aux)
        loss = loss + 0.01 * aux.get("lb_loss", 0.0) \
            + 1e-4 * aux.get("router_z", 0.0)
    return loss, metrics
