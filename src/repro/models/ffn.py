"""Dense feed-forward variants.

The dense gated FFNs reuse the paper's key fusion idea at the XLA level: gate
and up projections consume the same activations and XLA fuses the SiLU/GELU
epilogue; on TPU the Pallas fused kernel handles the grouped (MoE) case while
the dense case is a single wide GEMM pair that the MXU already saturates."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.blocks import dense_init


def init_ffn(key, d: int, f: int, act: str, bias: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[0], (d, f), dtype=dtype)
        p["w_up"] = dense_init(ks[1], (d, f), dtype=dtype)
    else:  # gelu_mlp
        p["w_up"] = dense_init(ks[1], (d, f), dtype=dtype)
        if bias:
            p["b_up"] = jnp.zeros((f,), dtype)
    p["w_down"] = dense_init(ks[2], (f, d), dtype=dtype)
    if bias:
        p["b_down"] = jnp.zeros((d,), dtype)
    return p


def apply_ffn(p, x: jnp.ndarray, act: str) -> jnp.ndarray:
    dt = x.dtype
    if act in ("swiglu", "geglu"):
        g = jnp.dot(x, p["w_gate"].astype(dt))
        u = jnp.dot(x, p["w_up"].astype(dt))
        gf = g.astype(jnp.float32)
        nl = gf * jax.nn.sigmoid(gf) if act == "swiglu" \
            else jax.nn.gelu(gf, approximate=True)
        h = (nl * u.astype(jnp.float32)).astype(dt)
    else:
        h = jnp.dot(x, p["w_up"].astype(dt))
        if "b_up" in p:
            h = h + p["b_up"].astype(dt)
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(dt)
    out = jnp.dot(h, p["w_down"].astype(dt))
    if "b_down" in p:
        out = out + p["b_down"].astype(dt)
    return out
