"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Train/prefill: decompress the latent to per-head K/V and run chunked flash
attention (standard).  Decode: the cache stores only the compressed latent
``c_kv`` (kv_lora dims) plus the shared rope key — the whole point of MLA —
and attention runs in the *absorbed* form (q projected into latent space;
per-head K/V never materialized), chunked over the cached sequence.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.attention import (flash_attention, gather_block_kv,
                                    scatter_block_rows, scatter_decode_row)
from repro.models.blocks import apply_norm, dense_init, init_norm, rope


def init_mla(key, d_model: int, n_heads: int, mla: MLAConfig,
             dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    return {
        "wq_a": dense_init(ks[0], (d_model, mla.q_lora_rank), dtype=dtype),
        "q_norm": init_norm(mla.q_lora_rank, "rmsnorm"),
        "wq_b": dense_init(ks[1], (mla.q_lora_rank, n_heads * (dn + dr)),
                           dtype=dtype),
        "wkv_a": dense_init(ks[2], (d_model, mla.kv_lora_rank + dr),
                            dtype=dtype),
        "kv_norm": init_norm(mla.kv_lora_rank, "rmsnorm"),
        "wkv_b": dense_init(ks[3], (mla.kv_lora_rank, n_heads * (dn + dv)),
                            dtype=dtype),
        "wo": dense_init(ks[4], (n_heads * dv, d_model), dtype=dtype),
    }


def _project_q(p, x, n_heads: int, mla: MLAConfig, positions):
    dn, dr = mla.qk_nope_head_dim, mla.qk_rope_head_dim
    B, S, _ = x.shape
    cq = apply_norm(p["q_norm"], jnp.dot(x, p["wq_a"].astype(x.dtype)),
                    "rmsnorm")
    q = jnp.dot(cq, p["wq_b"].astype(x.dtype)).reshape(B, S, n_heads, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, 10_000.0)
    return q_nope, q_rope


def _latent(p, x, mla: MLAConfig, positions):
    """x -> (c_kv normalized (B,S,r), k_rope (B,S,dr))."""
    dr = mla.qk_rope_head_dim
    ckv_full = jnp.dot(x, p["wkv_a"].astype(x.dtype))
    c_kv = apply_norm(p["kv_norm"], ckv_full[..., :mla.kv_lora_rank],
                      "rmsnorm")
    k_rope = rope(ckv_full[..., mla.kv_lora_rank:][:, :, None, :],
                  positions, 10_000.0)[:, :, 0, :]
    return c_kv, k_rope


def mla_block(p, x: jnp.ndarray, *, n_heads: int, mla: MLAConfig,
              positions: jnp.ndarray, cache: Optional[dict] = None,
              cache_pos=None, block_tables=None, paged_fused: bool = False,
              q_chunk: int = 512, kv_chunk: int = 512):
    """Returns (out, new_cache). Cache: {"ckv": (B,S,r), "kr": (B,S,dr)};
    with ``block_tables`` (B, nb) the cache leaves are paged block pools
    (n_blocks, block_size, ...) written block-granular and read through a
    per-row gather — the latent cache pages exactly like attention K/V.
    ``paged_fused`` runs absorbed decode through the fused Pallas
    paged-attention kernel instead: the latent pools are scored in place
    (q_eff/ckv + q_rope/kr as a two-operand score, ckv doubling as the
    value), so neither the gathered latent view nor the concatenated key
    ever materializes."""
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    B, S, _ = x.shape
    q_nope, q_rope = _project_q(p, x, n_heads, mla, positions)
    c_kv, k_rope = _latent(p, x, mla, positions)

    if cache is None:
        # ---- train/prefill: decompress, chunked flash over full seq ----
        kv = jnp.dot(c_kv, p["wkv_b"].astype(x.dtype)).reshape(
            B, S, n_heads, dn + dv)
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, n_heads, dr))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(q, k, v, causal=True,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)
        out = out.reshape(B, S, n_heads * dv)
        return jnp.dot(out, p["wo"].astype(x.dtype)), None

    # ---- decode: absorbed attention over the compressed cache ----
    # (scatter_decode_row handles scalar and (B,) per-slot positions)
    idx = cache_pos
    if block_tables is not None:
        new_ckv = scatter_block_rows(cache["ckv"], c_kv, block_tables, idx)
        new_kr = scatter_block_rows(cache["kr"], k_rope, block_tables, idx)
        new_cache = {"ckv": new_ckv, "kr": new_kr}
        if paged_fused:
            out = _mla_fused_paged_decode(
                p, q_nope, q_rope, new_ckv, new_kr, block_tables, idx,
                n_heads=n_heads, mla=mla)
            out = out.reshape(B, S, n_heads * dv)
            return jnp.dot(out, p["wo"].astype(x.dtype)), new_cache
        ckv_view = gather_block_kv(new_ckv, block_tables)
        kr_view = gather_block_kv(new_kr, block_tables)
    else:
        new_ckv = scatter_decode_row(cache["ckv"], c_kv, idx)
        new_kr = scatter_decode_row(cache["kr"], k_rope, idx)
        new_cache = {"ckv": new_ckv, "kr": new_kr}
        ckv_view, kr_view = new_ckv, new_kr

    out = mla_absorbed_decode(
        p, q_nope, q_rope, ckv_view.astype(x.dtype), kr_view.astype(x.dtype),
        n_heads=n_heads, mla=mla, kv_limit=idx, kv_chunk=kv_chunk)
    out = out.reshape(B, S, n_heads * dv)
    return jnp.dot(out, p["wo"].astype(x.dtype)), new_cache


def _mla_fused_paged_decode(p, q_nope, q_rope, ckv_pool, kr_pool, tables,
                            kv_limit, *, n_heads: int, mla: MLAConfig):
    """Absorbed MLA decode straight off the latent block pools.

    Mirrors ``mla_absorbed_decode`` over ``gather_block_kv`` views term
    for term — same absorbed q construction, same two-step scale
    compensation (pre-scale by ((r+dr)/(dn+dr))^0.5 then the kernel's
    (r+dr)^-0.5, in the same dtype and order) — but the scores run inside
    the fused Pallas kernel with ckv/kr as two scalar-prefetch-indexed
    score operands and ckv as the value.  Returns (B, 1, H, dv)."""
    from repro.kernels.ops import _interp
    from repro.kernels.paged_attention import paged_decode_attention
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    r = mla.kv_lora_rank
    B = q_nope.shape[0]
    wkv_b = p["wkv_b"].astype(q_nope.dtype).reshape(r, n_heads, dn + dv)
    w_k, w_v = wkv_b[..., :dn], wkv_b[..., dn:]
    q_eff = jnp.einsum("bthd,rhd->bthr", q_nope, w_k)         # (B,1,H,r)
    comp = jnp.asarray(((r + dr) ** 0.5) / ((dn + dr) ** 0.5),
                       q_eff.dtype)
    # (B, 1, H, *) doubles as the kernel's (B, Hkv=1, G=H, *) layout
    q1 = q_eff * comp
    q2 = q_rope * comp
    ckv4 = ckv_pool[:, :, None, :]                            # Hkv=1 axis
    kr4 = kr_pool[:, :, None, :]
    ctx = paged_decode_attention(
        q1, ckv4, ckv4, tables, kv_limit, scale=(r + dr) ** -0.5,
        q2=q2, k2_pool=kr4, interpret=_interp(None))          # (B,1,H,r)
    return jnp.einsum("bthr,rhd->bthd", ctx.astype(q_nope.dtype), w_v)


def mla_absorbed_decode(p, q_nope, q_rope, ckv, kr, *, n_heads: int,
                        mla: MLAConfig, kv_limit, kv_chunk: int = 2048,
                        kv_offset: int = 0, return_stats: bool = False):
    """Absorbed-form attention: score = q_nope W_k^T c + q_rope k_rope;
    context stays in latent space until the final W_v projection.

    q_*: (B, 1, H, dn|dr); ckv: (B, S, r); kr: (B, S, dr).
    """
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    r = mla.kv_lora_rank
    B, S = ckv.shape[:2]
    wkv_b = p["wkv_b"].astype(q_nope.dtype).reshape(r, n_heads, dn + dv)
    w_k, w_v = wkv_b[..., :dn], wkv_b[..., dn:]
    # absorb: q_eff (B,1,H,r) so scores need only the latent cache
    q_eff = jnp.einsum("bthd,rhd->bthr", q_nope, w_k)
    # keys: latent ckv (acts per-head-identically) + shared rope key.
    # Treat (r + dr) as the effective qk head dim, Hkv=1 GQA group.
    q_cat = jnp.concatenate([q_eff, q_rope], axis=-1)         # (B,1,H,r+dr)
    k_cat = jnp.concatenate([ckv, kr], axis=-1)[:, :, None, :]  # (B,S,1,r+dr)
    # flash_attention scales by D^-0.5 of its qk dim; MLA scales by the
    # *decompressed* head dim (dn + dr). Pre-scale to compensate.
    q_cat = q_cat * jnp.asarray(
        ((r + dr) ** 0.5) / ((dn + dr) ** 0.5), q_cat.dtype)
    stats = flash_attention(
        q_cat, k_cat, ckv[:, :, None, :], causal=False, kv_limit=kv_limit,
        kv_offset=kv_offset, q_chunk=1, kv_chunk=kv_chunk,
        return_stats=return_stats)
    if return_stats:
        return stats, w_v
    ctx = stats                                               # (B,1,H,r)
    return jnp.einsum("bthr,rhd->bthd", ctx, w_v)             # (B,1,H,dv)
