"""Shared building blocks: norms, rotary embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) == 2 else shape[-2]
    scale = (fan_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ----------------------------------------------------------------------
# Norms (computed in fp32, cast back)
# ----------------------------------------------------------------------
def init_norm(d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}       # (1 + scale) form
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Rotary position embeddings (llama-style rotate-half)
# ----------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, half)
    if ang.ndim == 2:                                           # (S, half)
        ang = ang[None]                                         # (1, S, half)
    cos = jnp.cos(ang)[:, :, None, :]                           # (B|1, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
