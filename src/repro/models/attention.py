"""Attention substrate: chunked ("flash") attention in pure JAX.

No T^2 tensor is ever materialized: the computation is a scan over query
chunks with an inner scan over KV chunks carrying running (max, denom, acc)
statistics — the standard online-softmax formulation.  This is what the
full-scale dry-run lowers (32k prefill would otherwise need multi-GB score
buffers), and it is exact (tests compare against naive attention).

Features: GQA (grouped KV heads), causal masks, sliding windows (gemma2
local layers — banded so FLOPs stay O(S*W)), attention-logit softcap,
bidirectional (encoder) mode, cross-attention, decode with a KV-position
limit, and ``return_stats`` for the cross-device flash-decode LSE combine
(distributed/decode.py)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.blocks import dense_init, rope

NEG_INF = -1e30  # finite -inf stand-in: keeps exp()/where() NaN-free


def init_attn(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              bias: bool, dtype=jnp.float32, v_head_dim: int = 0):
    vd = v_head_dim or head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim), dtype=dtype),
        "wk": dense_init(ks[1], (d_model, n_kv_heads * head_dim), dtype=dtype),
        "wv": dense_init(ks[2], (d_model, n_kv_heads * vd), dtype=dtype),
        "wo": dense_init(ks[3], (n_heads * vd, d_model), dtype=dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * vd,), dtype)
    return p


def project_qkv(p, x: jnp.ndarray, xkv: jnp.ndarray, n_heads: int,
                n_kv_heads: int, head_dim: int, v_head_dim: int = 0):
    """x: (B, S, d) queries source; xkv: (B, Skv, d) key/value source."""
    vd = v_head_dim or head_dim
    dt = x.dtype
    q = jnp.dot(x, p["wq"].astype(dt))
    k = jnp.dot(xkv, p["wk"].astype(dt))
    v = jnp.dot(xkv, p["wv"].astype(dt))
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    B, S, _ = x.shape
    Skv = xkv.shape[1]
    return (q.reshape(B, S, n_heads, head_dim),
            k.reshape(B, Skv, n_kv_heads, head_dim),
            v.reshape(B, Skv, n_kv_heads, vd))


def _pick_chunk(n: int, target: int) -> int:
    c = min(n, target)
    while n % c:
        c -= 1
    return c


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_softcap", "q_chunk",
                     "kv_chunk", "return_stats"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    logit_softcap: Optional[float] = None,
                    q_offset=0, kv_offset=0,
                    kv_limit: Optional[jnp.ndarray] = None,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    return_stats: bool = False):
    """q: (B, Sq, Hq, D); k: (B, Skv, Hkv, D); v: (B, Skv, Hkv, Dv).

    q_offset/kv_offset: absolute position of the first query/key (CP shards
    pass their global offsets).  kv_limit: inclusive max attended key
    position, scalar or (B,) (decode).  Returns (B, Sq, Hq, Dv); with
    return_stats, returns (unnormalized_acc, sumexp l, rowmax m) for LSE
    combination across KV shards.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, Dv = v.shape[0], k.shape[1], k.shape[2], v.shape[-1]
    G = Hq // Hkv
    qc = _pick_chunk(Sq, q_chunk)
    kc = _pick_chunk(Skv, kv_chunk)
    nq, nk = Sq // qc, Skv // kc

    # keep q/k/v in their storage dtype; per-chunk MXU einsums accumulate in
    # fp32 via preferred_element_type. Materializing fp32 copies up front
    # costs 2x HBM on the (replicated-under-CP) K/V — measured at ~6 GB per
    # device per layer for MLA at 32k (EXPERIMENTS.md §Perf iteration 3).
    scale = jnp.asarray(D ** -0.5, q.dtype)
    qr = q.reshape(B, nq, qc, Hkv, G, D) * scale
    kr = k.reshape(B, nk, kc, Hkv, D)
    vr = v.reshape(B, nk, kc, Hkv, Dv)

    if kv_limit is not None:
        kv_lim = jnp.broadcast_to(jnp.asarray(kv_limit), (B,)).astype(jnp.int32)
    else:
        kv_lim = None

    def one_q_chunk(qi, qb):                     # qb: (B, qc, Hkv, G, D)
        qpos = q_offset + qi * qc + jnp.arange(qc)          # (qc,)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kb, vb = inp                      # kb: (B, kc, Hkv, D)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,     # (B,Hkv,G,qc,kc)
                           preferred_element_type=jnp.float32)
            if logit_softcap is not None:
                s = logit_softcap * jnp.tanh(s / logit_softcap)
            kpos = kv_offset + ki * kc + jnp.arange(kc)     # (kc,)
            ok = jnp.ones((qc, kc), bool)
            if causal:
                ok &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                ok &= kpos[None, :] > qpos[:, None] - window
            mask = ok[None, None, None]
            if kv_lim is not None:
                mask = mask & (kpos[None, None, None, None, :]
                               <= kv_lim[:, None, None, None, None])
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask, p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = corr * l + jnp.sum(p, axis=-1)
            # P is cast to the value dtype for the MXU (standard TPU flash
            # practice); accumulation stays fp32.
            acc_new = (corr[..., None] * acc
                       + jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype),
                                    vb, preferred_element_type=jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qc, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        return m, l, acc

    ms, ls, accs = jax.lax.map(
        lambda t: one_q_chunk(t[0], t[1]),
        (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    # (nq, B, Hkv, G, qc[, Dv]) -> (B, Sq, Hq[, Dv])
    def _restore(x, last=()):
        x = jnp.moveaxis(x, 0, 3)                 # (B,Hkv,G,nq,qc,...)
        return x.reshape((B, Hkv, G, Sq) + last)
    m = _restore(ms)
    l = _restore(ls)
    acc = _restore(accs, (Dv,))
    if return_stats:
        return acc, l, m                          # (B,Hkv,G,Sq,Dv),(B,Hkv,G,Sq)
    out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l[..., None], 1e-30), 0)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, Hq, Dv)
    return out.astype(q.dtype)


def combine_stats(acc, l, m, axis_name: str):
    """LSE-combine partial attention stats across a mesh axis (flash-decode).

    Each rank holds (acc, l, m) for its KV shard; the result equals attention
    over the full KV. Used by distributed/decode.py inside shard_map."""
    m_g = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axis_name)
    acc_g = jax.lax.psum(acc * corr[..., None], axis_name)
    out = jnp.where(l_g[..., None] > 0,
                    acc_g / jnp.maximum(l_g[..., None], 1e-30), 0)
    return out


def naive_attention(q, k, v, *, causal=True, window=None, logit_softcap=None,
                    kv_limit=None):
    """O(S^2)-memory oracle for tests."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf.reshape(B, Sq, Hkv, G, D),
                   k.astype(jnp.float32))
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    qpos, kpos = jnp.arange(Sq), jnp.arange(k.shape[1])
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= kpos[None] <= qpos[:, None]
    if window is not None:
        ok &= kpos[None] > qpos[:, None] - window
    mask = ok[None, None, None]
    if kv_limit is not None:
        lim = jnp.broadcast_to(jnp.asarray(kv_limit), (B,)).astype(jnp.int32)
        mask = mask & (kpos[None, None, None, None, :]
                       <= lim[:, None, None, None, None])
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, -1).astype(q.dtype)


def attention_block(p, x: jnp.ndarray, *, n_heads: int, n_kv_heads: int,
                    head_dim: int, causal: bool, use_rope: bool,
                    rope_theta: float, positions: jnp.ndarray,
                    window: Optional[int] = None,
                    logit_softcap: Optional[float] = None,
                    xkv: Optional[jnp.ndarray] = None,
                    kv_positions: Optional[jnp.ndarray] = None,
                    cache: Optional[dict] = None,
                    cache_pos: Optional[jnp.ndarray] = None,
                    block_tables: Optional[jnp.ndarray] = None,
                    paged_fused: bool = False,
                    q_chunk: int = 512, kv_chunk: int = 512):
    """Full attention sub-block: project -> rope -> (cache update) -> flash
    -> output projection.  Returns (out, new_cache).

    Decode: ``cache_pos`` is a scalar (all rows write/attend at the same
    position) or a (B,) vector — the batched-serving path, where each cache
    row carries its own sequence position (``scatter_decode_row`` + per-row
    ``kv_limit`` mask).  With ``block_tables`` (B, nb) the cache is a paged
    block POOL instead of per-row buffers: writes scatter block-granular
    (``scatter_block_rows``) and reads gather each row's logical view
    through its table (``gather_block_kv``) — same math, paged storage.
    ``paged_fused`` replaces that gather + flash with the fused Pallas
    paged-attention kernel (kernels/paged_attention.py): online softmax
    walks the block-table-indexed pool tiles directly, so the gathered
    view never materializes.  ``gather_block_kv`` remains the
    differential oracle (token-identical greedy decode, asserted in
    tests/test_paged_attention.py)."""
    from repro.distributed.ctx import constrain
    source_kv = x if xkv is None else xkv
    q, k, v = project_qkv(p, x, source_kv, n_heads, n_kv_heads, head_dim)
    if use_rope:
        q = rope(q, positions, rope_theta)
        kp = positions if kv_positions is None else kv_positions
        if xkv is None:                       # self-attn: keys share positions
            k = rope(k, kp, rope_theta)
    q = constrain("q_seq", constrain("qkv", q))
    k = constrain("kv_full", constrain("qkv", k))
    v = constrain("kv_full", constrain("qkv", v))
    new_cache = None
    kv_limit = None
    kv_off = 0
    if cache is not None:
        # decode: write this step's k/v at cache_pos, attend to <= cache_pos
        idx = cache_pos
        if block_tables is not None:
            new_k = scatter_block_rows(cache["k"], k, block_tables, idx)
            new_v = scatter_block_rows(cache["v"], v, block_tables, idx)
            new_cache = {"k": new_k, "v": new_v}
            if paged_fused:
                # fused path: attend straight off the pool.  The decode
                # flash call below runs with qpos=0 (Sq=1), which makes
                # the window term inert — the fused call mirrors that
                # exactly (window omitted) so both paths stay bitwise
                # companions.
                from repro.kernels.ops import _interp
                from repro.kernels.paged_attention import \
                    paged_decode_attention
                B = q.shape[0]
                G = n_heads // n_kv_heads
                qf = q[:, 0].reshape(B, n_kv_heads, G, head_dim)
                out = paged_decode_attention(
                    qf, new_k, new_v, block_tables, idx,
                    scale=head_dim ** -0.5, logit_softcap=logit_softcap,
                    interpret=_interp(None))
                out = out.reshape(B, 1, n_heads, -1).astype(q.dtype)
                out = out.reshape(B, 1, -1)
                return jnp.dot(out, p["wo"].astype(x.dtype)), new_cache
            k = gather_block_kv(new_k, block_tables).astype(q.dtype)
            v = gather_block_kv(new_v, block_tables).astype(q.dtype)
        else:
            new_k = scatter_decode_row(cache["k"], k, idx)
            new_v = scatter_decode_row(cache["v"], v, idx)
            new_cache = {"k": new_k, "v": new_v}
            k, v = new_k.astype(q.dtype), new_v.astype(q.dtype)
        kv_limit = idx
        causal = False
    out = flash_attention(q, k, v, causal=causal, window=window,
                          logit_softcap=logit_softcap,
                          kv_limit=kv_limit, kv_offset=kv_off,
                          q_chunk=q_chunk, kv_chunk=kv_chunk)
    B, S = x.shape[:2]
    out = out.reshape(B, S, -1)
    return jnp.dot(out, p["wo"].astype(x.dtype)), new_cache


def gather_block_kv(pool: jnp.ndarray, tables: jnp.ndarray) -> jnp.ndarray:
    """Paged attention READ: reassemble each row's logically-contiguous
    KV view from the global block pool.

    pool: (n_blocks, block_size, ...); tables: (B, nb) int32 physical block
    ids in logical order.  Returns (B, nb * block_size, ...) — row b's view
    holds its sequence positions in order, so downstream flash attention
    (kv_limit masking, rope'd keys, windows) is unchanged: paging is
    invisible past the gather.  Unallocated table entries may point at
    arbitrary blocks; their logical positions lie beyond the row's
    ``kv_limit`` and are masked."""
    g = pool[tables]                        # (B, nb, bs, ...)
    return g.reshape(g.shape[0], g.shape[1] * g.shape[2], *g.shape[3:])


def scatter_block_rows(pool: jnp.ndarray, val: jnp.ndarray,
                       tables: jnp.ndarray, pos) -> jnp.ndarray:
    """Paged attention WRITE: the block-granular sibling of
    ``scatter_decode_row``.

    pool: (n_blocks, block_size, ...); val: (B, 1, ...); tables: (B, nb);
    pos: (B,) logical positions.  Token b lands at physical
    ``(tables[b, pos[b] // block_size], pos[b] % block_size)``.  Rank-
    agnostic (attention K/V and the MLA latent cache share it).  The
    engine guarantees the (block, offset) pairs of one step are pairwise
    distinct: decode tokens occupy different slots and a prefill chunk's
    tokens occupy consecutive positions of one slot — so the point
    scatter's unordered updates never collide.  A position past the
    row's table (nb * block_size) is DROPPED, matching the contiguous
    cache's out-of-bounds scatter at the capacity edge (the engine
    retires such rows on the same step)."""
    bs = pool.shape[1]
    nb = tables.shape[1]
    pos = jnp.asarray(pos)
    logical = pos // bs
    blk = jnp.take_along_axis(tables, jnp.clip(logical, 0, nb - 1)[:, None],
                              axis=1)[:, 0]
    # rows past the table get an out-of-range physical id; mode="drop"
    # discards them (they hold no block to write)
    blk = jnp.where(logical < nb, blk, pool.shape[0])
    return pool.at[blk, pos % bs].set(val[:, 0].astype(pool.dtype),
                                      mode="drop")


def scatter_decode_row(cache: jnp.ndarray, val: jnp.ndarray, pos):
    """Write one decode step's row into a cache along the sequence axis.

    cache: (B, S, ...); val: (B, 1, ...); pos: scalar (shared position) or
    (B,) per-row positions (batched serving).  Rank-agnostic — the same
    primitive serves attention K/V (B, S, H, D) and the MLA latent cache
    (B, S, r).  The vector case is a point scatter, not a dense one-hot
    blend: per step it writes O(B * row) instead of reading and blending
    the whole O(B * S * row) cache."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, val.astype(cache.dtype), pos, axis=1)
    B = cache.shape[0]
    return cache.at[jnp.arange(B), pos].set(val[:, 0].astype(cache.dtype))
