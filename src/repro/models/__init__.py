"""Model substrate: the 10 assigned architectures on a shared block library."""
from repro.models.lm import (RunConfig, forward, group_structure, init_cache,  # noqa: F401
                             init_params, loss_fn, slice_cache_slots,
                             swap_cache_slots, update_cache_slots)
