"""RWKV6 "Finch": data-dependent-decay linear attention + channel mix.

Time-mix recurrence (per head, k/v head size n):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
with the Finch signature feature: w_t = exp(-exp(w0 + lora(x~_t))) is
DATA-DEPENDENT per channel.  Token-shift mixing coefficients are kept static
per projection (the full ddlerp stack is simplified; noted in DESIGN.md).

Implementation: exact ``lax.scan`` over time with fp32 state (the recurrence
is a rank-1 update — memory-bound VPU work on TPU; the chunked-GLA
reformulation is the documented optimization path in EXPERIMENTS.md §Perf).
Decode is the same recurrence applied to a single step with O(1) state.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RWKVConfig
from repro.models.blocks import apply_norm, dense_init, init_norm


def init_time_mix(key, d_model: int, rwkv: RWKVConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 9)
    n = rwkv.head_size
    H = d_model // n
    return {
        "mu": 0.5 * jnp.ones((5, d_model), jnp.float32),   # r,k,v,w,g shifts
        "w0": jnp.full((d_model,), -1.0, jnp.float32),
        "w_lora_a": dense_init(ks[0], (d_model, rwkv.decay_lora), dtype=dtype),
        "w_lora_b": dense_init(ks[1], (rwkv.decay_lora, d_model),
                               scale=0.01, dtype=dtype),
        "u": jnp.zeros((H, n), jnp.float32),               # per-channel bonus
        "wr": dense_init(ks[2], (d_model, d_model), dtype=dtype),
        "wk": dense_init(ks[3], (d_model, d_model), dtype=dtype),
        "wv": dense_init(ks[4], (d_model, d_model), dtype=dtype),
        "wg": dense_init(ks[5], (d_model, d_model), dtype=dtype),
        "ln_x": init_norm(d_model, "layernorm"),           # per-head group norm
        "wo": dense_init(ks[6], (d_model, d_model), dtype=dtype),
    }


def init_channel_mix(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d_model), jnp.float32),   # k, r shifts
        "wk": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "wv": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
        "wr": dense_init(ks[2], (d_model, d_model), dtype=dtype),
    }


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray]):
    """x_{t-1} (zero / cached at t=0). x: (B,S,d); last: (B,1,d) or None."""
    prev = jnp.zeros_like(x[:, :1]) if last is None else last.astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def time_mix(p, x: jnp.ndarray, rwkv: RWKVConfig, *,
             cache: Optional[dict] = None):
    """Returns (out, new_cache). cache: {"shift": (B,1,d),
    "state": (B,H,n,n) fp32}."""
    B, S, d = x.shape
    n = rwkv.head_size
    H = d // n
    from repro.distributed.ctx import constrain
    xx = _token_shift(x, cache["shift"] if cache else None)
    mix = lambda i: x + (xx - x) * p["mu"][i].astype(x.dtype)
    r = constrain("heads4",
                  jnp.dot(mix(0), p["wr"].astype(x.dtype)).reshape(B, S, H, n))
    k = constrain("heads4",
                  jnp.dot(mix(1), p["wk"].astype(x.dtype)).reshape(B, S, H, n))
    v = constrain("heads4",
                  jnp.dot(mix(2), p["wv"].astype(x.dtype)).reshape(B, S, H, n))
    # Finch: data-dependent decay
    xw = mix(3)
    dd = p["w0"] + jnp.dot(jnp.tanh(jnp.dot(xw, p["w_lora_a"].astype(x.dtype))
                                    ), p["w_lora_b"].astype(x.dtype)
                           ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dd)).reshape(B, S, H, n)            # decay in (0,1)
    g = jax.nn.silu(jnp.dot(mix(4), p["wg"].astype(x.dtype)
                            ).astype(jnp.float32))

    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    u = p["u"][None]                                          # (1,H,n)

    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp                              # (B,H,n)
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,H,n,n)
        o = jnp.einsum("bhn,bhnm->bhm", r_t, S_ + u[..., None] * kv)
        S_new = w_t[..., None] * S_ + kv
        return S_new, o

    S0 = (jnp.zeros((B, H, n, n), jnp.float32) if cache is None
          else cache["state"].astype(jnp.float32))
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, w))
    S_fin, o = jax.lax.scan(step, S0, xs)
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, d)                # (B,S,d)
    o = apply_norm(p["ln_x"], o.astype(x.dtype), "layernorm") \
        .astype(jnp.float32) * g
    out = jnp.dot(o.astype(x.dtype), p["wo"].astype(x.dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"shift": x[:, -1:].astype(cache["shift"].dtype),
                     "state": S_fin}
    return out, new_cache


def channel_mix(p, x: jnp.ndarray, *, cache: Optional[dict] = None):
    """Returns (out, new_cache). cache: {"shift": (B,1,d)}."""
    xx = _token_shift(x, cache["shift"] if cache else None)
    mix = lambda i: x + (xx - x) * p["mu"][i].astype(x.dtype)
    k = jnp.dot(mix(0), p["wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = jnp.dot(k, p["wv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.dot(mix(1), p["wr"].astype(x.dtype)
                               ).astype(jnp.float32))
    out = (r * kv.astype(jnp.float32)).astype(x.dtype)
    new_cache = ({"shift": x[:, -1:].astype(cache["shift"].dtype)}
                 if cache is not None else None)
    return out, new_cache


def init_rwkv_cache(batch: int, d_model: int, rwkv: RWKVConfig,
                    dtype=jnp.float32):
    n = rwkv.head_size
    H = d_model // n
    return {
        "tm": {"shift": jnp.zeros((batch, 1, d_model), dtype),
               "state": jnp.zeros((batch, H, n, n), jnp.float32)},
        "cm": {"shift": jnp.zeros((batch, 1, d_model), dtype)},
    }
