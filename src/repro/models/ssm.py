"""Mamba2 (SSD) mixer: chunked train/prefill recurrence + O(1) decode step.

The SSD scan follows the Mamba2 paper's chunked algorithm: quadratic
attention-like computation inside fixed-size chunks, a (heads, head_dim,
d_state) state carried across chunks by ``lax.scan``.  Per-head compute is
independent, which is what lets the distributed layer shard heads across the
``model`` mesh axis (TP) for the ssm/hybrid architectures."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.blocks import dense_init, init_norm, apply_norm


def init_ssm(key, d_model: int, ssm: SSMConfig, dtype=jnp.float32):
    d_in = ssm.expand * d_model
    n_heads = d_in // ssm.head_dim
    G, N = ssm.n_groups, ssm.d_state
    conv_dim = d_in + 2 * G * N
    ks = jax.random.split(key, 5)
    return {
        # x -> [z, xBC, dt]
        "in_proj": dense_init(
            ks[0], (d_model, 2 * d_in + 2 * G * N + n_heads), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (ssm.conv_kernel, conv_dim))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "out_norm": init_norm(d_in, "rmsnorm"),
        "out_proj": dense_init(ks[3], (d_in, d_model), dtype=dtype),
    }


def _split_proj(p, x, ssm: SSMConfig, d_model: int):
    d_in = ssm.expand * d_model
    n_heads = d_in // ssm.head_dim
    G, N = ssm.n_groups, ssm.d_state
    zxbcdt = jnp.dot(x, p["in_proj"].astype(x.dtype))
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * G * N]
    dt = zxbcdt[..., -n_heads:]
    return z, xbc, dt


def _causal_conv(p, xbc, conv_state: Optional[jnp.ndarray]):
    """Depthwise causal conv1d; returns (out, new_conv_state)."""
    K = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros_like(xbc[:, : K - 1])
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)                  # (B, S+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * p["conv_w"][i].astype(xbc.dtype)
              for i in range(K))
    out = jax.nn.silu((out + p["conv_b"].astype(xbc.dtype)
                       ).astype(jnp.float32)).astype(xbc.dtype)
    new_state = xp[:, xbc.shape[1]:]                          # last K-1 inputs
    return out, new_state


def ssd_chunked(xh, dt, B_, C_, a, chunk: int,
                state0: Optional[jnp.ndarray] = None):
    """SSD chunked scan.
    xh: (B,S,H,P); dt: (B,S,H) (post-softplus); B_/C_: (B,S,G,N); a: (H,)<0.
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bb, S, H, P = xh.shape
    G, N = B_.shape[2], B_.shape[3]
    L = min(chunk, S)
    while S % L:
        L -= 1
    nc = S // L
    rep = H // G

    xc = xh.reshape(Bb, nc, L, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bb, nc, L, H).astype(jnp.float32)
    Bc = B_.reshape(Bb, nc, L, G, N).astype(jnp.float32)
    Cc = C_.reshape(Bb, nc, L, G, N).astype(jnp.float32)
    Bh = jnp.repeat(Bc, rep, axis=3)                          # (B,nc,L,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * a[None, None, None, :]                         # (B,nc,L,H) <=0
    cum = jnp.cumsum(dA, axis=2)                              # inclusive

    def chunk_step(state, inp):
        xb, dtb, Bb_, Cb_, dAb, cumb = inp                    # (B,L,...)
        # intra-chunk (quadratic within L)
        seg = cumb[:, :, None, :] - cumb[:, None, :, :]       # (B,L,L,H) i-j
        ii, jj = jnp.meshgrid(jnp.arange(L), jnp.arange(L), indexing="ij")
        causal = (jj <= ii)[None, :, :, None]
        decay = jnp.where(causal, jnp.exp(jnp.minimum(seg, 0.0)), 0.0)
        sc = jnp.einsum("blhn,bmhn->blmh", Cb_, Bb_)          # (B,L,L,H)
        mat = sc * decay
        xdt = xb * dtb[..., None]                             # (B,L,H,P)
        y_intra = jnp.einsum("blmh,bmhp->blhp", mat, xdt)
        # inter-chunk (carry-in state)
        state_decay = jnp.exp(cumb)                           # (B,L,H)
        y_inter = jnp.einsum("blhn,bhpn->blhp", Cb_, state) \
            * state_decay[..., None]
        # state update
        tail = jnp.exp(cumb[:, -1:, :] - cumb)                # (B,L,H)
        new_state = state * jnp.exp(cumb[:, -1])[..., None, None] \
            + jnp.einsum("blhn,blhp->bhpn", Bb_ * tail[..., None], xdt)
        return new_state, y_intra + y_inter

    state0 = jnp.zeros((Bb, H, P, N), jnp.float32) if state0 is None \
        else state0.astype(jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, dtc, Bh, Ch, dA, cum))
    final, ys = jax.lax.scan(chunk_step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, P)
    return y, final


def ssm_block(p, x: jnp.ndarray, ssm: SSMConfig, *,
              cache: Optional[dict] = None):
    """Mamba2 mixer. Returns (out, new_cache).
    cache: {"conv": (B,K-1,C), "state": (B,H,P,N)} or None."""
    B, S, d_model = x.shape
    d_in = ssm.expand * d_model
    H, P = d_in // ssm.head_dim, ssm.head_dim
    G, N = ssm.n_groups, ssm.d_state

    from repro.distributed.ctx import constrain
    z, xbc, dt = _split_proj(p, x, ssm, d_model)
    xbc = constrain("channels3", xbc)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(p, xbc, conv_state)
    xh = constrain("heads4", xbc[..., :d_in].reshape(B, S, H, P))
    B_ = xbc[..., d_in:d_in + G * N].reshape(B, S, G, N)
    C_ = xbc[..., d_in + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])

    state0 = cache["state"] if cache is not None else None
    y, final_state = ssd_chunked(xh, dt, B_, C_, a, ssm.chunk, state0)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, d_in)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = apply_norm(p["out_norm"], y.astype(x.dtype), "rmsnorm")
    out = jnp.dot(y, p["out_proj"].astype(x.dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": final_state.astype(cache["state"].dtype)}
    return out, new_cache


def init_ssm_cache(batch: int, d_model: int, ssm: SSMConfig,
                   dtype=jnp.float32):
    d_in = ssm.expand * d_model
    H, P = d_in // ssm.head_dim, ssm.head_dim
    conv_dim = d_in + 2 * ssm.n_groups * ssm.d_state
    return {"conv": jnp.zeros((batch, ssm.conv_kernel - 1, conv_dim), dtype),
            "state": jnp.zeros((batch, H, P, ssm.d_state), jnp.float32)}
