"""repro: TPU-native reproduction of "Cross-Platform Fused MoE Dispatch in
Triton" — a multi-pod JAX training/inference framework whose first-class
feature is the paper's fused MoE dispatch pipeline (see DESIGN.md)."""

__version__ = "1.0.0"
