"""Pure-jnp oracles for every Pallas kernel.

Each function is the mathematical specification that the corresponding kernel
in this package must reproduce (asserted with assert_allclose in
tests/test_kernels.py across shape/dtype sweeps).  The refs are also the
CPU-fast execution path used by the full-scale dry-run (see
core/dispatch.py, impl="xla").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.scheduling import BlockSchedule


# ----------------------------------------------------------------------
# Router (paper §3.4)
# ----------------------------------------------------------------------
def router_ref(logits: jnp.ndarray, top_k: int, *, gating: str = "softmax",
               norm_topk: bool = False, routed_scale: float = 1.0):
    """Stable gating + iterative-argmax top-k.

    Matches the kernel's selection semantics exactly: iterative argmax with
    -inf masking (the paper masks with -1.0 because its scores live in [0,1];
    -inf is the strictly-safe generalization), ties broken toward the lowest
    expert index.

    logits: (T, E) -> (weights (T, k) f32, indices (T, k) i32)
    """
    x = logits.astype(jnp.float32)
    if gating == "softmax":
        x = x - jax.lax.stop_gradient(jnp.max(x, axis=-1, keepdims=True))
        e = jnp.exp(x)
        scores = e / jnp.sum(e, axis=-1, keepdims=True)
    elif gating == "sigmoid":
        scores = jax.nn.sigmoid(x)
    else:
        raise ValueError(f"unknown gating {gating!r}")

    E = scores.shape[-1]
    masked = scores
    idxs, ws = [], []
    for _ in range(top_k):
        idx = jnp.argmax(masked, axis=-1)
        w = jnp.take_along_axis(scores, idx[:, None], axis=-1)[:, 0]
        idxs.append(idx.astype(jnp.int32))
        ws.append(w)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.bool_)
        masked = jnp.where(onehot, -jnp.inf, masked)
    indices = jnp.stack(idxs, axis=-1)
    weights = jnp.stack(ws, axis=-1)
    if norm_topk:
        weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-20)
    return weights * routed_scale, indices


# ----------------------------------------------------------------------
# Permute / unpermute (paper §3.5)
# ----------------------------------------------------------------------
def permute_ref(x: jnp.ndarray, sched: BlockSchedule) -> jnp.ndarray:
    """Gather token rows into the padded expert-contiguous layout.

    x: (T, d) -> (capacity, d); padding rows (src_tok == -1) are zeros.
    """
    valid = sched.src_tok >= 0
    rows = x[jnp.maximum(sched.src_tok, 0)]
    return jnp.where(valid[:, None], rows, 0).astype(x.dtype)


def unpermute_ref(y: jnp.ndarray, sched: BlockSchedule,
                  weights: jnp.ndarray | None) -> jnp.ndarray:
    """Weighted gather-combine back to token order, fp32 accumulation.

    y: (capacity, d); weights: (T, k) or None (weights already folded into the
    down projection) -> (T, d)
    """
    T, k = sched.pos.shape
    gathered = y[sched.pos.reshape(-1)].reshape(T, k, -1).astype(jnp.float32)
    if weights is not None:
        gathered = gathered * weights[..., None].astype(jnp.float32)
    return jnp.sum(gathered, axis=1).astype(y.dtype)


# ----------------------------------------------------------------------
# Grouped GEMMs (paper §3.2 / §3.3)
# ----------------------------------------------------------------------
def _block_gather_matmul(x: jnp.ndarray, w: jnp.ndarray, sched: BlockSchedule):
    """Yield (x_blocks (B, M, K), w_blocks (B, K, N)) for a block-level ref."""
    M = sched.block_m
    nb = sched.capacity // M
    xb = x.reshape(nb, M, x.shape[-1])
    wb = w[sched.block_expert]
    return xb, wb


def grouped_gemm_ref(x: jnp.ndarray, w: jnp.ndarray, sched: BlockSchedule,
                     row_scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """Block-scheduled grouped GEMM: out[block i] = x[block i] @ w[expert(i)].

    x: (capacity, K), w: (E, K, N), row_scale: optional (capacity,) fp32
    epilogue scale (the fused combine-weight optimization) -> (capacity, N).
    """
    xb, wb = _block_gather_matmul(x, w, sched)
    out = jnp.einsum("bmk,bkn->bmn", xb.astype(jnp.float32),
                     wb.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out * sched.block_active[:, None, None].astype(jnp.float32)
    out = out.reshape(sched.capacity, -1)
    if row_scale is not None:
        out = out * row_scale[:, None].astype(jnp.float32)
    return out.astype(x.dtype)


def fused_gate_up_ref(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                      sched: BlockSchedule) -> jnp.ndarray:
    """Fused SwiGLU projections: silu(x @ w_gate) * (x @ w_up), fp32 epilogue.

    x: (capacity, K), w_*: (E, K, N) -> (capacity, N)
    """
    xb, wgb = _block_gather_matmul(x, w_gate, sched)
    _, wub = _block_gather_matmul(x, w_up, sched)
    g = jnp.einsum("bmk,bkn->bmn", xb.astype(jnp.float32), wgb.astype(jnp.float32))
    u = jnp.einsum("bmk,bkn->bmn", xb.astype(jnp.float32), wub.astype(jnp.float32))
    out = (g * jax.nn.sigmoid(g)) * u
    out = out * sched.block_active[:, None, None].astype(jnp.float32)
    return out.reshape(sched.capacity, -1).astype(x.dtype)


# ----------------------------------------------------------------------
# Whole-layer dense oracle (the paper's "PyTorch reference" analogue)
# ----------------------------------------------------------------------
def moe_ffn_dense_ref(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                      w_down: jnp.ndarray, weights: jnp.ndarray,
                      indices: jnp.ndarray) -> jnp.ndarray:
    """Loop-over-experts oracle: y_t = sum_j w_tj * FFN_{e_tj}(x_t).

    Computes every expert densely and combines with a mask — O(T*E*ffn)
    compute, exact semantics.  x: (T, d); w_gate/w_up: (E, d, f);
    w_down: (E, f, d); weights/indices: (T, k).
    """
    xf = x.astype(jnp.float32)
    g = jnp.einsum("td,edf->tef", xf, w_gate.astype(jnp.float32))
    u = jnp.einsum("td,edf->tef", xf, w_up.astype(jnp.float32))
    h = (g * jax.nn.sigmoid(g)) * u
    y_all = jnp.einsum("tef,efd->ted", h, w_down.astype(jnp.float32))  # (T,E,d)
    E = w_gate.shape[0]
    combine = jnp.zeros((x.shape[0], E), jnp.float32)
    onehot = jax.nn.one_hot(indices, E, dtype=jnp.float32)             # (T,k,E)
    combine = jnp.einsum("tk,tke->te", weights.astype(jnp.float32), onehot)
    return jnp.einsum("te,ted->td", combine, y_all).astype(x.dtype)


def grouped_wgrad_ref(x: jnp.ndarray, dy: jnp.ndarray,
                      sched: BlockSchedule, n_experts: int) -> jnp.ndarray:
    """Weight gradient of the grouped GEMM: dW[e] = x_e^T @ dy_e.

    x: (capacity, K); dy: (capacity, N) -> (E, K, N), fp32. Padding rows of
    x are zeros so they contribute nothing."""
    M = sched.block_m
    nb = sched.capacity // M
    xb = x.reshape(nb, M, -1).astype(jnp.float32)
    dyb = dy.reshape(nb, M, -1).astype(jnp.float32)
    per_block = jnp.einsum("bmk,bmn->bkn", xb, dyb)
    per_block = per_block * sched.block_active[:, None, None]
    dw = jnp.zeros((n_experts, x.shape[-1], dy.shape[-1]), jnp.float32)
    return dw.at[sched.block_expert].add(per_block)
