"""Fused gating + iterative top-k router kernel — the paper's §3.4.

One pass over a (BLOCK_T, E) tile of router logits:
  * manual numerically-stable softmax (subtract row max — the paper notes
    Triton's builtin skips this; jnp.softmax is stable but we keep the manual
    form so the kernel matches the paper's computation step-for-step), or
    sigmoid gating (DeepSeek-style) with optional top-k renormalization;
  * top-k by iterative argmax; selected entries are masked to -inf (the
    paper masks to -1.0 which suffices for scores in [0,1]; -inf is the
    strict generalization) so they can never be re-selected — the 0.0-mask
    failure mode at E=256 described in the paper cannot occur;
  * argmax is expressed as max + where + min-index so tie-breaking (lowest
    expert index) is explicit and identical on every backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(logits_ref, w_ref, i_ref, *, top_k: int, gating: str,
            norm_topk: bool, routed_scale: float):
    x = logits_ref[...].astype(jnp.float32)             # (BT, E)
    bt, E = x.shape
    if gating == "softmax":
        m = jnp.max(x, axis=-1, keepdims=True)          # manual stable softmax
        e = jnp.exp(x - m)
        scores = e / jnp.sum(e, axis=-1, keepdims=True)
    else:  # sigmoid
        scores = jax.nn.sigmoid(x)

    col = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    masked = scores
    for j in range(top_k):                              # static unroll, k <= 8
        mx = jnp.max(masked, axis=-1, keepdims=True)
        is_max = masked == mx
        idx = jnp.min(jnp.where(is_max, col, E), axis=-1)      # lowest index
        w = jnp.max(jnp.where(col == idx[:, None], scores, -jnp.inf), axis=-1)
        i_ref[:, j] = idx.astype(jnp.int32)
        w_ref[:, j] = w
        masked = jnp.where(col == idx[:, None], -jnp.inf, masked)

    if norm_topk:
        all_w = w_ref[...]
        w_ref[...] = all_w / (jnp.sum(all_w, axis=-1, keepdims=True) + 1e-20)
    if routed_scale != 1.0:
        w_ref[...] = w_ref[...] * routed_scale


@functools.partial(
    jax.jit,
    static_argnames=("top_k", "gating", "norm_topk", "routed_scale",
                     "block_t", "interpret"))
def router_topk(logits: jnp.ndarray, *, top_k: int, gating: str = "softmax",
                norm_topk: bool = False, routed_scale: float = 1.0,
                block_t: int = 256, interpret: bool = False):
    """logits: (T, E) -> (weights (T, top_k) f32, indices (T, top_k) i32)."""
    T, E = logits.shape
    block_t = min(block_t, T)
    assert T % block_t == 0, f"T={T} not divisible by block_t={block_t}"

    fn = pl.pallas_call(
        functools.partial(_kernel, top_k=top_k, gating=gating,
                          norm_topk=norm_topk, routed_scale=routed_scale),
        grid=(T // block_t,),
        in_specs=[pl.BlockSpec((block_t, E), lambda t: (t, 0))],
        out_specs=[pl.BlockSpec((block_t, top_k), lambda t: (t, 0)),
                   pl.BlockSpec((block_t, top_k), lambda t: (t, 0))],
        out_shape=[jax.ShapeDtypeStruct((T, top_k), jnp.float32),
                   jax.ShapeDtypeStruct((T, top_k), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )
    return tuple(fn(logits))
