"""Grouped weight-gradient (tgmm) kernel — training backward, beyond-paper.

The paper is inference-only (its Limitation 3).  Training the dispatch
pipeline needs the transposed grouped GEMM:

    dW[e] = sum_{rows r of expert e} x[r]^T dy[r]        (E, K, N)

TPU formulation: grid (K-tiles, N-tiles, M-blocks) with M innermost, so
consecutive grid steps stream the (tile-aligned, expert-contiguous)
M-blocks of one expert through an fp32 VMEM accumulator and the output
block (expert, ki, ni) is flushed exactly once at each expert boundary —
the revisiting-accumulation pattern, driven by the same scalar-prefetch
schedule as the forward kernels.  Trailing inactive blocks carry the last
expert id (schedule clamp), so they extend — never reset — a real
expert's accumulation; experts that received zero tokens are zeroed by
the ops wrapper (their output blocks are never visited).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(block_expert_ref, block_active_ref,
            x_ref, dy_ref,
            out_ref,
            acc_ref, *, n_m: int):
    m = pl.program_id(2)
    be = block_expert_ref[m]
    prev = block_expert_ref[jnp.maximum(m - 1, 0)]
    first = (m == 0) | (be != prev)
    nxt = block_expert_ref[jnp.minimum(m + 1, n_m - 1)]
    last = (m == n_m - 1) | (nxt != be)
    active = block_active_ref[m] == 1

    @pl.when(first)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(active)
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], dy_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),   # x^T @ dy
            preferred_element_type=jnp.float32)

    @pl.when(last)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_experts", "block_m", "block_k", "block_n",
                     "interpret", "out_dtype"))
def grouped_wgrad(x: jnp.ndarray, dy: jnp.ndarray,
                  block_expert: jnp.ndarray, block_active: jnp.ndarray, *,
                  n_experts: int, block_m: int, block_k: int, block_n: int,
                  interpret: bool = False, out_dtype=None) -> jnp.ndarray:
    """x: (capacity, K); dy: (capacity, N) — both in the tile-aligned
    expert-contiguous layout -> dW: (E, K, N)."""
    capacity, K = x.shape
    _, N = dy.shape
    assert capacity % block_m == 0 and K % block_k == 0 and N % block_n == 0
    n_m = capacity // block_m

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(K // block_k, N // block_n, n_m),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda ki, ni, m, be, ba: (m, ki)),
            pl.BlockSpec((block_m, block_n), lambda ki, ni, m, be, ba: (m, ni)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_k, block_n), lambda ki, ni, m, be, ba: (be[m], ki, ni)),
        scratch_shapes=[pltpu.VMEM((block_k, block_n), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, n_m=n_m),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_experts, K, N),
                                       out_dtype or jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return fn(block_expert, block_active, x, dy)
