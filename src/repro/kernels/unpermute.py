"""Unpermute + weighted-combine kernel — the paper's §3.5 inverse scatter.

For token t, the k expert outputs live at padded rows ``pos[t, :]``.  The
grid is (T, d-tiles, k) with k innermost: the output block (t, j) is
*revisited* across the k axis, accumulating ``w[t, c] * y[pos[t, c]]`` into an
fp32 VMEM scratch (the paper's FP32 accumulation), written out once on the
last visit.  When the combine weights were already folded into the down
projection's epilogue (our beyond-paper fusion), the caller passes
``weights=None`` and the kernel degenerates to an unweighted sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(pos_ref, y_ref, w_ref, out_ref, acc_ref, *, top_k: int,
            has_weights: bool):
    c = pl.program_id(2)

    contrib = y_ref[...].astype(jnp.float32)
    if has_weights:
        contrib = contrib * w_ref[0, c]

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(c != 0)
    def _accum():
        acc_ref[...] += contrib

    @pl.when(c == top_k - 1)
    def _write():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def unpermute(y: jnp.ndarray, pos: jnp.ndarray,
              weights: jnp.ndarray | None, *, block_d: int = 0,
              interpret: bool = False) -> jnp.ndarray:
    """y: (capacity, d); pos: (T, k) padded-row of expanded token (t, c);
    weights: (T, k) combine weights or None (already folded) -> (T, d)."""
    capacity, d = y.shape
    T, k = pos.shape
    block_d = block_d or d
    assert d % block_d == 0
    has_weights = weights is not None
    pos_flat = pos.reshape(-1).astype(jnp.int32)

    in_specs = [pl.BlockSpec(
        (1, block_d), lambda t, j, c, pos: (pos[t * k + c], j))]
    operands = [y]
    if has_weights:
        in_specs.append(pl.BlockSpec((1, k), lambda t, j, c, pos: (t, 0)))
        operands.append(weights.astype(jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, d // block_d, k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_d), lambda t, j, c, pos: (t, j)),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
    )

    kernel = functools.partial(_kernel, top_k=k, has_weights=has_weights)
    if not has_weights:
        def kernel(pos_r, y_r, out_r, acc_r):  # noqa: F811
            _kernel(pos_r, y_r, None, out_r, acc_r, top_k=k, has_weights=False)

    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, d), y.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return fn(pos_flat, *operands)
