"""Fused gate+up grouped GEMM with in-register SiLU — the paper's §3.3.

Both SwiGLU projections are computed from the SAME input tile per grid step:
the A block is DMA'd HBM->VMEM once and feeds two MXU matmuls whose fp32
accumulators live in VMEM scratch.  The SiLU(gate) * up epilogue runs in
vector registers before a single bf16 copy-out, so the ``gate_out`` and
``up_out`` intermediates never exist in HBM.

HBM traffic (T tokens, K = d_model, F = d_ffn, bf16):
  unfused: A read twice (2*T*K*2B) + gate_out/up_out written + read back
           (4*T*F*2B) + intermediate written (T*F*2B)   = 10TF + 4TK bytes*
  fused:   A read once (T*K*2B) + intermediate written (T*F*2B) = 2TF + 2TK
  (*weight traffic identical in both; the paper counts a subset of these
  terms and lands on ~35% — our accounting in benchmarks/stage_roofline.py
  reports both conventions.)

Quantized weights: like grouped_gemm.py, ``w_format`` turns both weight
operands into compressed payloads with per-channel ``w*_scale`` operands,
dequantized per DMA'd block in VREGs right before the MXU issues
(DESIGN.md §8).  ``w_format="dense"`` is the original kernel (bitwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.grouped_gemm import dequant_weight_block


def _kernel(block_expert_ref, block_active_ref,       # scalar prefetch
            x_ref, wg_ref, wu_ref, wsg_ref, wsu_ref,  # inputs (ws* opt.)
            out_ref,                                  # output
            acc_g_ref, acc_u_ref,                     # scratch
            *, n_k: int, w_format: str):
    m, _, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    active = block_active_ref[m] == 1

    @pl.when(k == 0)
    def _zero():
        acc_g_ref[...] = jnp.zeros_like(acc_g_ref)
        acc_u_ref[...] = jnp.zeros_like(acc_u_ref)

    @pl.when(active)
    def _accum():
        x = x_ref[...]                                # one VMEM A-tile ...
        wg = dequant_weight_block(
            wg_ref[0], None if wsg_ref is None else wsg_ref[...],
            w_format, x.dtype)
        wu = dequant_weight_block(
            wu_ref[0], None if wsu_ref is None else wsu_ref[...],
            w_format, x.dtype)
        acc_g_ref[...] += jnp.dot(x, wg,              # ... two MXU issues
                                  preferred_element_type=jnp.float32)
        acc_u_ref[...] += jnp.dot(x, wu,
                                  preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        g = acc_g_ref[...]
        h = g * jax.nn.sigmoid(g) * acc_u_ref[...]    # SiLU(g) * u, in VREGs
        out_ref[...] = h.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret",
                     "out_dtype", "w_format"))
def fused_gate_up(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                  block_expert: jnp.ndarray, block_active: jnp.ndarray,
                  wg_scale: jnp.ndarray | None = None,
                  wu_scale: jnp.ndarray | None = None, *,
                  block_m: int, block_n: int, block_k: int,
                  w_format: str = "dense",
                  interpret: bool = False, out_dtype=None) -> jnp.ndarray:
    """x: (capacity, K); w_gate/w_up: (E, K, F) dense or the scheme's
    packed payload; w*_scale: (E, F) f32 per-channel scales (quant only)
    -> silu(x@wg)*(x@wu): (capacity, F).  ``block_k`` is in LOGICAL K."""
    capacity, K = x.shape
    F = w_gate.shape[-1]
    pack = 2 if w_format == "int4" else 1
    assert w_up.shape == w_gate.shape
    assert w_gate.shape[1] * pack == K, (w_gate.shape, K, w_format)
    assert (wg_scale is not None) == (w_format != "dense"), w_format
    assert capacity % block_m == 0 and K % block_k == 0 and F % block_n == 0, (
        f"shape {(capacity, K, F)} not divisible by blocks "
        f"{(block_m, block_k, block_n)}")
    assert block_k % pack == 0, (block_k, w_format)
    n_m, n_n, n_k = capacity // block_m, F // block_n, K // block_k
    quant = w_format != "dense"

    w_spec = pl.BlockSpec((1, block_k // pack, block_n),
                          lambda m, n, k, be, ba: (be[m], k, n))
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda m, n, k, be, ba: (m, k)),
        w_spec, w_spec,
    ]
    operands = [x, w_gate, w_up]
    if quant:
        s_spec = pl.BlockSpec((1, block_n),
                              lambda m, n, k, be, ba: (be[m], n))
        in_specs += [s_spec, s_spec]
        operands += [wg_scale.astype(jnp.float32),
                     wu_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_m, n_n, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda m, n, k, be, ba: (m, n)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32),
                        pltpu.VMEM((block_m, block_n), jnp.float32)],
    )

    def kernel(be, ba, *refs):
        # refs: x, wg, wu, [wsg, wsu], out, acc_g, acc_u
        it = iter(refs)
        x_ref, wg_ref, wu_ref = next(it), next(it), next(it)
        wsg_ref = next(it) if quant else None
        wsu_ref = next(it) if quant else None
        out_ref, acc_g_ref, acc_u_ref = next(it), next(it), next(it)
        _kernel(be, ba, x_ref, wg_ref, wu_ref, wsg_ref, wsu_ref,
                out_ref, acc_g_ref, acc_u_ref, n_k=n_k, w_format=w_format)

    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((capacity, F), out_dtype or x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return fn(block_expert, block_active, *operands)
