"""Fused gate+up grouped GEMM with in-register SiLU — the paper's §3.3.

Both SwiGLU projections are computed from the SAME input tile per grid step:
the A block is DMA'd HBM->VMEM once and feeds two MXU matmuls whose fp32
accumulators live in VMEM scratch.  The SiLU(gate) * up epilogue runs in
vector registers before a single bf16 copy-out, so the ``gate_out`` and
``up_out`` intermediates never exist in HBM.

HBM traffic (T tokens, K = d_model, F = d_ffn, bf16):
  unfused: A read twice (2*T*K*2B) + gate_out/up_out written + read back
           (4*T*F*2B) + intermediate written (T*F*2B)   = 10TF + 4TK bytes*
  fused:   A read once (T*K*2B) + intermediate written (T*F*2B) = 2TF + 2TK
  (*weight traffic identical in both; the paper counts a subset of these
  terms and lands on ~35% — our accounting in benchmarks/stage_roofline.py
  reports both conventions.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(block_expert_ref, block_active_ref,       # scalar prefetch
            x_ref, wg_ref, wu_ref,                    # inputs
            out_ref,                                  # output
            acc_g_ref, acc_u_ref,                     # scratch
            *, n_k: int):
    m, _, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    active = block_active_ref[m] == 1

    @pl.when(k == 0)
    def _zero():
        acc_g_ref[...] = jnp.zeros_like(acc_g_ref)
        acc_u_ref[...] = jnp.zeros_like(acc_u_ref)

    @pl.when(active)
    def _accum():
        x = x_ref[...]                                # one VMEM A-tile ...
        acc_g_ref[...] += jnp.dot(x, wg_ref[0],      # ... two MXU issues
                                  preferred_element_type=jnp.float32)
        acc_u_ref[...] += jnp.dot(x, wu_ref[0],
                                  preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        g = acc_g_ref[...]
        h = g * jax.nn.sigmoid(g) * acc_u_ref[...]    # SiLU(g) * u, in VREGs
        out_ref[...] = h.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "out_dtype"))
def fused_gate_up(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                  block_expert: jnp.ndarray, block_active: jnp.ndarray, *,
                  block_m: int, block_n: int, block_k: int,
                  interpret: bool = False, out_dtype=None) -> jnp.ndarray:
    """x: (capacity, K); w_gate/w_up: (E, K, F) -> silu(x@wg)*(x@wu): (capacity, F)."""
    capacity, K = x.shape
    _, _, F = w_gate.shape
    assert w_up.shape == w_gate.shape
    assert capacity % block_m == 0 and K % block_k == 0 and F % block_n == 0, (
        f"shape {(capacity, K, F)} not divisible by blocks "
        f"{(block_m, block_k, block_n)}")
    n_m, n_n, n_k = capacity // block_m, F // block_n, K // block_k

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, n, k, be, ba: (m, k)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda m, n, k, be, ba: (be[m], k, n)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda m, n, k, be, ba: (be[m], k, n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda m, n, k, be, ba: (m, n)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32),
                        pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((capacity, F), out_dtype or x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return fn(block_expert, block_active, x, w_gate, w_up)
