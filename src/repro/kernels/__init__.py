"""Pallas TPU kernels for the paper's MoE dispatch pipeline.

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrappers, block-size policy), ref.py (pure-jnp oracles).
"""
from repro.kernels import ops, ref  # noqa: F401
