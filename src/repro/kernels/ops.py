"""Public jit'd wrappers for the Pallas kernels.

Handles block-size selection (MXU-aligned divisors), automatic
``interpret=True`` off-TPU (this container validates kernels on CPU in
interpret mode; the compiled target is TPU v5e), adapts the
schedule-carrying call signatures to the BlockSchedule tuple, and splits
scheme-tagged ``QuantTensor`` expert weights into the kernels' compressed
payload + per-channel-scale operands (in-kernel dequant, DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quantization import QuantTensor, get_scheme
from repro.scheduling import BlockSchedule
from repro.kernels import fused_gate_up as _fgu
from repro.kernels import grouped_gemm as _gg
from repro.kernels import permute as _perm
from repro.kernels import router_topk as _router
from repro.kernels import unpermute as _unperm


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp(flag: bool | None) -> bool:
    return (not on_tpu()) if flag is None else flag


_block_warned: set = set()


def _warn_block_once(fn: str, n: int, target: int, got: int) -> None:
    key = (fn, n, target, got)
    if key not in _block_warned:
        _block_warned.add(key)
        import warnings
        warnings.warn(
            f"{fn}: no well-aligned divisor of {n} <= {target}; falling "
            f"back to block size {got} (correct but slow — pad the dim "
            "toward a multiple of 128 for MXU-shaped tiles)",
            RuntimeWarning, stacklevel=3)


def pick_block(n: int, target: int, align: int = 128) -> int:
    """Largest TRUE divisor of n that is <= target, preferring MXU
    alignment (multiples of ``align``, then of 8, then any).

    Total: every n >= 1 yields a valid divisor — awkward dims (primes,
    odd N/K) fall back to the largest unaligned divisor and warn once
    per (n, target) instead of tripping the kernels' divisibility
    asserts downstream."""
    if n < 1:
        raise ValueError(f"pick_block: non-positive dim {n}")
    target = max(1, min(n, target))
    for a in (align, 8, 1):
        if n % a:
            continue
        b = (target // a) * a
        while b >= a:
            if n % b == 0:
                if b < min(8, target) and n >= 8:
                    # degenerate: a big dim with only tiny divisors
                    _warn_block_once("pick_block", n, target, b)
                return b
            b -= a
    # unreachable (a=1 always succeeds at b=1), kept as a total fallback
    _warn_block_once("pick_block", n, target, 1)
    return 1


def _weight_operands(w):
    """Split an expert-weight stack into kernel operands.

    Dense array -> (w, None, "dense", (K, N)); QuantTensor -> (payload,
    (E, N) f32 channel scales, scheme kernel_format, logical (K, N)).
    """
    if isinstance(w, QuantTensor):
        if w.meta:
            # padded layouts (int4 odd-K) have no in-kernel dequant path:
            # fall back to the dense operand (edge case; the paper
            # configs' K are all even)
            w = w.materialize()
            return w, None, "dense", tuple(w.shape[-2:])
        sch = get_scheme(w.scheme)
        K, N = w.shape[-2:]
        return w.q, sch.channel_scales(w), sch.kernel_format, (K, N)
    return w, None, "dense", tuple(w.shape[-2:])


def _pick_block_k(K: int, target: int, w_format: str) -> int:
    """Like pick_block, but an int4-packed payload DMAs block_k//2 rows,
    so the logical block must stay even."""
    bk = pick_block(K, target)
    if w_format == "int4":
        while bk > 2 and (bk % 2 or K % bk):
            bk -= 1                    # K is even (asserted at pack time)
        if bk % 2 or K % bk:
            # total fallback: K even (pack-time invariant) => 2 divides K
            bk = 2
            if K % bk:
                raise ValueError(
                    f"int4 payload needs an even K divisor; K={K} is odd")
            _warn_block_once("_pick_block_k", K, target, bk)
    return bk


def _tuned_blocks(kernel: str, *, M: int, K: int, N: int, E: int,
                  dtype, fmt: str, block_n: int, block_k: int):
    """Trace-time tune-cache consult (DESIGN.md §12): swap the hard-coded
    block targets for this shape key's swept winner when one exists.

    Shapes are concrete Python ints during tracing, so the lookup runs
    once per compiled shape and costs nothing per step.  A miss keeps the
    caller's defaults — an absent/stale cache degrades, never breaks."""
    from repro.tuning import lookup_block_sizes
    rec = lookup_block_sizes(kernel, M=M, K=K, N=N, E=E,
                             dtype=jnp.dtype(dtype).name,
                             scheme=fmt, executor="pallas")
    if rec is None:
        return block_n, block_k
    return rec["block_n"], rec["block_k"]


# ----------------------------------------------------------------------
def router_topk(logits: jnp.ndarray, *, top_k: int, gating: str = "softmax",
                norm_topk: bool = False, routed_scale: float = 1.0,
                block_t: int = 256, interpret: bool | None = None):
    T = logits.shape[0]
    return _router.router_topk(
        logits, top_k=top_k, gating=gating, norm_topk=norm_topk,
        routed_scale=routed_scale, block_t=pick_block(T, block_t, align=8),
        interpret=_interp(interpret))


def permute(x: jnp.ndarray, sched: BlockSchedule, *, block_d: int = 2048,
            interpret: bool | None = None) -> jnp.ndarray:
    return _perm.permute(x, sched.src_tok,
                         block_d=pick_block(x.shape[-1], block_d),
                         interpret=_interp(interpret))


def unpermute(y: jnp.ndarray, sched: BlockSchedule,
              weights: jnp.ndarray | None, *, block_d: int = 2048,
              interpret: bool | None = None) -> jnp.ndarray:
    return _unperm.unpermute(y, sched.pos, weights,
                             block_d=pick_block(y.shape[-1], block_d),
                             interpret=_interp(interpret))


def grouped_gemm(x: jnp.ndarray, w, sched: BlockSchedule,
                 row_scale: jnp.ndarray | None = None, *,
                 block_n: int = 512, block_k: int = 512,
                 autotune: bool = False,
                 interpret: bool | None = None) -> jnp.ndarray:
    """``w``: (E, K, N) array or a QuantTensor (in-kernel dequant).
    ``autotune`` consults the persistent tune cache for this shape key's
    swept (block_n, block_k) winner before the divisor snap."""
    wq, ws, fmt, (K, N) = _weight_operands(w)
    E = wq.shape[0]
    if autotune:
        block_n, block_k = _tuned_blocks(
            "grouped_gemm", M=x.shape[0], K=K, N=N, E=E, dtype=x.dtype,
            fmt=fmt, block_n=block_n, block_k=block_k)
    return _gg.grouped_gemm(
        x, wq, sched.block_expert, sched.block_active, row_scale, ws,
        block_m=sched.block_m, w_format=fmt,
        block_n=pick_block(N, block_n),
        block_k=_pick_block_k(K, block_k, fmt),
        interpret=_interp(interpret))


def fused_gate_up(x: jnp.ndarray, w_gate, w_up,
                  sched: BlockSchedule, *, block_n: int = 512,
                  block_k: int = 512, autotune: bool = False,
                  interpret: bool | None = None) -> jnp.ndarray:
    """``w_gate``/``w_up``: (E, K, F) arrays or QuantTensors under ONE
    scheme (in-kernel dequant).  ``autotune`` as in ``grouped_gemm``."""
    wgq, wsg, fmt_g, (K, F) = _weight_operands(w_gate)
    wuq, wsu, fmt_u, _ = _weight_operands(w_up)
    assert fmt_g == fmt_u, (fmt_g, fmt_u)
    if autotune:
        block_n, block_k = _tuned_blocks(
            "fused_gate_up", M=x.shape[0], K=K, N=F, E=wgq.shape[0],
            dtype=x.dtype, fmt=fmt_g, block_n=block_n, block_k=block_k)
    return _fgu.fused_gate_up(
        x, wgq, wuq, sched.block_expert, sched.block_active, wsg, wsu,
        block_m=sched.block_m, w_format=fmt_g,
        block_n=pick_block(F, block_n),
        block_k=_pick_block_k(K, block_k, fmt_g),
        interpret=_interp(interpret))


def grouped_wgrad(x: jnp.ndarray, dy: jnp.ndarray, sched: BlockSchedule,
                  n_experts: int, *, block_n: int = 512, block_k: int = 512,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Training-backward tgmm: dW[e] = x_e^T dy_e over the padded layout.
    Experts that received zero tokens never get their block flushed by the
    kernel, so they are explicitly zeroed here."""
    from repro.kernels import grouped_wgrad as _wg
    K, N = x.shape[-1], dy.shape[-1]
    dw = _wg.grouped_wgrad(
        x, dy, sched.block_expert, sched.block_active,
        n_experts=n_experts, block_m=sched.block_m,
        block_k=pick_block(K, block_k), block_n=pick_block(N, block_n),
        interpret=_interp(interpret))
    return jnp.where((sched.counts > 0)[:, None, None], dw, 0.0)
