"""Public jit'd wrappers for the Pallas kernels.

Handles block-size selection (MXU-aligned divisors), automatic
``interpret=True`` off-TPU (this container validates kernels on CPU in
interpret mode; the compiled target is TPU v5e), and adapts the
schedule-carrying call signatures to the BlockSchedule tuple.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.scheduling import BlockSchedule
from repro.kernels import fused_gate_up as _fgu
from repro.kernels import grouped_gemm as _gg
from repro.kernels import permute as _perm
from repro.kernels import router_topk as _router
from repro.kernels import unpermute as _unperm


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp(flag: bool | None) -> bool:
    return (not on_tpu()) if flag is None else flag


def pick_block(n: int, target: int, align: int = 128) -> int:
    """Largest divisor of n that is <= target, preferring MXU alignment."""
    target = min(n, target)
    for a in (align, 8, 1):
        if n % a == 0:
            b = (target // a) * a
            while b >= a:
                if n % b == 0:
                    return b
                b -= a
    return 1


# ----------------------------------------------------------------------
def router_topk(logits: jnp.ndarray, *, top_k: int, gating: str = "softmax",
                norm_topk: bool = False, routed_scale: float = 1.0,
                block_t: int = 256, interpret: bool | None = None):
    T = logits.shape[0]
    return _router.router_topk(
        logits, top_k=top_k, gating=gating, norm_topk=norm_topk,
        routed_scale=routed_scale, block_t=pick_block(T, block_t, align=8),
        interpret=_interp(interpret))


def permute(x: jnp.ndarray, sched: BlockSchedule, *, block_d: int = 2048,
            interpret: bool | None = None) -> jnp.ndarray:
    return _perm.permute(x, sched.src_tok,
                         block_d=pick_block(x.shape[-1], block_d),
                         interpret=_interp(interpret))


def unpermute(y: jnp.ndarray, sched: BlockSchedule,
              weights: jnp.ndarray | None, *, block_d: int = 2048,
              interpret: bool | None = None) -> jnp.ndarray:
    return _unperm.unpermute(y, sched.pos, weights,
                             block_d=pick_block(y.shape[-1], block_d),
                             interpret=_interp(interpret))


def grouped_gemm(x: jnp.ndarray, w: jnp.ndarray, sched: BlockSchedule,
                 row_scale: jnp.ndarray | None = None, *,
                 block_n: int = 512, block_k: int = 512,
                 interpret: bool | None = None) -> jnp.ndarray:
    _, K, N = w.shape
    return _gg.grouped_gemm(
        x, w, sched.block_expert, sched.block_active, row_scale,
        block_m=sched.block_m,
        block_n=pick_block(N, block_n), block_k=pick_block(K, block_k),
        interpret=_interp(interpret))


def fused_gate_up(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                  sched: BlockSchedule, *, block_n: int = 512,
                  block_k: int = 512,
                  interpret: bool | None = None) -> jnp.ndarray:
    _, K, F = w_gate.shape
    return _fgu.fused_gate_up(
        x, w_gate, w_up, sched.block_expert, sched.block_active,
        block_m=sched.block_m,
        block_n=pick_block(F, block_n), block_k=pick_block(K, block_k),
        interpret=_interp(interpret))


def grouped_wgrad(x: jnp.ndarray, dy: jnp.ndarray, sched: BlockSchedule,
                  n_experts: int, *, block_n: int = 512, block_k: int = 512,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Training-backward tgmm: dW[e] = x_e^T dy_e over the padded layout.
    Experts that received zero tokens never get their block flushed by the
    kernel, so they are explicitly zeroed here."""
    from repro.kernels import grouped_wgrad as _wg
    K, N = x.shape[-1], dy.shape[-1]
    dw = _wg.grouped_wgrad(
        x, dy, sched.block_expert, sched.block_active,
        n_experts=n_experts, block_m=sched.block_m,
        block_k=pick_block(K, block_k), block_n=pick_block(N, block_n),
        interpret=_interp(interpret))
    return jnp.where((sched.counts > 0)[:, None, None], dw, 0.0)
