"""Token permutation kernel — the paper's §3.5 gather, TPU form.

Scatter-to-expert-contiguous is expressed as its inverse gather: grid step
(i, j) copies hidden-dim tile j of source token ``src_tok[i]`` into padded
row i.  The row index comes from a scalar-prefetch table consumed by the
input ``BlockSpec.index_map``, which turns the Pallas pipeline into a
sequence of gather DMAs (HBM->VMEM->HBM) — the TPU analogue of the paper's
coalesced BLOCK_D-tiled gather.  Padding rows (src_tok == -1) are zero-filled
so downstream grouped GEMMs see exact zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(src_ref, x_ref, out_ref):
    i = pl.program_id(0)
    valid = src_ref[i] >= 0
    out_ref[...] = jnp.where(valid, x_ref[...], 0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def permute(x: jnp.ndarray, src_tok: jnp.ndarray, *, block_d: int = 0,
            interpret: bool = False) -> jnp.ndarray:
    """x: (T, d); src_tok: (capacity,) int32 (-1 = padding) -> (capacity, d)."""
    T, d = x.shape
    capacity = src_tok.shape[0]
    block_d = block_d or d
    assert d % block_d == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(capacity, d // block_d),
        in_specs=[pl.BlockSpec(
            (1, block_d), lambda i, j, src: (jnp.maximum(src[i], 0), j))],
        out_specs=pl.BlockSpec((1, block_d), lambda i, j, src: (i, j)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((capacity, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return fn(src_tok, x)
