"""Pallas-TPU API compatibility across jax versions.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels import the alias from here so they compile against either name.
"""
from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams",
                         getattr(_pltpu, "TPUCompilerParams", None))
