"""Fused Pallas paged-attention decode kernel (DESIGN.md §12).

The paged serve path (PR 5) reads KV through ``gather_block_kv``: an XLA
gather that MATERIALIZES each row's logically-contiguous (B, nb*bs, H, D)
view in HBM before flash attention re-reads it — per decoded token, the
whole attended cache is written once and read once more than necessary.
This kernel runs flash-style online softmax directly over the block pool:
the grid walks each row's block table, the scalar-prefetched table drives
the KV ``BlockSpec.index_map`` (the same SMEM-lookup trick the grouped
GEMM uses for expert weights), and each (bs, D) KV tile is DMA'd from the
pool exactly once.  The gathered view never exists.

Masking mirrors ``models/attention.flash_attention``: an inclusive
per-row ``kv_limit``, optional causal / sliding-window terms against a
per-row query position, optional logit softcap, fp32 accumulation with
the probability matrix cast to the value dtype before its MXU issue, and
the same ``max(l, 1e-30)`` guarded divide — so greedy argmax tokens are
identical to the gather path (asserted token-for-token in
tests/test_paged_attention.py; ``gather_block_kv`` stays as the
differential oracle).

The MLA latent path fuses too: scores there are ``q_eff @ ckv^T +
q_rope @ kr^T`` with the latent ``ckv`` doubling as the value — passed as
a second (q2, k2_pool) score operand, so the per-row latent view is never
concatenated or materialized either.

Off-TPU this runs in interpret mode (the container validates on CPU; the
compiled target is TPU v5e).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30          # finite -inf stand-in (matches attention.py)


def _kernel(tables_ref, lim_ref, qpos_ref,        # scalar prefetch
            q_ref, k_ref, v_ref, q2_ref, k2_ref,  # inputs (q2/k2 optional)
            o_ref,                                # output
            m_ref, l_ref, acc_ref,                # scratch
            *, n_blocks_per_row: int, block_size: int,
            causal: bool, window: Optional[int],
            logit_softcap: Optional[float]):
    b, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                               # (G, D) pre-scaled
    k = k_ref[0, :, 0, :]                         # (bs, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (G, bs)
    if q2_ref is not None:
        s += jnp.dot(q2_ref[0, 0], k2_ref[0, :, 0, :].T,
                     preferred_element_type=jnp.float32)
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)

    kpos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)            # (1, bs)
    ok = kpos <= lim_ref[b]
    if causal:
        ok &= kpos <= qpos_ref[b]
    if window is not None:
        ok &= kpos > qpos_ref[b] - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]       # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    v = v_ref[0, :, 0, :]                         # (bs, Dv)
    acc_ref[...] = corr * acc_ref[...] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)

    @pl.when(j == n_blocks_per_row - 1)
    def _flush():
        l = l_ref[...]
        out = jnp.where(l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_softcap", "scale",
                     "interpret"))
def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, tables: jnp.ndarray,
                           kv_limit: jnp.ndarray, *,
                           scale: Optional[float] = None,
                           q_pos: Optional[jnp.ndarray] = None,
                           causal: bool = False,
                           window: Optional[int] = None,
                           logit_softcap: Optional[float] = None,
                           q2: Optional[jnp.ndarray] = None,
                           k2_pool: Optional[jnp.ndarray] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """One decode step of attention straight off the paged block pool.

    q: (B, Hkv, G, D) — row b is one decode token, GQA grouped;
    k_pool: (n_blocks, bs, Hkv, D); v_pool: (n_blocks, bs, Hkv, Dv);
    tables: (B, nb) int32 physical block ids in logical order;
    kv_limit: (B,) or scalar inclusive max attended position;
    q_pos: (B,) query positions — required for causal/window masks;
    q2/k2_pool: optional second score operand (MLA: q_eff/ckv + q_rope/kr
    with v_pool == the ckv pool), same layout with its own depth D2;
    scale: applied to q (and q2) in the query dtype, default D**-0.5.

    Returns (B, Hkv, G, Dv) in q.dtype.  Unallocated table entries may
    point at arbitrary pool blocks; their logical positions lie beyond
    ``kv_limit`` and are masked — identical semantics to
    ``gather_block_kv`` + ``flash_attention``.
    """
    B, Hkv, G, D = q.shape
    n_blocks, bs = k_pool.shape[0], k_pool.shape[1]
    Dv = v_pool.shape[-1]
    nb = tables.shape[1]
    assert tables.shape == (B, nb), (tables.shape, B)
    assert k_pool.shape[2] == Hkv and v_pool.shape[2] == Hkv
    if scale is None:
        scale = D ** -0.5
    q = q * jnp.asarray(scale, q.dtype)
    two = q2 is not None
    if two:
        assert k2_pool is not None
        q2 = q2 * jnp.asarray(scale, q2.dtype)
        D2 = q2.shape[-1]
        assert k2_pool.shape == (n_blocks, bs, Hkv, D2), k2_pool.shape

    tf = tables.reshape(-1).astype(jnp.int32)                 # (B*nb,)
    lim = jnp.broadcast_to(jnp.asarray(kv_limit), (B,)).astype(jnp.int32)
    qp = (jnp.zeros((B,), jnp.int32) if q_pos is None
          else jnp.broadcast_to(jnp.asarray(q_pos), (B,)).astype(jnp.int32))
    if (causal or window is not None) and q_pos is None:
        raise ValueError("causal/window masks need q_pos (per-row query "
                         "positions)")

    in_specs = [
        pl.BlockSpec((1, 1, G, D), lambda b, h, j, t, l, p: (b, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, D),
                     lambda b, h, j, t, l, p: (t[b * nb + j], 0, h, 0)),
        pl.BlockSpec((1, bs, 1, Dv),
                     lambda b, h, j, t, l, p: (t[b * nb + j], 0, h, 0)),
    ]
    operands = [q, k_pool, v_pool]
    if two:
        in_specs += [
            pl.BlockSpec((1, 1, G, D2),
                         lambda b, h, j, t, l, p: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D2),
                         lambda b, h, j, t, l, p: (t[b * nb + j], 0, h, 0)),
        ]
        operands += [q2, k2_pool]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, Dv),
                               lambda b, h, j, t, l, p: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, Dv), jnp.float32)],
    )

    def kernel(t, l, p, *refs):
        it = iter(refs)
        q_ref, k_ref, v_ref = next(it), next(it), next(it)
        q2_ref = next(it) if two else None
        k2_ref = next(it) if two else None
        o_ref, m_ref, l_ref, acc_ref = next(it), next(it), next(it), next(it)
        _kernel(t, l, p, q_ref, k_ref, v_ref, q2_ref, k2_ref,
                o_ref, m_ref, l_ref, acc_ref,
                n_blocks_per_row=nb, block_size=bs, causal=causal,
                window=window, logit_softcap=logit_softcap)

    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return fn(tf, lim, qp, *operands)
