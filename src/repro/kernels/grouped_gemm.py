"""Block-scheduled grouped GEMM — the paper's §3.2, TPU-native.

One ``pallas_call`` computes ``out[block i] = x[block i] @ w[expert(i)]`` for
every M-tile in the tile-aligned expert-contiguous layout.  The schedule
(block->expert, block->active) is passed as scalar-prefetch operands so the
weight ``BlockSpec.index_map`` selects each block's expert weights while the
DMA pipeline is still ahead of compute — the TPU replacement for the paper's
precomputed (expert_id, token_offset) grid mapping.

Optional epilogue: per-row scale (the top-k combine weight) fused into the
down projection — possible here because Pallas epilogues are ordinary vector
code (the paper's Triton version could not, its Limitation 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(block_expert_ref, block_active_ref,   # scalar prefetch
            x_ref, w_ref, scale_ref,              # inputs (scale may be None)
            out_ref,                              # output
            acc_ref,                              # scratch
            *, n_k: int, has_scale: bool):
    m, _, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    active = block_active_ref[m] == 1

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(active)
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        if has_scale:
            acc = acc * scale_ref[...].astype(jnp.float32)
        out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "out_dtype"))
def grouped_gemm(x: jnp.ndarray, w: jnp.ndarray,
                 block_expert: jnp.ndarray, block_active: jnp.ndarray,
                 row_scale: jnp.ndarray | None = None, *,
                 block_m: int, block_n: int, block_k: int,
                 interpret: bool = False, out_dtype=None) -> jnp.ndarray:
    """x: (capacity, K) tile-aligned expert-contiguous; w: (E, K, N);
    block_expert/block_active: (capacity // block_m,);
    row_scale: optional (capacity,) fused epilogue scale -> (capacity, N)."""
    capacity, K = x.shape
    _, _, N = w.shape
    assert capacity % block_m == 0 and K % block_k == 0 and N % block_n == 0, (
        f"shape {(capacity, K, N)} not divisible by blocks "
        f"{(block_m, block_k, block_n)}")
    n_m, n_n, n_k = capacity // block_m, N // block_n, K // block_k
    has_scale = row_scale is not None

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda m, n, k, be, ba: (m, k)),
        pl.BlockSpec((1, block_k, block_n), lambda m, n, k, be, ba: (be[m], k, n)),
    ]
    operands = [x, w]
    if has_scale:
        in_specs.append(
            pl.BlockSpec((block_m, 1), lambda m, n, k, be, ba: (m, 0)))
        operands.append(row_scale.reshape(capacity, 1).astype(jnp.float32))
    else:
        in_specs.append(None)
        operands.append(None)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_m, n_n, n_k),
        in_specs=[s for s in in_specs if s is not None],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k, be, ba: (m, n)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )

    kernel = functools.partial(_kernel, n_k=n_k, has_scale=has_scale)
    if not has_scale:
        # adapt arity: drop the scale ref
        def kernel(be, ba, x_ref, w_ref, out_ref, acc_ref):  # noqa: F811
            _kernel(be, ba, x_ref, w_ref, None, out_ref, acc_ref,
                    n_k=n_k, has_scale=False)

    out_dtype = out_dtype or x.dtype
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((capacity, N), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    args = [block_expert, block_active, x, w]
    if has_scale:
        args.append(operands[2])
    return fn(*args)
