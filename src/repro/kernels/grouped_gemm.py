"""Block-scheduled grouped GEMM — the paper's §3.2, TPU-native.

One ``pallas_call`` computes ``out[block i] = x[block i] @ w[expert(i)]`` for
every M-tile in the tile-aligned expert-contiguous layout.  The schedule
(block->expert, block->active) is passed as scalar-prefetch operands so the
weight ``BlockSpec.index_map`` selects each block's expert weights while the
DMA pipeline is still ahead of compute — the TPU replacement for the paper's
precomputed (expert_id, token_offset) grid mapping.

Optional epilogue: per-row scale (the top-k combine weight) fused into the
down projection — possible here because Pallas epilogues are ordinary vector
code (the paper's Triton version could not, its Limitation 1).

Quantized weights (DESIGN.md §8): ``w_format`` selects in-kernel dequant of
each DMA'd weight block — ``"int8"`` (payload int8, per-(expert, channel)
``w_scale`` multiply in VREGs) or ``"int4"`` (two-nibbles-per-byte payload
packed along K; sign-extend + row-interleave + scale in VREGs).  Only the
compressed bytes ever cross HBM->VMEM; the dense expert stack exists one
block at a time, right before its MXU issue.  ``w_format="dense"`` is the
original kernel unchanged (bitwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.quantization.schemes import unpack_int4


def dequant_weight_block(wq, ws, w_format: str, dtype):
    """Expand one gathered weight block to ``dtype`` inside the kernel.

    wq: (bk, bn) dense, (bk, bn) int8, or (bk//2, bn) int8 nibble-packed;
    ws: (1, bn) f32 per-output-channel scales (None for dense).
    Uses the SAME unpack/scale primitives as the jnp schemes
    (repro.quantization.schemes), so the Pallas and xla executors produce
    bit-identical dequantized blocks.
    """
    if w_format == "dense":
        return wq
    if w_format == "int4":
        wq = unpack_int4(wq)
    return (wq.astype(jnp.float32) * ws).astype(dtype)


def _kernel(block_expert_ref, block_active_ref,   # scalar prefetch
            x_ref, w_ref, ws_ref, scale_ref,      # inputs (ws/scale opt.)
            out_ref,                              # output
            acc_ref,                              # scratch
            *, n_k: int, has_scale: bool, w_format: str):
    m, _, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    active = block_active_ref[m] == 1

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(active)
    def _accum():
        w = dequant_weight_block(
            w_ref[0], None if ws_ref is None else ws_ref[...],
            w_format, x_ref.dtype)
        acc_ref[...] += jnp.dot(x_ref[...], w,
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        if has_scale:
            acc = acc * scale_ref[...].astype(jnp.float32)
        out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret",
                     "out_dtype", "w_format"))
def grouped_gemm(x: jnp.ndarray, w: jnp.ndarray,
                 block_expert: jnp.ndarray, block_active: jnp.ndarray,
                 row_scale: jnp.ndarray | None = None,
                 w_scale: jnp.ndarray | None = None, *,
                 block_m: int, block_n: int, block_k: int,
                 w_format: str = "dense",
                 interpret: bool = False, out_dtype=None) -> jnp.ndarray:
    """x: (capacity, K) tile-aligned expert-contiguous; w: (E, K, N) dense
    or the scheme's packed payload ((E, K, N) int8 / (E, K//2, N) int8);
    w_scale: (E, N) f32 per-channel scales (required unless dense);
    block_expert/block_active: (capacity // block_m,);
    row_scale: optional (capacity,) fused epilogue scale -> (capacity, N).
    ``block_k`` is in LOGICAL K rows (the packed payload DMAs block_k//2)."""
    capacity, K = x.shape
    N = w.shape[-1]
    pack = 2 if w_format == "int4" else 1
    assert w.shape[1] * pack == K, (w.shape, K, w_format)
    assert (w_scale is not None) == (w_format != "dense"), w_format
    assert capacity % block_m == 0 and K % block_k == 0 and N % block_n == 0, (
        f"shape {(capacity, K, N)} not divisible by blocks "
        f"{(block_m, block_k, block_n)}")
    assert block_k % pack == 0, (block_k, w_format)
    n_m, n_n, n_k = capacity // block_m, N // block_n, K // block_k
    has_scale = row_scale is not None
    quant = w_format != "dense"

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda m, n, k, be, ba: (m, k)),
        pl.BlockSpec((1, block_k // pack, block_n),
                     lambda m, n, k, be, ba: (be[m], k, n)),
    ]
    operands = [x, w]
    if quant:
        in_specs.append(
            pl.BlockSpec((1, block_n), lambda m, n, k, be, ba: (be[m], n)))
        operands.append(w_scale.astype(jnp.float32))
    if has_scale:
        in_specs.append(
            pl.BlockSpec((block_m, 1), lambda m, n, k, be, ba: (m, 0)))
        operands.append(row_scale.reshape(capacity, 1).astype(jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_m, n_n, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda m, n, k, be, ba: (m, n)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )

    def kernel(be, ba, *refs):
        # refs: x, w, [w_scale], [row_scale], out, acc
        it = iter(refs)
        x_ref, w_ref = next(it), next(it)
        ws_ref = next(it) if quant else None
        scale_ref = next(it) if has_scale else None
        out_ref, acc_ref = next(it), next(it)
        _kernel(be, ba, x_ref, w_ref, ws_ref, scale_ref, out_ref, acc_ref,
                n_k=n_k, has_scale=has_scale, w_format=w_format)

    out_dtype = out_dtype or x.dtype
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((capacity, N), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return fn(block_expert, block_active, *operands)
