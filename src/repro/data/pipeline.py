"""Deterministic synthetic data pipeline.

Markov-chain token streams with a low-entropy transition structure so a
correct model visibly learns (loss drops well below ln(vocab)); generation
is a pure function of (seed, step) — any restart or re-shard reproduces the
exact same global batch, which the fault-tolerance tests rely on.

``make_global_batch`` materializes the batch host-side then ``device_put``s
against the requested sharding (the single-process analogue of per-host
sharded loading; each host would generate only its slice in a pod)."""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig


@functools.lru_cache(maxsize=8)
def _transition(vocab: int, seed: int, branch: int = 4) -> np.ndarray:
    """Each token can be followed by only `branch` tokens (uniformly)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(vocab, branch)).astype(np.int32)


def markov_tokens(vocab: int, batch: int, seq: int, *, step: int,
                  seed: int = 1234, branch: int = 4) -> np.ndarray:
    trans = _transition(vocab, seed, branch)
    rng = np.random.default_rng((seed, step))
    toks = np.empty((batch, seq), np.int32)
    cur = rng.integers(0, vocab, size=batch).astype(np.int32)
    toks[:, 0] = cur
    choices = rng.integers(0, branch, size=(batch, seq))
    for t in range(1, seq):
        cur = trans[cur, choices[:, t]]
        toks[:, t] = cur
    return toks


def make_batch(cfg: ModelConfig, batch: int, seq: int, *, step: int,
               accum: int = 1, seed: int = 1234) -> Dict[str, np.ndarray]:
    lead = (accum,) if accum > 1 else ()
    n = batch * accum
    rng = np.random.default_rng((seed, step, 7))
    if cfg.encoder_only:
        labels = markov_tokens(cfg.vocab_size, n, seq, step=step, seed=seed)
        feats = rng.normal(size=(n, seq, cfg.d_model)).astype(np.float32) \
            + 0.5 * np.eye(cfg.d_model)[labels % cfg.d_model]
        mask = rng.random((n, seq)) < 0.08
        out = {"features": feats.astype(np.float32),
               "labels": labels, "mask": mask}
    else:
        out = {"tokens": markov_tokens(cfg.vocab_size, n, seq, step=step,
                                       seed=seed)}
        if cfg.cross_attn_every:
            out["image_embeds"] = rng.normal(
                size=(n, cfg.n_image_tokens, cfg.d_model)
            ).astype(np.float32) * 0.3
    return {k: v.reshape(lead + (batch,) + v.shape[1:]) for k, v in
            out.items()}


def device_batch(batch: Dict[str, np.ndarray], shardings=None):
    if shardings is None:
        return {k: jax.device_put(v) for k, v in batch.items()}
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}
