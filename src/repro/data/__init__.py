"""repro.data subpackage."""
