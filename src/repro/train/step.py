"""Jitted training step: grad-accumulation microbatching + AdamW.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function.  With accum_steps > 1 the batch carries a leading microbatch axis
and gradients accumulate in fp32 through a ``lax.scan`` — the optimizer
update (and therefore the cross-pod gradient all-reduce that GSPMD places
around it) happens once per step, letting XLA overlap the reduction with
the last microbatch's backward."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import RunConfig, loss_fn
from repro.optim.adamw import OptConfig, apply_updates, init_opt_state


def init_train_state(cfg: ModelConfig, key, rc: RunConfig):
    from repro.models.lm import init_params
    params = init_params(cfg, key, rc.param_dtype)
    return {"params": params, "opt": init_opt_state(params)}


def make_train_step(cfg: ModelConfig, rc: RunConfig, opt: OptConfig,
                    accum_steps: int = 1, grad_shardings=None):
    """grad_shardings: optional NamedSharding tree matching params — pins
    the fp32 grad-accumulation carry to the parameter (FSDP) layout so the
    per-microbatch gradient reduction lowers as reduce-scatter into a
    SHARDED buffer instead of an all-reduce into a replicated one (2x link
    bytes + a full replicated fp32 copy of the gradients otherwise)."""
    def one_micro(params, mb):
        return loss_fn(params, cfg, rc, mb)

    grad_fn = jax.value_and_grad(one_micro, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def _pin(tree):
                if grad_shardings is None:
                    return tree
                return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                                    grad_shardings)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g_acc = _pin(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g))
                return (g_acc, l_acc + l), m

            g0 = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (g_sum, l_sum), ms = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), batch)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss = l_sum / accum_steps
            metrics = jax.tree.map(lambda m: m[-1], ms)
        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, state["opt"], opt)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
