"""Training loop: data -> jitted step -> metrics -> async checkpoints,
with straggler monitoring, failure injection hooks, and resume-on-restart
(optionally onto a different mesh — elastic)."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import device_batch, make_batch
from repro.models.lm import RunConfig
from repro.obs import NOOP
from repro.optim.adamw import OptConfig
from repro.runtime.fault import FailureInjector, StragglerMonitor
from repro.train.step import init_train_state, make_train_step


def train(cfg: ModelConfig, rc: RunConfig, opt: OptConfig, *,
          steps: int, batch: int, seq: int, accum: int = 1,
          ckpt_dir: Optional[str] = None, save_every: int = 20,
          mesh=None, state_shardings=None, batch_shardings=None,
          fail_at: Optional[int] = None, seed: int = 0,
          log_every: int = 10, log: Callable[[str], None] = print,
          obs=None) -> Dict:
    """Returns {"state", "history", "stragglers", "resumed_from"}.

    ``obs`` (repro.obs.Observability, default NOOP) adds the same
    step-timeline spans the serve engine emits — ``train/data`` /
    ``train/step`` / ``train/checkpoint`` — plus ``train/*`` metric
    observations at each logged step; the loop's own StragglerMonitor
    keeps driving the log line either way."""
    obs = obs or NOOP
    manager = CheckpointManager(ckpt_dir) if ckpt_dir else None
    injector = FailureInjector(fail_at)
    monitor = StragglerMonitor()

    step_fn = make_train_step(cfg, rc, opt, accum_steps=accum)
    if mesh is not None:
        step_fn = jax.jit(step_fn, in_shardings=(state_shardings,
                                                 batch_shardings),
                          out_shardings=(state_shardings, None))
    else:
        step_fn = jax.jit(step_fn)

    start = 0
    resumed_from = None
    abstract = jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.key(seed), rc))
    if manager is not None and manager.latest_step() is not None:
        state = manager.restore(abstract, shardings=state_shardings)
        start = manager.latest_step() + 1
        resumed_from = start - 1
        log(f"[train] resumed from step {resumed_from}")
    else:
        state = init_train_state(cfg, jax.random.key(seed), rc)
        if state_shardings is not None:
            state = jax.device_put(state, state_shardings)

    history = []
    try:
        for step in range(start, steps):
            monitor.start_step(step)
            obs.step_begin(step)
            injector.maybe_fail(step)
            with obs.tracer.span("train/data", step=step):
                b = make_batch(cfg, batch, seq, step=step, accum=accum,
                               seed=seed + 1)
                b = device_batch(b, batch_shardings)
            with obs.tracer.span("train/step", step=step):
                state, metrics = step_fn(state, b)
            flag = monitor.end_step()
            obs.step_end(step, scope="train")
            if flag:
                log(f"[straggler] step {flag['step']} "
                    f"{flag['slowdown']:.1f}x median")
            if step % log_every == 0 or step == steps - 1:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                history.append({"step": step, **m})
                if obs.enabled:
                    obs.metrics.inc("train/steps_logged")
                    obs.metrics.observe_many("train/", m)
                log(f"[train] step {step:5d} loss {m.get('loss', 0):.4f} "
                    f"ce {m.get('ce', 0):.4f} gnorm "
                    f"{m.get('grad_norm', 0):.3f}")
            if manager is not None and step % save_every == 0 and step > 0:
                with obs.tracer.span("train/checkpoint", step=step):
                    manager.save(step, state)
    finally:
        if manager is not None:
            manager.wait()
    if manager is not None:
        manager.save(steps - 1, state)
        manager.wait()
    return {"state": state, "history": history,
            "stragglers": monitor.flagged, "resumed_from": resumed_from}
