"""repro.train subpackage."""
