"""jax version compatibility shims.

The repo targets current jax; these adapters keep it running on the older
API surface too (containers pin different jax versions):

* ``jax.shard_map``            <-> ``jax.experimental.shard_map.shard_map``
  (``check_vma`` was ``check_rep``; both disabled — the EP bodies use
  collectives the replication checker cannot see through)
* ``jax.set_mesh(mesh)``       <-> ``with mesh:`` (Mesh is its own context
  manager on older jax)
* ``get_concrete_mesh()``      returns an empty tuple instead of None on
  some versions
* ``compiled.cost_analysis()`` returns a one-element list instead of a
  dict on older jax
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def set_mesh(mesh: Mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def current_mesh():
    """The ambient concrete mesh (set_mesh / `with mesh:`), or None."""
    from jax._src import mesh as mesh_lib
    get = getattr(mesh_lib, "get_concrete_mesh", None)
    m = get() if get is not None else None
    if isinstance(m, Mesh) and not getattr(m, "empty", False):
        return m
    m = mesh_lib.thread_resources.env.physical_mesh
    if isinstance(m, Mesh) and not getattr(m, "empty", False):
        return m
    return None


def axis_size(axis: str) -> int:
    """Size of a named mesh axis inside a shard_map/pmap body.

    ``jax.lax.axis_size`` is recent; ``psum(1, axis)`` is the classic
    idiom and is folded to a static int on every version.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: always a (possibly empty)
    dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
