"""Build the persistent kernel tune cache (DESIGN.md §12).

Sweeps every grouped-GEMM shape the paper configs dispatch
(``fused_gate_up`` at (d, f) and the down-projection ``grouped_gemm`` at
(f, d)) over the candidate tile grid and writes the winners to
``results/tuning/cache.json`` (override with ``--out`` /
``$REPRO_TUNE_CACHE``).  The default config is always in the candidate
set, so every written entry is measured >= the hard-coded default on the
same microbenchmark.

Off-TPU the Pallas kernels run interpreted: timings order the
interpreter, not the MXU, so the tool refuses to write a cache unless
``--force`` (CI smoke passes it; a real deployment builds on the TPU
host).  ``--reduce`` shrinks shapes for smoke runs.

Usage:
    PYTHONPATH=src python tools/build_tune_cache.py [--reduce] [--force]
        [--configs mixtral-8x7b ...] [--scheme dense|int8|int4]
        [--tokens 256] [--reps 3] [--out results/tuning/cache.json]
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.configs import PAPER_CONFIGS
from repro.kernels import ops
from repro.tuning import (TuneCache, local_cache_path, reset_cache,
                          tune_moe_layer)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--configs", nargs="*", default=sorted(PAPER_CONFIGS),
                    choices=sorted(PAPER_CONFIGS))
    ap.add_argument("--tokens", type=int, default=256,
                    help="routed tokens per sweep (M = bucket(tokens*k))")
    ap.add_argument("--scheme", default="dense",
                    choices=("dense", "int8", "int4"),
                    help="kernel-level weight format to tune for")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--reduce", action="store_true",
                    help="shrink d/f (divide by 16) for smoke runs")
    ap.add_argument("--force", action="store_true",
                    help="write the cache even off-TPU (interpret-mode "
                         "timings — CI smoke only)")
    ap.add_argument("--out", default=None,
                    help=f"cache path (default {local_cache_path()})")
    args = ap.parse_args(argv)

    if not ops.on_tpu() and not args.force:
        print("refusing to build a tune cache off-TPU (interpret-mode "
              "timings are not deployment-representative); pass --force "
              "for a smoke build", file=sys.stderr)
        return 2

    out_path = args.out or local_cache_path()
    cache = TuneCache.load(out_path) or TuneCache()
    import jax
    cache.device = jax.default_backend()
    shrink = 16 if args.reduce else 1
    for name in args.configs:
        pc = PAPER_CONFIGS[name]
        d = max(32, pc.d_model // shrink)
        f = max(32, pc.d_ffn // shrink)
        results = tune_moe_layer(
            E=pc.n_experts, top_k=pc.top_k, d_model=d, d_ffn=f,
            tokens=args.tokens, scheme=args.scheme, reps=args.reps,
            cache=cache)
        for res in results:
            w, dflt = res["winner"], res["default"]
            print(f"{name} {res['kernel']}: "
                  f"default ({dflt['block_m']},{dflt['block_n']},"
                  f"{dflt['block_k']}) {dflt['us']:.0f}us -> tuned "
                  f"({w['block_m']},{w['block_n']},{w['block_k']}) "
                  f"{w['us']:.0f}us [{res['key']}]")
    cache.save(out_path)
    reset_cache()        # next get_cache() in this process sees the file
    print(f"wrote {len(cache.entries)} entries -> {out_path}")
    print(json.dumps({"entries": len(cache.entries),
                      "device": cache.device}, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
