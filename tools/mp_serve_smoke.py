"""Multi-process serving smoke: 2-process ``jax.distributed`` launch with
a single-process forced-device-count fallback.

The real thing first: two subprocesses join a coordination group
(process 0 binds the coordinator) and run the distributed serving
launcher.  On backends without multi-process compute (CPU: the
coordination service and global device visibility work, but jit dispatch
across processes does not) the launcher exits with its documented
capability message — that counts as "coordination verified, compute
unsupported" and the smoke falls back to the single-process path the
ISSUE's CI job allows: one process, ``--ep-devices N`` forcing a
multi-device host mesh, same per-host admission + global-step code.

Either way the smoke FAILS unless a distributed serve run completes all
its requests.

Usage:
    PYTHONPATH=src python tools/mp_serve_smoke.py [--processes 2]
        [--port 12377]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

LAUNCH = [sys.executable, "-m", "repro.launch.serve",
          "--arch", "moonshot-v1-16b-a3b", "--reduce",
          "--requests", "3", "--max-new", "3", "--distributed"]


def _env():
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def try_multiprocess(n: int, port: int) -> bool:
    """True iff the n-process launch served its requests end to end."""
    procs = [subprocess.Popen(
        LAUNCH + ["--coordinator", f"localhost:{port}",
                  "--num-processes", str(n), "--process-id", str(i)],
        env=_env(), stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True) for i in range(n)]
    outs = []
    for p in procs:
        try:
            out, _ = p.communicate(timeout=420)
        except subprocess.TimeoutExpired:
            p.kill()
            out, _ = p.communicate()
        outs.append(out)
    ok = all(p.returncode == 0 for p in procs) \
        and "requests completed" in outs[0]
    unsupported = any("cannot run multi-process computations" in o
                      for o in outs)
    print(f"multi-process launch: "
          f"{'OK' if ok else 'unsupported' if unsupported else 'FAILED'}")
    if not ok and not unsupported:
        for i, o in enumerate(outs):
            print(f"--- process {i} output ---\n{o}")
    return ok


def single_process_fallback() -> None:
    out = subprocess.run(
        LAUNCH + ["--ep-devices", "2", "--hosts", "2"],
        env=_env(), stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, timeout=420)
    sys.stdout.write(out.stdout)
    assert out.returncode == 0, "fallback distributed serve launch failed"
    assert "3/3 requests completed" in out.stdout, \
        "distributed serve smoke did not complete all requests"
    print("single-process fallback (forced 2-device mesh, 2 host "
          "queues): OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--port", type=int, default=12377)
    args = ap.parse_args()
    if not try_multiprocess(args.processes, args.port):
        single_process_fallback()
    print("mp serve smoke OK")


if __name__ == "__main__":
    main()
