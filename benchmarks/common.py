"""Benchmark utilities: timing, CSV emission, synthetic routing.

CPU wall-clock numbers use the XLA dispatch implementation (the Pallas
kernels' interpret mode is a correctness tool, not a timing proxy).  Each
benchmark additionally *derives* TPU v5e latency projections from the
analytic roofline terms so every paper table has a structural counterpart
at the paper's true shapes.  CSV: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def zipf_assignments(key, T: int, k: int, E: int, alpha: float):
    """Synthetic expert assignments: uniform (alpha=0) or Zipfian (paper
    §4.7: alpha=1.2 ~ FasterMoE empirical; 2.0 stress).  Per-row budget
    T*k fixed; gating weights uniform 1/k (isolates load imbalance)."""
    if alpha <= 0:
        probs = jnp.ones((E,)) / E
    else:
        w = (jnp.arange(E, dtype=jnp.float32) + 1.0) ** (-alpha)
        probs = w / w.sum()
    idx = jax.random.choice(key, E, shape=(T, k), p=probs)
    weights = jnp.full((T, k), 1.0 / k, jnp.float32)
    return weights, idx.astype(jnp.int32)


def moe_flops(T: int, k: int, d: int, f: int) -> float:
    """Expert-FFN matmul FLOPs for T tokens (gate+up+down)."""
    return 2.0 * T * k * 3 * d * f


def moe_weight_bytes(E: int, d: int, f: int, bytes_per=2) -> float:
    return 3.0 * E * d * f * bytes_per


def tpu_projection(T: int, k: int, E: int, d: int, f: int,
                   *, fused: bool = True) -> float:
    """Analytic single-chip v5e latency for one MoE layer (paper Table 2
    analogue): max(compute, memory) with the §3.3 fused-vs-unfused
    activation-traffic difference."""
    fl = moe_flops(T, k, d, f)
    acts = T * k * (2 * d + (2 if fused else 10) * f) * 2.0
    wb = moe_weight_bytes(E, d, f)
    return max(fl / PEAK_FLOPS, (acts + wb) / HBM_BW)
