"""Paper Table 4: fusion ablation on Mixtral-8x7B at 512 tokens.

  (a) dense loop-over-experts oracle   (paper: PyTorch reference)
  (b) grouped GEMM, unfused gate/up    (paper: Triton unfused)
  (c) grouped GEMM, fused gate+up      (paper: Triton fused)

CPU wall times give the (a)->(b) structural speedup; the (b)->(c) gain is
HBM-traffic-bound on TPU, so we report both the measured CPU ratio and the
analytic activation-byte ratio at full Mixtral dims (paper: 1.15x).
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn, tpu_projection
from repro.configs.paper import PAPER_CONFIGS
from repro.core.dispatch import MoEDispatchConfig, moe_ffn

SCALE = 8
T = 512


def main():
    pc = PAPER_CONFIGS["mixtral-8x7b"]
    d, f = pc.d_model // SCALE, pc.d_ffn // SCALE
    E, k = pc.n_experts, pc.top_k
    ks = jax.random.split(jax.random.key(0), 5)
    wr = jax.random.normal(ks[0], (d, E)) * 0.1
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.1
    x = jax.random.normal(ks[4], (T, d))

    base = MoEDispatchConfig(n_experts=E, top_k=k, block_m=128, executor="xla")
    arms = {
        "a_dense_loop": base._replace(executor="dense"),
        "b_grouped_unfused": base._replace(fuse_gate_up=False,
                                           fold_combine=False),
        "c_grouped_fused": base,
    }
    times = {}
    for name, cfg in arms.items():
        fn = jax.jit(lambda x, c=cfg: moe_ffn(x, wr, wg, wu, wd, c)[0])
        times[name] = time_fn(fn, x)
        emit(f"fusion/{name}", times[name], f"T{T}_cpu_scaled_1_{SCALE}")
    emit("fusion/speedup_a_to_b", 0.0,
         f"{times['a_dense_loop'] / times['b_grouped_unfused']:.2f}x")
    emit("fusion/speedup_b_to_c", 0.0,
         f"{times['b_grouped_unfused'] / times['c_grouped_fused']:.2f}x")
    # analytic TPU (full dims): activation traffic unfused vs fused
    tu = tpu_projection(T, k, E, pc.d_model, pc.d_ffn, fused=False)
    tf = tpu_projection(T, k, E, pc.d_model, pc.d_ffn, fused=True)
    emit("fusion/tpu_proj_unfused", tu, "full_dims")
    emit("fusion/tpu_proj_fused", tf, f"paper_1.15x_ours_{tu / tf:.2f}x")


if __name__ == "__main__":
    main()
