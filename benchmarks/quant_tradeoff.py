"""Quantization trade-off sweep: scheme x executor -> decode tokens/sec,
gathered expert-weight bytes, and layer relative error (DESIGN.md §8).

MoE decode is gather-bound on expert weights, so a scheme's value is the
three-way trade this sweep records per (scheme, executor) cell:

* **gathered_bytes** — the per-layer expert-weight payload a decode step's
  weight gather actually moves (QuantTensor ``q``+``s`` leaf bytes; the
  dense baseline's full mats for ``none``).  int8 halves the fp32 layout's
  traffic twice over; int4 packs two nibbles per byte on top.
* **rel_error** — layer-output inf-norm relative error of the quantized
  dispatch vs the fp32 dense oracle on unquantized weights, checked
  against the scheme's *declared* ``rel_error_bound`` (the registry's
  accuracy contract; a scheme that breaks its own declaration fails the
  sweep, which is what CI's quant parity smoke runs).
* **tok_per_s** — steady-state batched decode throughput through
  `ServeEngine` (same methodology as benchmarks/serving_throughput.py:
  admit all slots, warm up, time lock-step decodes).

Records go to results/quant/<arch><suffix>.json.

    PYTHONPATH=src python -m benchmarks.quant_tradeoff [--smoke]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.core import apply_moe, dispatch_config, init_moe_params
from repro.execution import available_executors, get_executor
from repro.models import RunConfig, init_params
from repro.quantization import (QuantTensor, available_schemes, get_scheme,
                                quantize_moe_params)
from repro.serve.engine import Request, ServeEngine

PROMPT_LEN = 6


def layer_error(moe_cfg, d_model: int, *, scheme: str, executor: str,
                policy: str, seed: int = 0) -> float:
    """Inf-norm relative error of the quantized dispatch (one routed
    batch).  Quant schemes compare against the fp32 dense oracle on
    UNQUANTIZED weights; ``none`` compares the capability-contract path
    (apply_moe: expert_weights + supports_scheme + prepare_weights)
    against the raw pipeline called on the bare arrays, where its
    declared bound of 0.0 means *bitwise*."""
    from repro.core.dispatch import moe_ffn
    params = init_moe_params(jax.random.key(seed), moe_cfg, d_model)
    # quantization touches only the ROUTED mats; drop the dense shared
    # experts so the error cells measure the quantized path undiluted
    params.pop("shared", None)
    x = jax.random.normal(jax.random.key(seed + 1), (4, 32, d_model))
    cfg = dispatch_config(moe_cfg, executor=executor,
                          schedule_policy=policy)
    if scheme == "none":
        y_ref, _ = moe_ffn(x.reshape(-1, d_model), params["router"],
                           params["w_gate"], params["w_up"],
                           params["w_down"], cfg)
        y_ref = y_ref.reshape(x.shape)
        qp = params
    else:
        y_ref, _ = apply_moe(params, x, dispatch_config(moe_cfg,
                                                        executor="dense"))
        qp = quantize_moe_params(params, scheme)
    y_q, _ = apply_moe(qp, x, cfg)
    return float(jnp.max(jnp.abs(y_q.astype(jnp.float32)
                                 - y_ref.astype(jnp.float32)))
                 / jnp.max(jnp.abs(y_ref.astype(jnp.float32))))


def gathered_bytes(moe_cfg, d_model: int, scheme: str) -> int:
    """Stored bytes of ONE layer's routed expert mats under the scheme —
    what every decode step's expert-weight gather moves."""
    params = init_moe_params(jax.random.key(0), moe_cfg, d_model)
    qp = quantize_moe_params(params, scheme) if scheme != "none" else params
    total = 0
    for name in ("w_gate", "w_up", "w_down"):
        w = qp[name]
        total += w.nbytes if isinstance(w, QuantTensor) else int(w.nbytes)
    return total


def decode_throughput(cfg, params, *, scheme: str, executor: str,
                      slots: int, steps: int, capacity: int) -> float:
    rc = RunConfig(q_chunk=64, kv_chunk=64, executor=executor,
                   schedule_policy="dynamic", quant=scheme)
    eng = ServeEngine(cfg, params, slots=slots, capacity=capacity, rc=rc)
    rng = np.random.default_rng(0)
    for i in range(slots):
        eng.admit(Request(rid=i,
                          prompt=rng.integers(0, cfg.vocab_size,
                                              PROMPT_LEN).astype(np.int32),
                          max_new=capacity))        # never retires in-window
    for _ in range(2):                              # warmup: compile
        eng.step()
    t0 = time.perf_counter()
    for _ in range(steps):
        assert eng.step() == slots
    return slots * steps / (time.perf_counter() - t0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moonshot-v1-16b-a3b")
    ap.add_argument("--schemes", default=",".join(available_schemes()),
                    help="comma-separated quant schemes "
                         f"(registered: {','.join(available_schemes())})")
    ap.add_argument("--executors", default="xla,pallas",
                    help="comma-separated executor backends "
                         f"(registered: {','.join(available_executors())})")
    ap.add_argument("--policy", default="dynamic",
                    help="schedule policy for the error cells")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="CI parity sweep: none/int8_expert/int4_packed "
                         "on xla+pallas, 2 slots / 4 steps")
    ap.add_argument("--out", default="results/quant")
    args = ap.parse_args()

    schemes = args.schemes.split(",")
    executors = args.executors.split(",")
    slots, steps = args.slots, args.steps
    if args.smoke:
        schemes = ["none", "int8_expert", "int4_packed"]
        executors = ["xla", "pallas"]
        slots, steps = 2, 4

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.key(0))
    moe_cfg, d_model = cfg.moe, cfg.d_model
    print(f"# {args.arch} (reduced) — quant trade-off, "
          f"schemes={schemes} x executors={executors} "
          f"[policy={args.policy}, slots={slots}]")
    print("name,us_per_call,derived")

    records = []
    for scheme in schemes:
        bound = get_scheme(scheme).rel_error_bound
        gbytes = gathered_bytes(moe_cfg, d_model, scheme)
        for executor in executors:
            if not get_executor(executor).supports_scheme(scheme):
                print(f"# skip {scheme} on {executor}: unsupported")
                continue
            rel = layer_error(moe_cfg, d_model, scheme=scheme,
                              executor=executor, policy=args.policy)
            assert rel <= bound, \
                (f"{scheme} on {executor}: rel error {rel:.4f} exceeds "
                 f"the scheme's declared bound {bound}")
            tps = decode_throughput(cfg, params, scheme=scheme,
                                    executor=executor, slots=slots,
                                    steps=steps, capacity=args.capacity)
            emit(f"quant_{scheme}_{executor}", 1.0 / tps,
                 f"tok_per_s={tps:.1f} bytes={gbytes} rel={rel:.4f}")
            records.append({"scheme": scheme, "executor": executor,
                            "policy": args.policy, "slots": slots,
                            "steps": steps, "bits": get_scheme(scheme).bits,
                            "gathered_bytes_per_layer": gbytes,
                            "rel_error": rel, "rel_error_bound": bound,
                            "tok_per_s": tps})

    by_scheme = {r["scheme"]: r for r in records}
    if "int8_expert" in by_scheme and "none" in by_scheme:
        assert by_scheme["int8_expert"]["gathered_bytes_per_layer"] \
            < by_scheme["none"]["gathered_bytes_per_layer"]
    if "int4_packed" in by_scheme and "int8_expert" in by_scheme:
        assert by_scheme["int4_packed"]["gathered_bytes_per_layer"] \
            < by_scheme["int8_expert"]["gathered_bytes_per_layer"]

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "_smoke" if args.smoke else ""
    out_path = out_dir / f"{args.arch}{suffix}.json"
    out_path.write_text(json.dumps({"arch": args.arch, "reduced": True,
                                    "records": records}, indent=1))
    print(f"# wrote {out_path}")
    for r in records:
        print(f"# {r['scheme']:>12s} @ {r['executor']:<6s} "
              f"{r['gathered_bytes_per_layer']:>9d} B/layer  "
              f"rel {r['rel_error']:.4f} (bound {r['rel_error_bound']})  "
              f"{r['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
