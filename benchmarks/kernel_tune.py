"""Kernel-tuning sweep: default vs tuned block configs per paper config.

Runs the autotuner's microbenchmark sweep (repro.tuning) for every
grouped-GEMM shape each paper MoE config dispatches, ASSERTS the
no-regression contract — the swept winner's throughput is >= the
default config's on the same measurement for EVERY cell (the default is
always a candidate, so a regression here means the sweep machinery
itself broke) — and records the table ``analysis/report.py`` renders.

Also records the fused-paged-attention arm per config when present in
``results/serve/*_smoke.json`` (serving_throughput writes those cells);
this file's own records are kernel-level.

Records -> results/tuning/<name><suffix>.json, and (with ``--write-cache``)
the winners overlay into results/tuning/cache.json.

    PYTHONPATH=src python -m benchmarks.kernel_tune --smoke
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import emit
from repro.configs import PAPER_CONFIGS
from repro.tuning import TuneCache, local_cache_path, reset_cache, \
    tune_moe_layer


def run_config(name: str, *, shrink: int, tokens: int, reps: int,
               scheme: str, cache) -> list:
    pc = PAPER_CONFIGS[name]
    d = max(32, pc.d_model // shrink)
    f = max(32, pc.d_ffn // shrink)
    rows = []
    for res in tune_moe_layer(E=pc.n_experts, top_k=pc.top_k, d_model=d,
                              d_ffn=f, tokens=tokens, scheme=scheme,
                              reps=reps, cache=cache):
        w, dflt = res["winner"], res["default"]
        # the no-regression acceptance criterion: tuned >= default tok/s
        # on every (config, kernel) cell, measured not assumed
        assert w["tok_per_s"] >= dflt["tok_per_s"], (name, res)
        row = {"config": name, "kernel": res["kernel"], "key": res["key"],
               "shape": res["shape"],
               "default": {k: dflt[k] for k in
                           ("block_m", "block_n", "block_k", "us",
                            "tok_per_s")},
               "tuned": {k: w[k] for k in
                         ("block_m", "block_n", "block_k", "us",
                          "tok_per_s")},
               "speedup": dflt["us"] / w["us"],
               "n_candidates": len(res["records"])}
        rows.append(row)
        emit(f"tune/{name}/{res['kernel']}", w["us"] * 1e-6,
             f"default {dflt['us']:.0f}us x{row['speedup']:.2f}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes + 1 rep (CI)")
    ap.add_argument("--configs", nargs="*", default=sorted(PAPER_CONFIGS),
                    choices=sorted(PAPER_CONFIGS))
    ap.add_argument("--tokens", type=int, default=256)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--scheme", default="dense",
                    choices=("dense", "int8", "int4"))
    ap.add_argument("--write-cache", action="store_true",
                    help="persist winners into the local tune cache")
    ap.add_argument("--out", default="results/tuning")
    args = ap.parse_args()

    shrink = 32 if args.smoke else 1
    reps = 1 if args.smoke else args.reps
    cache = TuneCache() if not args.write_cache else (
        TuneCache.load(local_cache_path()) or TuneCache())
    rows = []
    for name in args.configs:
        rows.extend(run_config(name, shrink=shrink, tokens=args.tokens,
                               reps=reps, scheme=args.scheme, cache=cache))
    assert rows, "no cells swept"
    assert all(r["tuned"]["tok_per_s"] >= r["default"]["tok_per_s"]
               for r in rows)        # no regression cell, re-checked flat

    if args.write_cache:
        cache.save(local_cache_path())
        reset_cache()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "_smoke" if args.smoke else ""
    doc = {"suffix": suffix, "scheme": args.scheme, "tokens": args.tokens,
           "reps": reps, "reduced": shrink > 1, "records": rows}
    path = out_dir / f"kernel_tune{suffix}.json"
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    print(f"wrote {len(rows)} cells -> {path}")


if __name__ == "__main__":
    main()
