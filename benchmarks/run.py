"""Benchmark driver — one module per paper table (see DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV.  CPU-measured arms use
width-scaled dims (structure-exact dispatch); ``*/tpu_proj`` and ``*/v5e``
arms are analytic v5e projections at the paper's full dimensions.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (e2e_latency, expert_scaling, fusion_ablation,
                            skew_sensitivity, stage_roofline)
    mods = [("e2e_latency", e2e_latency), ("fusion_ablation", fusion_ablation),
            ("expert_scaling", expert_scaling),
            ("stage_roofline", stage_roofline),
            ("skew_sensitivity", skew_sensitivity)]
    print("name,us_per_call,derived")
    for name, mod in mods:
        t0 = time.time()
        mod.main()
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
