"""Goodput under SLO: trace-driven open-stream load on the serving
front-end, ``fcfs`` vs ``slo`` admission across MoE-Inference-Bench-style
arrival patterns (DESIGN.md §11).

Every cell replays one seeded trace (repro.serve.loadgen.synth_trace)
through a fresh ServingFrontend on VIRTUAL time — one engine step
advances the injected clock by a fixed ``STEP_TIME`` — so goodput,
preemption counts and TTFT/TPOT percentiles are a pure function of
(seed, config) and the non-smoke assertions below are CI-stable:

* burst workload: ``slo`` admission achieves STRICTLY higher
  goodput-under-SLO than ``fcfs`` at the same offered load, with
  preemptions > 0 recorded (long-prefill burst members get parked for
  feasible short ones — paged preemption is a host-side table park);
* token identity: per-request outputs are bitwise-identical across
  admission policies whenever both runs complete the trace (admission
  reorders WHO decodes when, never WHAT a request decodes).

Records go to results/serve/loadgen_<arch><suffix>.json;
``analysis/report.py`` renders the goodput table.

    PYTHONPATH=src python -m benchmarks.serve_loadgen [--smoke]
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.execution import available_executors
from repro.models import RunConfig, init_params
from repro.serve.engine import ServeEngine
from repro.serve.loadgen import PATTERNS, make_virtual_obs, replay, synth_trace
from repro.spec import SpecEngine, make_draft_config

STEP_TIME = 0.05        # virtual seconds per engine step
RATE = 8.0              # offered load, requests per virtual second
SLO_TTFT = 0.4          # per-request deadlines carried on the trace
SLO_TPOT = 0.2

# pattern-specific trace shape: burst carries long-prefill members (the
# preemption workload — a parked long prefill frees the slot for a
# feasible short one), longtail mixes 48-token head-of-line blockers
TRACE_KW = {
    "poisson": {},
    "burst": dict(burst_size=6, prompt_hi=40),
    "shared_prefix": dict(burst_size=6, prefix_len=16),
    "longtail": dict(tail_len=48, tail_frac=0.25),
}


def run_cell(cfg, params, *, pattern: str, admission: str, executor: str,
             n: int, seed: int, max_steps: int, calibrate: bool = False,
             spec_k: int = 0, draft=None) -> dict:
    trace = synth_trace(pattern, seed=seed, n=n, rate=RATE,
                        vocab=cfg.vocab_size, max_new=6,
                        slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT,
                        **TRACE_KW[pattern])
    clock, obs = make_virtual_obs(enabled=True)
    rc = RunConfig(q_chunk=16, kv_chunk=16, executor=executor,
                   schedule_policy="dynamic", moe_stats=False)
    kw = dict(slots=2, capacity=64, rc=rc, kv_block_size=4,
              prefill_chunk=4, admission=admission, obs=obs)
    if spec_k > 0:
        # speculative serving cell: engine.describe() records spec_k /
        # spec_draft in the artifact config block, so goodput-under-SLO
        # is comparable with and without speculation
        dcfg, dparams = draft
        eng = SpecEngine(cfg, params, draft_cfg=dcfg, draft_params=dparams,
                         spec_k=spec_k, **kw)
    else:
        eng = ServeEngine(cfg, params, **kw)
    rec = replay(eng, trace, clock=clock,
                 step_time=None if calibrate else STEP_TIME, seed=seed,
                 pattern=pattern, max_steps=max_steps)
    emit(f"loadgen_{pattern}_{admission}",
         rec["steps"] * (rec["step_time_s"] or 0.0),
         f"goodput_rps={rec['goodput_rps']:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moonshot-v1-16b-a3b")
    ap.add_argument("--executor", default="xla",
                    choices=available_executors())
    ap.add_argument("--patterns", default=",".join(PATTERNS),
                    help="comma-separated trace patterns to replay")
    ap.add_argument("--n", type=int, default=24,
                    help="requests per trace")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spec-k", type=int, default=0,
                    help="run every cell on the speculative engine with "
                         "this many draft tokens per round (0 = off); "
                         "recorded in the artifact config block so "
                         "goodput is comparable with/without speculation")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI: burst pattern only, 12 "
                         "requests, no goodput-ordering assertion")
    ap.add_argument("--calibrate", action="store_true",
                    help="scale the virtual step by the measured step "
                         "wall-time EWMA instead of the fixed STEP_TIME "
                         "(host-dependent numbers; skips the CI-stable "
                         "goodput-ordering assertion)")
    ap.add_argument("--out", default="results/serve",
                    help="output dir for the JSON record")
    args = ap.parse_args()

    patterns = args.patterns.split(",")
    n = args.n
    if args.smoke:
        patterns, n = ["burst"], 12

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.key(0))
    draft = None
    if args.spec_k > 0:
        dcfg = make_draft_config(cfg, reduce=True, layers=1, d_model=32)
        draft = (dcfg, init_params(dcfg, jax.random.key(1)))
    print(f"# {args.arch} (reduced) — open-stream loadgen, "
          f"patterns={patterns} x admission=[fcfs, slo] "
          f"[executor={args.executor}, virtual step={STEP_TIME}s, "
          f"rate={RATE} req/s, SLO ttft={SLO_TTFT}s tpot={SLO_TPOT}s]")
    print("name,us_per_call,derived")

    records = []
    for pattern in patterns:
        cells = {}
        for admission in ("fcfs", "slo"):
            rec = run_cell(cfg, params, pattern=pattern,
                           admission=admission, executor=args.executor,
                           n=n, seed=args.seed,
                           max_steps=1024 if args.smoke else 4096,
                           calibrate=args.calibrate,
                           spec_k=args.spec_k, draft=draft)
            cells[admission] = rec
            records.append(rec)
        f, s = cells["fcfs"], cells["slo"]
        # admission reorders who decodes when, never what: outputs must
        # match per-request whenever both policies completed the trace
        if f["completed"] == n and s["completed"] == n:
            assert f["outputs"] == s["outputs"], \
                f"{pattern}: outputs differ across admission policies"
        print(f"# {pattern}: goodput {f['goodput_rps']:.3f} (fcfs) vs "
              f"{s['goodput_rps']:.3f} (slo) req/s; attainment "
              f"{f['slo_attainment']:.2f} -> {s['slo_attainment']:.2f}; "
              f"preempted {s['preempted']}, resumed {s['resumed']}")
        # the goodput ordering is only CI-stable on the fixed virtual
        # timeline; calibrated runs race the host scheduler by design
        if not args.smoke and not args.calibrate and pattern == "burst":
            assert s["goodput_rps"] > f["goodput_rps"], \
                (f"slo admission must beat fcfs goodput on the burst "
                 f"workload: {s['goodput_rps']:.3f} <= "
                 f"{f['goodput_rps']:.3f}")
            assert s["preempted"] > 0, \
                "burst/slo cell recorded no preemptions"

    for rec in records:
        rec.pop("outputs", None)        # artifact stays small + diffable
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "_smoke" if args.smoke else ""
    out_path = out_dir / f"loadgen_{args.arch}{suffix}.json"
    out_path.write_text(json.dumps(
        {"arch": args.arch, "reduced": True, "virtual_time": True,
         "step_time_mode": "calibrated" if args.calibrate else "fixed",
         "step_time_s": None if args.calibrate else STEP_TIME,
         "rate_rps": RATE,
         "slo": {"ttft_s": SLO_TTFT, "tpot_s": SLO_TPOT},
         "records": records}, indent=1))
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()
