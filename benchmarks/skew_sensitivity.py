"""Paper §4.7 / Figures 2-3: sensitivity to routing imbalance.

Methodology mirrors the paper: the router output is replaced by synthetic
assignments (uniform, Zipf alpha=1.2, alpha=2.0) with uniform 1/k gating
weights; the total per-row budget T*k is held fixed.  We report:

  * measured CPU latency of the dispatch pipeline per distribution
    (the paper's fixed-BLOCK_M latency stays ~flat under skew — ours
    structurally matches: capacity blocks depend on counts, not identity);
  * the tile-padding waste of the fixed-BLOCK_M schedule (padded rows /
    useful rows) — the mechanism behind the paper's Qwen2-MoE regression;
  * EP capacity-overflow drop fraction at capacity_factor 1.25 and 2.0 —
    the distributed-dispatch analogue of skew sensitivity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, zipf_assignments
from repro.configs.paper import PAPER_CONFIGS
from repro.core.dispatch import (MoEDispatchConfig, combine_scale_rows,
                                 fused_gate_up_xla, grouped_gemm_xla)
from repro.core.schedule import build_schedule, round_up
from repro.kernels import ref

SCALE = 8
T = 512
ALPHAS = {"uniform": 0.0, "zipf1.2": 1.2, "zipf2.0": 2.0}


def run_config(name: str):
    pc = PAPER_CONFIGS[name]
    d, f = pc.d_model // SCALE, max(pc.d_ffn // SCALE, 8)
    E, k = pc.n_experts, pc.top_k
    ks = jax.random.split(jax.random.key(1), 5)
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.1
    x = jax.random.normal(ks[4], (T, d))
    block_m = min(128, max(8, T * k // E))

    for dist, alpha in ALPHAS.items():
        w, idx = zipf_assignments(jax.random.key(7), T, k, E, alpha)

        def pipeline(x, idx=idx, w=w):
            sched = build_schedule(idx, E, block_m)
            xp = ref.permute_ref(x, sched)
            h = fused_gate_up_xla(xp, wg, wu, sched)
            y = grouped_gemm_xla(h, wd, sched,
                                 row_scale=combine_scale_rows(sched, w))
            return ref.unpermute_ref(y, sched, None)

        t = time_fn(jax.jit(pipeline), x)

        counts = np.bincount(np.asarray(idx).reshape(-1), minlength=E)
        padded = ((counts + block_m - 1) // block_m * block_m).sum()
        waste = padded / max(counts.sum(), 1)
        top1 = counts.max() / max(counts.sum(), 1)

        drops = {}
        for cf in (1.25, 2.0):
            cap = round_up(max(1, int(T * k * cf / E)), block_m)
            drops[cf] = float(np.maximum(counts - cap, 0).sum()
                              / max(counts.sum(), 1))
        emit(f"skew/{name}/{dist}", t,
             f"M{block_m};pad_waste={waste:.2f}x;top1_share={top1:.1%};"
             f"drop@1.25={drops[1.25]:.1%};drop@2.0={drops[2.0]:.1%}")


def main():
    for name in ("mixtral-8x7b", "mixtral-8x22b", "qwen2-moe-57b",
                 "deepseek-v3"):
        run_config(name)


if __name__ == "__main__":
    main()
