"""Paper §4.7 / Figures 2-3: sensitivity to routing imbalance — extended to
a head-to-head sweep of the schedule policies (fixed / capacity_factor /
dynamic; repro.scheduling, DESIGN.md §3) on any registered executor backend
(repro.execution, DESIGN.md §6).

Methodology mirrors the paper: the router output is replaced by synthetic
assignments (uniform, Zipf alpha=1.2, alpha=2.0) with uniform 1/k gating
weights; the total per-row budget T*k is held fixed.  For every (config,
distribution, policy) cell we report:

  * measured CPU latency of the dispatch pipeline (the paper's fixed-BLOCK_M
    latency stays ~flat under skew; ``dynamic`` trades finer blocks for
    fewer padded rows — the TPU-relevant quantity is padded rows, i.e.
    tiles launched);
  * the policy's ScheduleStats: padding waste (padded/useful rows — the
    mechanism behind the paper's Qwen2-MoE regression), block occupancy,
    drop fraction, and top-1 expert share.

The pipeline runs through the executor's phase methods (permute ->
expert_ffn -> unpermute), so ``--executor pallas`` measures the kernel path
(interpret mode off-TPU) on exactly the same schedules as ``xla``.

Records are also dumped to results/sched/*.json for analysis/report.py.

    PYTHONPATH=src python -m benchmarks.skew_sensitivity [--smoke]
    PYTHONPATH=src python -m benchmarks.skew_sensitivity --smoke \\
        --executor pallas
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax
import numpy as np

from benchmarks.common import emit, time_fn, zipf_assignments
from repro.configs.paper import PAPER_CONFIGS
from repro.core.dispatch import MoEDispatchConfig
from repro.execution import (available_executors, combine_scale_rows,
                             get_executor)
from repro.scheduling import (DEFAULT_POLICY_SWEEP, build_schedule,
                              schedule_stats)

SCALE = 8
ALPHAS = {"uniform": 0.0, "zipf1.2": 1.2, "zipf2.0": 2.0}
POLICIES = DEFAULT_POLICY_SWEEP


def run_config(name: str, n_tokens: int, records: list,
               executor: str = "xla"):
    pc = PAPER_CONFIGS[name]
    d, f = pc.d_model // SCALE, max(pc.d_ffn // SCALE, 8)
    E, k, T = pc.n_experts, pc.top_k, n_tokens
    ks = jax.random.split(jax.random.key(1), 5)
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.1
    x = jax.random.normal(ks[4], (T, d))
    block_m = min(128, max(8, T * k // E))
    ex = get_executor(executor)
    weights = {"w_gate": wg, "w_up": wu, "w_down": wd}

    for dist, alpha in ALPHAS.items():
        w, idx = zipf_assignments(jax.random.key(7), T, k, E, alpha)

        for policy, kw in POLICIES:
            cfg = MoEDispatchConfig(n_experts=E, top_k=k, block_m=block_m,
                                    executor=executor,
                                    schedule_policy=policy)

            def pipeline(x, idx=idx, w=w, policy=policy, kw=kw, cfg=cfg):
                sched = build_schedule(idx, E, block_m, policy=policy, **kw)
                xp = ex.permute(x, sched, cfg)
                y = ex.expert_ffn(xp, weights, sched, cfg,
                                  row_scale=combine_scale_rows(sched, w))
                return ex.unpermute(y, sched, None, cfg)

            t = time_fn(jax.jit(pipeline), x)
            st = schedule_stats(build_schedule(idx, E, block_m,
                                               policy=policy, **kw))
            rec = {
                "config": name, "dist": dist, "policy": policy,
                "executor": executor,
                "n_tokens": T, "n_experts": E, "top_k": k,
                "block_m": block_m, "us": t * 1e6,
                "pad_waste": float(st.pad_waste),
                "occupancy": float(st.occupancy),
                "drop_fraction": float(st.drop_fraction),
                "top1_share": float(st.top1_share),
                "n_blocks_active": int(st.n_blocks_active),
            }
            records.append(rec)
            emit(f"skew/{name}/{dist}/{policy}[{executor}]", t,
                 f"M{block_m};pad_waste={rec['pad_waste']:.2f}x;"
                 f"occ={rec['occupancy']:.1%};"
                 f"drop={rec['drop_fraction']:.1%};"
                 f"top1_share={rec['top1_share']:.1%}")


def main(argv=None):
    schedule_capable = [n for n in available_executors()
                        if get_executor(n).needs_schedule]
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--configs", nargs="*", choices=sorted(PAPER_CONFIGS),
                    default=["mixtral-8x7b", "mixtral-8x22b",
                             "qwen2-moe-57b", "deepseek-v3"])
    ap.add_argument("--tokens", type=int, default=512)
    ap.add_argument("--executor", default="xla", choices=schedule_capable,
                    help="backend whose phase methods run the pipeline "
                         "(schedule-free executors such as 'dense' have "
                         "no permuted layout to measure)")
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny config (CI): mixtral-8x7b at 64 tokens")
    ap.add_argument("--out", default="results/sched",
                    help="directory for per-config JSON records")
    args = ap.parse_args(argv)
    if args.smoke:
        args.configs, args.tokens = ["mixtral-8x7b"], 64

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    for name in args.configs:
        records: list = []
        run_config(name, args.tokens, records, executor=args.executor)
        suffix = "" if args.executor == "xla" else f".{args.executor}"
        (out_dir / f"{name}{suffix}.json").write_text(
            json.dumps(records, indent=1))

        # sanity echoed for the acceptance criterion: dynamic never pads
        # more than fixed
        for dist in ALPHAS:
            by = {r["policy"]: r for r in records if r["dist"] == dist}
            assert by["dynamic"]["pad_waste"] <= by["fixed"]["pad_waste"] \
                + 1e-6, (name, dist)


if __name__ == "__main__":
    main()
