"""Paper Table 5: expert-scaling analysis at 512 tokens (E = 8 -> 256,
d_ffn adjusted for ~constant total compute).

Reports CPU tokens/s for the dispatch pipeline plus the analytic v5e
TFLOPS utilization — reproducing the paper's cliff at 64+ experts, where
per-expert batches shrink below a tile and weight loading dominates."""
from __future__ import annotations

import jax

from benchmarks.common import (emit, moe_flops, moe_weight_bytes, time_fn,
                               HBM_BW, PEAK_FLOPS)
from repro.configs.paper import EXPERT_SCALING
from repro.core.dispatch import MoEDispatchConfig, moe_ffn

SCALE = 8
T = 512
D_MODEL = 4096


def main():
    d = D_MODEL // SCALE
    for E, k, d_ffn in EXPERT_SCALING:
        f = max(d_ffn // SCALE, 8)
        ks = jax.random.split(jax.random.key(E), 5)
        wr = jax.random.normal(ks[0], (d, E)) * 0.1
        wg = jax.random.normal(ks[1], (E, d, f)) * 0.1
        wu = jax.random.normal(ks[2], (E, d, f)) * 0.1
        wd = jax.random.normal(ks[3], (E, f, d)) * 0.1
        x = jax.random.normal(ks[4], (T, d))
        block_m = min(128, max(8, T * k // E))
        cfg = MoEDispatchConfig(n_experts=E, top_k=k, block_m=block_m,
                                executor="xla")
        t = time_fn(jax.jit(lambda x: moe_ffn(x, wr, wg, wu, wd, cfg)[0]), x)
        # analytic v5e TFLOPS at FULL dims: weight loading vs compute
        fl = moe_flops(T, k, D_MODEL, d_ffn)
        wb = moe_weight_bytes(E, D_MODEL, d_ffn)
        acts = T * k * (2 * D_MODEL + 2 * d_ffn) * 2.0
        t_proj = max(fl / PEAK_FLOPS, (wb + acts) / HBM_BW)
        tflops = fl / t_proj / 1e12
        emit(f"scaling/E{E}_k{k}_f{d_ffn}", t,
             f"tok_per_s={T / t:.0f};v5e_TFLOPS={tflops:.1f};"
             f"tok_per_expert={T * k / E:.1f}")


if __name__ == "__main__":
    main()
