"""Decode throughput of the batched serving engine: active-slot count x
schedule policy, plus a mixed prefill/decode shared-prefix workload
comparing the paged cache (prefix caching + chunked prefill, DESIGN.md §9)
against the contiguous pre-paging engine.

The paper's throughput claim is that MoE wins come from batching tokens
into one fused dispatch; at serve time the decode batch IS the set of
active slots, so this sweep measures exactly that lever: every step is one
jitted forward over the (slots, capacity) cache — one DispatchPlan per MoE
layer covering all slots — and tokens/sec is slots * steps / wall.  More
active slots amortize both the per-step dispatch overhead and the expert
weight traffic (the dominant decode cost), so decode throughput should
rise with slot count; the fixed-vs-dynamic policy axis shows what schedule
construction costs on realistic decode batches.

Steady-state methodology: all slots are admitted up front (max_new large
enough that nothing retires inside the timed window), two warmup steps
absorb compilation, then ``--steps`` lock-step decodes are timed.

Records go to results/serve/<arch><suffix>.json (CSV on stdout follows
benchmarks/common emit conventions).

    PYTHONPATH=src python -m benchmarks.serving_throughput [--smoke]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.execution import available_executors
from repro.models import RunConfig, init_params
from repro.obs import latency_summary
from repro.quantization import available_schemes
from repro.scheduling import available_policies
from repro.serve.engine import Request, ServeEngine

PROMPT_LEN = 6


def run_cell(cfg, params, *, slots: int, policy: str, executor: str,
             steps: int, capacity: int, quant: str = "none",
             kv_block_size=None) -> dict:
    rc = RunConfig(q_chunk=64, kv_chunk=64, executor=executor,
                   schedule_policy=policy, quant=quant, moe_stats=False)
    eng = ServeEngine(cfg, params, slots=slots, capacity=capacity, rc=rc,
                      kv_block_size=kv_block_size)
    rng = np.random.default_rng(0)
    for i in range(slots):
        eng.admit(Request(rid=i,
                          prompt=rng.integers(0, cfg.vocab_size,
                                              PROMPT_LEN).astype(np.int32),
                          max_new=capacity))        # never retires in-window
    assert eng.n_active == slots
    for _ in range(2):                               # warmup: compile + cache
        eng.step()
    t0 = time.perf_counter()
    for _ in range(steps):
        n = eng.step()
        assert n == slots
    dt = time.perf_counter() - t0
    s_per_step = dt / steps
    tok_per_s = slots * steps / dt
    emit(f"serve_{policy}_slots{slots}", s_per_step,
         f"tok_per_s={tok_per_s:.1f}")
    return {"slots": slots, "policy": policy, "executor": executor,
            "quant": quant, "steps": steps, "s_per_step": s_per_step,
            "tok_per_s": tok_per_s, "kv_block": eng.kv_block_size,
            "kv_stats": eng.kv.stats() if eng.paged else None,
            "config": eng.describe(seed=0)}


# ----------------------------------------------------------------------
# Fused vs gathered paged-attention decode (DESIGN.md §12 acceptance)
# ----------------------------------------------------------------------
def run_paged_attn_compare(cfg, params, *, slots: int, steps: int,
                           capacity: int, kv_block: int) -> list:
    """Steady-state decode at ``slots`` active slots on the pallas
    executor, ``paged_attn`` fused vs gather.  Greedy tokens must be
    bitwise-identical (the fused kernel is a bit-for-bit companion of
    gather+flash); the throughput win is asserted on wall time on TPU
    and on the analytic HBM traffic everywhere (interpret-mode wall time
    orders the interpreter, not the memory system):

    * gather: reads the pool to materialize the (B, nb*bs, H, D) view,
      writes that view, and flash reads it back — 3x the KV bytes;
    * fused: the kernel DMAs each block-table-indexed tile exactly once.
    """
    from repro.kernels import ops
    cells = {}
    for mode in ("gather", "fused"):
        rc = RunConfig(q_chunk=64, kv_chunk=64, executor="pallas",
                       schedule_policy="dynamic", moe_stats=False,
                       paged_attn=mode)
        eng = ServeEngine(cfg, params, slots=slots, capacity=capacity,
                          rc=rc, kv_block_size=kv_block)
        assert eng.paged, "fused-vs-gather compare needs the paged cache"
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            PROMPT_LEN).astype(np.int32),
                        max_new=capacity)         # never retires in-window
                for i in range(slots)]
        for r in reqs:
            eng.admit(r)
        assert eng.n_active == slots
        for _ in range(2):                        # warmup: compile + cache
            eng.step()
        t0 = time.perf_counter()
        for _ in range(steps):
            assert eng.step() == slots
        dt = time.perf_counter() - t0
        # per-step KV traffic of the attention read path: with all slots
        # active the gathered (B, nb*bs, ...) view IS the pool's extent
        pool_bytes = sum(leaf.nbytes
                         for leaf in jax.tree_util.tree_leaves(eng.kv.pools))
        kv_bytes = pool_bytes * (3 if mode == "gather" else 1)
        tok_per_s = slots * steps / dt
        emit(f"paged_attn_{mode}_slots{slots}", dt / steps,
             f"tok_per_s={tok_per_s:.1f}")
        cells[mode] = {"paged_attn": mode, "slots": slots, "steps": steps,
                       "s_per_step": dt / steps, "tok_per_s": tok_per_s,
                       "kv_bytes_per_step": kv_bytes,
                       "kv_block": eng.kv_block_size, "on_tpu": ops.on_tpu(),
                       "outputs": {r.rid: list(r.out) for r in reqs},
                       "config": eng.describe(seed=0)}
    fused, gather = cells["fused"], cells["gather"]
    # the fused kernel must not change a single sampled token
    assert fused["outputs"] == gather["outputs"], \
        "fused paged attention changed greedy decode tokens"
    assert fused["kv_bytes_per_step"] < gather["kv_bytes_per_step"]
    fused["kv_bytes_win"] = gather["kv_bytes_win"] = \
        gather["kv_bytes_per_step"] / fused["kv_bytes_per_step"]
    if ops.on_tpu():
        assert fused["tok_per_s"] > gather["tok_per_s"], \
            (f"fused paged decode slower than gather on TPU: "
             f"{fused['tok_per_s']:.1f} <= {gather['tok_per_s']:.1f} tok/s")
    print(f"# paged-attn decode @ {slots} slots: "
          f"{gather['tok_per_s']:.1f} tok/s (gather) vs "
          f"{fused['tok_per_s']:.1f} tok/s (fused); KV bytes/step "
          f"{gather['kv_bytes_per_step']:.2e} -> "
          f"{fused['kv_bytes_per_step']:.2e} "
          f"({fused['kv_bytes_win']:.1f}x analytic, tokens identical)")
    for c in cells.values():
        c.pop("outputs")
    return [gather, fused]


# ----------------------------------------------------------------------
# Mixed prefill/decode + shared-prefix workload (paged-cache acceptance)
# ----------------------------------------------------------------------
def run_workload_cell(cfg, params, *, mode: str, executor: str, slots: int,
                      capacity: int, n_req: int, prefix_len: int,
                      suffix_len: int, max_new: int, prefill_chunk: int,
                      kv_block: int) -> dict:
    """One RESIDENT request decodes throughout while ``n_req`` shared-
    prefix requests stream through the remaining slots.  Counts, besides
    wall time, the DETERMINISTIC costs: engine forwards (steps + the
    contiguous engine's admission prefills — each is one jit call, i.e.
    one DispatchPlan per MoE layer), prompt tokens that actually entered
    dispatch plans (prefix-cache hits never do), and the resident's decode
    tokens per forward — the "prefill stalls decoding" lever: a contiguous
    admission prefill is a forward in which the resident produces nothing,
    while a prefill chunk rides the resident's own decode plan.

    modes: ``paged`` (prefix cache + chunked prefill), ``paged_noprefix``
    (chunked prefill only), ``contiguous`` (pre-paging engine)."""
    rc = RunConfig(q_chunk=64, kv_chunk=64, executor=executor,
                   schedule_policy="dynamic", moe_stats=bool(cfg.is_moe))
    kw = {"paged": dict(kv_block_size=kv_block, prefix_cache=True,
                        prefill_chunk=prefill_chunk),
          "paged_noprefix": dict(kv_block_size=kv_block, prefix_cache=False,
                                 prefill_chunk=prefill_chunk),
          "contiguous": dict(kv_block_size=0)}[mode]
    eng = ServeEngine(cfg, params, slots=slots, capacity=capacity, rc=rc,
                      **kw)
    rng = np.random.default_rng(0)
    resident = Request(rid=10 ** 6,
                       prompt=rng.integers(0, cfg.vocab_size,
                                           4).astype(np.int32),
                       max_new=10 ** 9)           # never retires in-window
    prefix = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [prefix, rng.integers(0, cfg.vocab_size, suffix_len)
                         ]).astype(np.int32),
                    max_new=max_new)
            for i in range(n_req)]
    eng.admit(resident)
    res_base = len(resident.out)
    pending = list(reqs)
    steps = admits = 0
    t0 = time.perf_counter()
    while not all(r.done for r in reqs):
        while pending and eng.n_active < eng.slots:
            eng.admit(pending.pop(0))
            admits += 1
        assert eng.step() > 0
        steps += 1
    dt = time.perf_counter() - t0
    decode_tokens = sum(len(r.out) for r in reqs)
    # contiguous admission runs a whole-prompt prefill forward per request;
    # paged admission runs none (chunks ride inside the counted steps)
    forwards = steps + (admits if not eng.paged else 0)
    resident_tokens = len(resident.out) - res_base
    hit = sum(r.stats.get("serve/prefix_hit_tokens", 0.0) for r in reqs)
    dispatched = sum(len(r.prompt) for r in reqs) - hit
    rec = {"mode": mode, "slots": slots, "n_req": n_req,
           "prefix_len": prefix_len, "suffix_len": suffix_len,
           "max_new": max_new, "prefill_chunk": prefill_chunk,
           "kv_block": (kv_block if mode != "contiguous" else 0),
           "decode_tokens": decode_tokens, "forwards": forwards,
           "prefill_dispatch_tokens": dispatched,
           "prefix_hit_tokens": hit,
           "resident_tokens": resident_tokens,
           "decode_tok_per_forward": resident_tokens / forwards,
           "wall_s": dt,
           "tok_per_s": (decode_tokens + resident_tokens) / dt,
           "latency": latency_summary(reqs),
           "kv_stats": eng.kv.stats() if eng.paged else None,
           "config": eng.describe(seed=0),
           "outputs": {r.rid: r.out for r in reqs}}
    emit(f"workload_{mode}", dt / max(forwards, 1),
         f"resident_tok_per_fwd={rec['decode_tok_per_forward']:.2f}")
    return rec


def run_shared_prefix_sweep(cfg, params, *, executor: str, smoke: bool):
    dims = dict(slots=2, capacity=128 if smoke else 256,
                n_req=4 if smoke else 8,
                prefix_len=24 if smoke else 48, suffix_len=4,
                max_new=6 if smoke else 16, prefill_chunk=8, kv_block=8)
    cells = {m: run_workload_cell(cfg, params, mode=m, executor=executor,
                                  **dims)
             for m in ("paged", "paged_noprefix", "contiguous")}
    paged, noprefix, contig = (cells["paged"], cells["paged_noprefix"],
                               cells["contiguous"])
    # tokens must be identical across cache layouts — else the speedups
    # below are measuring a correctness bug
    assert paged["outputs"] == noprefix["outputs"] == contig["outputs"]
    # prefix hits: later requests' shared blocks never enter a plan —
    # fewer prefill dispatch tokens AND fewer engine forwards
    assert paged["prefill_dispatch_tokens"] \
        < noprefix["prefill_dispatch_tokens"], cells
    assert paged["forwards"] < noprefix["forwards"], cells
    # the stream's first admission computes the prefix; every later one
    # must hit the full registered run
    full_prefix = (dims["prefix_len"] // dims["kv_block"]) * dims["kv_block"]
    assert paged["prefix_hit_tokens"] \
        >= (dims["n_req"] - 1) * full_prefix, cells
    # chunked prefill: the resident slot decodes in EVERY forward (chunks
    # ride its plan), while the contiguous engine stalls it one forward
    # per admission prefill — strictly higher decode tok/forward
    assert paged["decode_tok_per_forward"] \
        > contig["decode_tok_per_forward"], cells
    for c in cells.values():
        c.pop("outputs")
    print(f"# shared-prefix workload: prefill tokens dispatched "
          f"{contig['prefill_dispatch_tokens']:.0f} (contiguous) -> "
          f"{paged['prefill_dispatch_tokens']:.0f} (prefix cache); "
          f"decode tok/forward {contig['decode_tok_per_forward']:.2f} -> "
          f"{paged['decode_tok_per_forward']:.2f} (chunked prefill)")
    return list(cells.values())


# ----------------------------------------------------------------------
# Expert-parallel scaling (padding-free a2a vs static layout + curves)
# ----------------------------------------------------------------------
def run_ep_scaling(*, smoke: bool, out_dir: pathlib.Path) -> None:
    """EP dispatch scaling on a >1-device mesh + the a2a payload
    accounting that motivates the padding-free send path.

    Payload table (analytic, per source rank): the padding-free transport
    commits ``ep * a2a_send_rows`` rows; the legacy static layout ships
    ``E * expert_capacity`` rows no matter what routed where.  The
    serving regime (many experts, modest per-rank token count — the
    DeepSeek-style E=64 cell here) is where padding-free wins; the
    acceptance bar (dynamic under zipf2.0 skew strictly below static) is
    asserted, with the actually-USED rows under a zipf2.0 draw recorded
    alongside.  Timed curves run the real sharded dispatch (and the
    overlapped variant) at each mesh size the host exposes — launch under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU."""
    from benchmarks.common import time_fn, zipf_assignments
    from repro.compat import set_mesh
    from repro.configs.base import MoEConfig
    from repro.core import dispatch_config, init_moe_params
    from repro.core.distributed import (a2a_send_rows, a2a_send_rows_static,
                                        apply_moe_ep)

    E, k, M, d, Tl, cf = 64, 2, 16, 32, 64, 2.0
    eps = [1, 2, 4]
    ndev = jax.local_device_count()

    payload = []
    static_rows = a2a_send_rows_static(Tl, k, E, M, cf)
    _, idx = zipf_assignments(jax.random.key(7), Tl, k, E, 2.0)
    for policy in ("fixed", "dynamic", "capacity_factor"):
        for ep in eps:
            C = a2a_send_rows(Tl, k, E, ep, M, cf, policy)
            dest = np.asarray(idx).reshape(-1) // (E // ep)
            used = int(np.bincount(dest, minlength=ep).max())
            payload.append({
                "policy": policy, "ep": ep, "skew": "zipf2.0",
                "rows_padding_free": ep * C, "rows_static": static_rows,
                "rows_used_max_dest": used,
                "payload_ratio": ep * C / static_rows})
    for rec in payload:
        if rec["policy"] in ("dynamic", "capacity_factor"):
            assert rec["rows_padding_free"] < rec["rows_static"], (
                "padding-free a2a payload must undercut the static "
                "layout in the many-expert serving regime", rec)
    print(f"# payload (per-rank a2a rows, E={E} k={k} M={M} Tl={Tl}): "
          f"static={static_rows}; padding-free "
          + ", ".join(f"{r['policy']}@ep{r['ep']}={r['rows_padding_free']}"
                      for r in payload if r["ep"] == max(eps)))

    moe = MoEConfig(n_experts=E, top_k=k, d_ff_expert=32, block_m=M,
                    capacity_factor=cf)
    params = init_moe_params(jax.random.key(0), moe, d)
    curves = []
    steps = 2 if smoke else 8
    for ep in [e for e in eps if e <= ndev]:
        mesh = jax.make_mesh((ep,), ("model",))
        T = Tl * ep                       # weak scaling: Tl fixed per rank
        x = jax.random.normal(jax.random.key(1), (1, T, d))
        for policy in ("dynamic", "capacity_factor"):
            dcfg = dispatch_config(moe, executor="xla",
                                   schedule_policy=policy)
            for overlap in ((0, 2) if ep > 1 else (0,)):
                with set_mesh(mesh):
                    fn = jax.jit(lambda p, x, o=overlap, c=dcfg:
                                 apply_moe_ep(p, x, c, overlap=o)[0])
                    t = time_fn(fn, params, x, warmup=1, iters=steps)
                tok_per_s = T / t
                curves.append({
                    "ep": ep, "policy": policy, "overlap": overlap,
                    "tokens": T, "s_per_call": t, "tok_per_s": tok_per_s})
                emit(f"ep_scaling/{policy}/ep{ep}"
                     f"{'/overlap' if overlap else ''}", t,
                     f"tok_per_s={tok_per_s:.1f}")
    if ndev < max(eps):
        print(f"# note: only {ndev} device(s) visible — curves above "
              f"ep={ndev} skipped (force more with XLA_FLAGS)")

    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"ep_scaling{'_smoke' if smoke else ''}.json"
    out_path.write_text(json.dumps(
        {"regime": {"n_experts": E, "top_k": k, "block_m": M,
                    "tokens_per_rank": Tl, "capacity_factor": cf,
                    "d_model": d},
         "payload_rows": payload, "curves": curves,
         "devices": ndev}, indent=1))
    print(f"# wrote {out_path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moonshot-v1-16b-a3b")
    ap.add_argument("--slots", default="1,2,4,8",
                    help="comma-separated active-slot counts to sweep")
    ap.add_argument("--policies", default="fixed,dynamic",
                    help=f"comma-separated schedule policies "
                         f"(registered: {','.join(available_policies())})")
    ap.add_argument("--executor", default="xla",
                    choices=available_executors())
    ap.add_argument("--quant", default="none",
                    choices=available_schemes(),
                    help="expert-weight quantization scheme "
                         "(repro.quantization registry)")
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--kv-block-size", type=int, default=None,
                    help="paged cache block size for the decode sweep "
                         "(0 = contiguous; default auto)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI: slots 1,2 / 4 steps")
    ap.add_argument("--out", default="results/serve",
                    help="output dir for the JSON records")
    ap.add_argument("--ep-scaling", action="store_true",
                    help="run ONLY the expert-parallel scaling sweep "
                         "(padding-free vs static a2a payload + dispatch "
                         "curves on a >1-device mesh); CPU needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N")
    args = ap.parse_args()

    if args.ep_scaling:
        run_ep_scaling(smoke=args.smoke, out_dir=pathlib.Path(args.out))
        return

    slot_counts = [int(s) for s in args.slots.split(",")]
    steps = args.steps
    if args.smoke:
        slot_counts = [1, 2]
        steps = 4
    # steady-state requires no retirement inside warmup(2)+steps decodes:
    # a slot retires when its position hits capacity - 1
    max_steps = args.capacity - 1 - PROMPT_LEN - 2
    if steps > max_steps:
        raise SystemExit(
            f"--steps {steps} exceeds the capacity headroom: at most "
            f"{max_steps} timed steps fit before a slot retires "
            f"(capacity {args.capacity} - prompt {PROMPT_LEN} - warmup 2); "
            f"raise --capacity or lower --steps")

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.key(0))
    print(f"# {args.arch} (reduced) — decode throughput, "
          f"slots={slot_counts} x policies={args.policies} "
          f"[executor={args.executor}, quant={args.quant}]")
    print("name,us_per_call,derived")

    records = []
    for policy in args.policies.split(","):
        for slots in slot_counts:
            records.append(run_cell(cfg, params, slots=slots, policy=policy,
                                    executor=args.executor, steps=steps,
                                    capacity=args.capacity,
                                    quant=args.quant,
                                    kv_block_size=args.kv_block_size))

    from repro.serve.kv_cache import paged_supported
    if paged_supported(cfg):
        shared_prefix = run_shared_prefix_sweep(cfg, params,
                                                executor=args.executor,
                                                smoke=args.smoke)
        # the ≥8-slot fused-vs-gather decode cell (pallas executor; the
        # modes differ only in the attention read path)
        paged_attn = run_paged_attn_compare(
            cfg, params, slots=8, steps=4 if args.smoke else 16,
            capacity=args.capacity, kv_block=8)
    else:
        shared_prefix = []
        paged_attn = []
        print(f"# shared-prefix workload skipped: {args.arch} has "
              f"non-pageable caches (contiguous engine only)")

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "_smoke" if args.smoke else ""
    out_path = out_dir / f"{args.arch}{suffix}.json"
    out_path.write_text(json.dumps({"arch": args.arch, "reduced": True,
                                    "records": records,
                                    "shared_prefix": shared_prefix,
                                    "paged_attn": paged_attn},
                                   indent=1))
    print(f"# wrote {out_path}")

    for policy in args.policies.split(","):
        by_slots = {r["slots"]: r for r in records if r["policy"] == policy}
        lo, hi = min(by_slots), max(by_slots)
        gain = by_slots[hi]["tok_per_s"] / by_slots[lo]["tok_per_s"]
        print(f"# {policy}: {by_slots[lo]['tok_per_s']:.1f} tok/s @ {lo} "
              f"slot(s) -> {by_slots[hi]['tok_per_s']:.1f} tok/s @ {hi} "
              f"slots ({gain:.2f}x)")
        if not args.smoke:
            assert gain > 1.0, \
                (f"{policy}: batched decode throughput did not increase "
                 f"with slot count ({gain:.2f}x)")


if __name__ == "__main__":
    main()
