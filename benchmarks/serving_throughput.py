"""Decode throughput of the batched serving engine: active-slot count x
schedule policy.

The paper's throughput claim is that MoE wins come from batching tokens
into one fused dispatch; at serve time the decode batch IS the set of
active slots, so this sweep measures exactly that lever: every step is one
jitted forward over the (slots, capacity) cache — one DispatchPlan per MoE
layer covering all slots — and tokens/sec is slots * steps / wall.  More
active slots amortize both the per-step dispatch overhead and the expert
weight traffic (the dominant decode cost), so decode throughput should
rise with slot count; the fixed-vs-dynamic policy axis shows what schedule
construction costs on realistic decode batches.

Steady-state methodology: all slots are admitted up front (max_new large
enough that nothing retires inside the timed window), two warmup steps
absorb compilation, then ``--steps`` lock-step decodes are timed.

Records go to results/serve/<arch><suffix>.json (CSV on stdout follows
benchmarks/common emit conventions).

    PYTHONPATH=src python -m benchmarks.serving_throughput [--smoke]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.execution import available_executors
from repro.models import RunConfig, init_params
from repro.quantization import available_schemes
from repro.scheduling import available_policies
from repro.serve.engine import Request, ServeEngine

PROMPT_LEN = 6


def run_cell(cfg, params, *, slots: int, policy: str, executor: str,
             steps: int, capacity: int, quant: str = "none") -> dict:
    rc = RunConfig(q_chunk=64, kv_chunk=64, executor=executor,
                   schedule_policy=policy, quant=quant, moe_stats=False)
    eng = ServeEngine(cfg, params, slots=slots, capacity=capacity, rc=rc)
    rng = np.random.default_rng(0)
    for i in range(slots):
        eng.admit(Request(rid=i,
                          prompt=rng.integers(0, cfg.vocab_size,
                                              PROMPT_LEN).astype(np.int32),
                          max_new=capacity))        # never retires in-window
    assert eng.n_active == slots
    for _ in range(2):                               # warmup: compile + cache
        eng.step()
    t0 = time.perf_counter()
    for _ in range(steps):
        n = eng.step()
        assert n == slots
    dt = time.perf_counter() - t0
    s_per_step = dt / steps
    tok_per_s = slots * steps / dt
    emit(f"serve_{policy}_slots{slots}", s_per_step,
         f"tok_per_s={tok_per_s:.1f}")
    return {"slots": slots, "policy": policy, "executor": executor,
            "quant": quant, "steps": steps, "s_per_step": s_per_step,
            "tok_per_s": tok_per_s}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moonshot-v1-16b-a3b")
    ap.add_argument("--slots", default="1,2,4,8",
                    help="comma-separated active-slot counts to sweep")
    ap.add_argument("--policies", default="fixed,dynamic",
                    help=f"comma-separated schedule policies "
                         f"(registered: {','.join(available_policies())})")
    ap.add_argument("--executor", default="xla",
                    choices=available_executors())
    ap.add_argument("--quant", default="none",
                    choices=available_schemes(),
                    help="expert-weight quantization scheme "
                         "(repro.quantization registry)")
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI: slots 1,2 / 4 steps")
    ap.add_argument("--out", default="results/serve",
                    help="output dir for the JSON records")
    args = ap.parse_args()

    slot_counts = [int(s) for s in args.slots.split(",")]
    steps = args.steps
    if args.smoke:
        slot_counts = [1, 2]
        steps = 4
    # steady-state requires no retirement inside warmup(2)+steps decodes:
    # a slot retires when its position hits capacity - 1
    max_steps = args.capacity - 1 - PROMPT_LEN - 2
    if steps > max_steps:
        raise SystemExit(
            f"--steps {steps} exceeds the capacity headroom: at most "
            f"{max_steps} timed steps fit before a slot retires "
            f"(capacity {args.capacity} - prompt {PROMPT_LEN} - warmup 2); "
            f"raise --capacity or lower --steps")

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.key(0))
    print(f"# {args.arch} (reduced) — decode throughput, "
          f"slots={slot_counts} x policies={args.policies} "
          f"[executor={args.executor}, quant={args.quant}]")
    print("name,us_per_call,derived")

    records = []
    for policy in args.policies.split(","):
        for slots in slot_counts:
            records.append(run_cell(cfg, params, slots=slots, policy=policy,
                                    executor=args.executor, steps=steps,
                                    capacity=args.capacity,
                                    quant=args.quant))

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "_smoke" if args.smoke else ""
    out_path = out_dir / f"{args.arch}{suffix}.json"
    out_path.write_text(json.dumps({"arch": args.arch, "reduced": True,
                                    "records": records}, indent=1))
    print(f"# wrote {out_path}")

    for policy in args.policies.split(","):
        by_slots = {r["slots"]: r for r in records if r["policy"] == policy}
        lo, hi = min(by_slots), max(by_slots)
        gain = by_slots[hi]["tok_per_s"] / by_slots[lo]["tok_per_s"]
        print(f"# {policy}: {by_slots[lo]['tok_per_s']:.1f} tok/s @ {lo} "
              f"slot(s) -> {by_slots[hi]['tok_per_s']:.1f} tok/s @ {hi} "
              f"slots ({gain:.2f}x)")
        if not args.smoke:
            assert gain > 1.0, \
                (f"{policy}: batched decode throughput did not increase "
                 f"with slot count ({gain:.2f}x)")


if __name__ == "__main__":
    main()
