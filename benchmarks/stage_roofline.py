"""Paper Table 6 / Figure 1: per-stage roofline for the dispatch pipeline.

Per stage (router / permute / expert-FFN-unfused / expert-FFN-fused /
unpermute): FLOPs, HBM bytes, arithmetic intensity, and projected v5e
bandwidth/compute efficiency at the paper's Mixtral-8x7B 512-token shape.
CPU wall fractions are also measured (structure check: expert FFN must
dominate, permute/unpermute negligible — paper: >95% / <3%)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, HBM_BW, PEAK_FLOPS
from repro.configs.paper import PAPER_CONFIGS
from repro.core.dispatch import combine_scale_rows
from repro.core.schedule import build_schedule
from repro.kernels import ref

SCALE = 8
T = 512


def stage_table(d: int, f: int, E: int, k: int):
    """(flops, bytes) per stage at given dims for T tokens."""
    Tk = T * k
    return {
        "router": (2 * T * d * E + 5 * T * E, T * d * 2 + T * E * 4),
        "permute": (0, 2 * Tk * d * 2),
        "ffn_unfused": (2 * Tk * 3 * d * f,
                        3 * E * d * f * 2 + Tk * (2 * d + 10 * f) * 2),
        "ffn_fused": (2 * Tk * 3 * d * f,
                      3 * E * d * f * 2 + Tk * (2 * d + 2 * f) * 2),
        "unpermute": (2 * Tk * d, (Tk + T) * d * 4),
    }


def main():
    pc = PAPER_CONFIGS["mixtral-8x7b"]
    # ---- analytic v5e table at FULL dims (paper Table 6 analogue) ----
    for stage, (fl, by) in stage_table(pc.d_model, pc.d_ffn,
                                       pc.n_experts, pc.top_k).items():
        ai = fl / by if by else 0.0
        t = max(fl / PEAK_FLOPS, by / HBM_BW)
        bw_eff = (by / t) / HBM_BW if t else 0.0
        c_eff = (fl / t) / PEAK_FLOPS if t else 0.0
        emit(f"stage/{stage}/v5e", t,
             f"AI={ai:.1f};BW_eff={bw_eff:.1%};compute_eff={c_eff:.1%}")

    # ---- measured CPU wall fractions (scaled dims) ----
    d, f = pc.d_model // SCALE, pc.d_ffn // SCALE
    E, k = pc.n_experts, pc.top_k
    ks = jax.random.split(jax.random.key(0), 6)
    wr = jax.random.normal(ks[0], (d, E)) * 0.1
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.1
    x = jax.random.normal(ks[4], (T, d))

    logits = x @ wr
    w, idx = ref.router_ref(logits, k)
    sched = build_schedule(idx, E, 128)
    xp = ref.permute_ref(x, sched)
    from repro.core.dispatch import fused_gate_up_xla, grouped_gemm_xla
    h = fused_gate_up_xla(xp, wg, wu, sched)
    y = grouped_gemm_xla(h, wd, sched,
                         row_scale=combine_scale_rows(sched, w))

    stages = {
        "router": jax.jit(lambda x: ref.router_ref(x @ wr, k)[0]),
        "permute": jax.jit(lambda x: ref.permute_ref(x, sched)),
        "ffn_fused": jax.jit(lambda xp: grouped_gemm_xla(
            fused_gate_up_xla(xp, wg, wu, sched), wd, sched)),
        "unpermute": jax.jit(lambda y: ref.unpermute_ref(y, sched, w)),
    }
    args = {"router": x, "permute": x, "ffn_fused": xp, "unpermute": y}
    times = {s: time_fn(fn, args[s]) for s, fn in stages.items()}
    total = sum(times.values())
    for s, t in times.items():
        emit(f"stage/{s}/cpu", t, f"frac={t / total:.1%}")


if __name__ == "__main__":
    main()
