"""Paper Tables 2-3: end-to-end MoE layer latency across the four model
configurations and the token sweep.

Three arms per (config, tokens):
  pytorch_ref -> dense loop-over-experts oracle (the paper's baseline)
  ours        -> the dispatch pipeline (router -> permute -> fused grouped
                 GEMMs -> unpermute), XLA implementation
  tpu_proj    -> analytic v5e latency at the PAPER'S true dimensions

CPU arms run at width-scaled dims (d/SCALE, f/SCALE — dispatch structure,
expert count and top-k are exact); the scale is reported in `derived`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, tpu_projection
from repro.configs.paper import PAPER_CONFIGS, TOKEN_SWEEP
from repro.core.dispatch import MoEDispatchConfig, moe_ffn
from repro.kernels import ref

SCALE = 8
CPU_TOKENS = (32, 128, 512)


def bench_config(name: str, run_dense: bool = True):
    pc = PAPER_CONFIGS[name]
    d, f = pc.d_model // SCALE, max(pc.d_ffn // SCALE, 8)
    E, k = pc.n_experts, pc.top_k
    ks = jax.random.split(jax.random.key(0), 5)
    wr = jax.random.normal(ks[0], (d, E)) * 0.1
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.1

    for T in CPU_TOKENS:
        x = jax.random.normal(ks[4], (T, d))
        block_m = min(128, max(8, T * k // E))
        cfg = MoEDispatchConfig(n_experts=E, top_k=k, block_m=block_m,
                                executor="xla", gating=pc.gating)
        ours = jax.jit(lambda x: moe_ffn(x, wr, wg, wu, wd, cfg)[0])
        t = time_fn(ours, x)
        emit(f"e2e/{name}/ours/T{T}", t, f"cpu_scaled_1_{SCALE}")
        if run_dense and E <= 64:
            dense_cfg = cfg._replace(executor="dense")
            base = jax.jit(lambda x: moe_ffn(x, wr, wg, wu, wd, dense_cfg)[0])
            tb = time_fn(base, x)
            emit(f"e2e/{name}/pytorch_ref/T{T}", tb,
                 f"speedup={tb / t:.2f}x")
    for T in TOKEN_SWEEP:
        proj = tpu_projection(T, k, E, pc.d_model, pc.d_ffn, fused=True)
        emit(f"e2e/{name}/tpu_proj/T{T}", proj, "v5e_analytic_full_dims")


def main():
    for name in PAPER_CONFIGS:
        # paper omits the dense baseline for DeepSeek-V3 (768 launches);
        # we omit it above E=64 for the same reason (CPU time)
        bench_config(name)


if __name__ == "__main__":
    main()
