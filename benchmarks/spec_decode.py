"""Speculative decoding: acceptance rate × decode throughput vs the
non-speculative baseline (DESIGN.md §13, ROADMAP item 1).

Every cell runs the SAME seeded request batch through a fresh engine on
the reduced MoE config and records decode progress per target forward —
the device-independent win metric: a speculative round emits up to k+1
tokens per slot for ONE target forward, so ``tokens_per_forward`` rises
with the acceptance rate while the baseline is pinned at <= 1 per slot.
Wall-clock tok/s is recorded too but only ASSERTED on TPU — on CPU the
draft forwards' interpreter cost swamps the accounting win.

Sweep: k ∈ {2, 4} × sampling ∈ {greedy, temperature} × draft ∈
{self (target params — acceptance 1.0 by construction, isolating the
verify-path mechanics), reduced smollm-360m (a REAL separate draft:
random-weights acceptance is near-zero, fuzzing the rejection/rollback
path)}.  k=0 cells are the non-speculative ServeEngine baseline.

Asserted (CI: the spec-smoke job re-checks these on the artifact):
* greedy speculative output == greedy baseline output, token for token,
  for EVERY draft (the verify construction, not draft quality);
* acceptance_rate ∈ (0, 1] and drafted >= accepted on self-draft cells;
* self-draft target-forward count strictly below the k=0 baseline's.

Artifact: results/spec/<arch>[_smoke].json; analysis/report.py renders
the acceptance/throughput table.

    PYTHONPATH=src python -m benchmarks.spec_decode [--smoke]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.execution import available_executors
from repro.models import RunConfig, init_params
from repro.sampling import SamplingConfig
from repro.serve.engine import Request, ServeEngine
from repro.spec import SpecEngine, make_draft_config


def make_requests(vocab: int, n: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, vocab,
                                        rng.integers(4, 12)).astype(np.int32),
                    max_new=max_new)
            for i in range(n)]


def run_cell(cfg, params, *, rc, sampling: SamplingConfig, k: int,
             draft, n: int, max_new: int, max_steps: int) -> dict:
    """One engine run; k=0 is the non-speculative baseline."""
    kw = dict(slots=2, capacity=64, kv_block_size=4, prefill_chunk=4,
              rc=rc, sampling=sampling)
    if k == 0:
        eng = ServeEngine(cfg, params, **kw)
    else:
        dcfg, dparams = draft
        eng = SpecEngine(cfg, params, draft_cfg=dcfg, draft_params=dparams,
                         spec_k=k, **kw)
    reqs = make_requests(cfg.vocab_size, n, max_new)
    t0 = time.perf_counter()
    done = eng.run(reqs, max_steps=max_steps)
    wall = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in reqs)
    rec = {
        "spec_k": k,
        "sampling": sampling.method,
        "temperature": sampling.temperature,
        "completed": len(done),
        "n_requests": n,
        "decode_tokens": tokens,
        "target_forwards": eng.n_forwards,
        "tokens_per_forward": tokens / max(eng.n_forwards, 1),
        "wall_s": wall,
        "tok_per_s_wall": tokens / wall if wall > 0 else None,
        "outputs": {r.rid: list(r.out) for r in reqs},
        "config": eng.describe(),
    }
    if k > 0:
        rec.update({
            "draft": eng.draft_cfg.name,
            "draft_self": draft[1] is params,
            "spec_rounds": eng.n_spec_rounds,
            "drafted": eng.n_drafted,
            "accepted": eng.n_accepted,
            "acceptance_rate": eng.acceptance_rate,
            "draft_forwards": eng.n_draft_forwards,
        })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moonshot-v1-16b-a3b")
    ap.add_argument("--executor", default="xla",
                    choices=available_executors())
    ap.add_argument("--ks", default="2,4",
                    help="comma-separated spec_k values (0 = baseline, "
                         "always run)")
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI: k in {2}, 3 requests, "
                         "greedy + temperature")
    ap.add_argument("--tpu-assert", action="store_true",
                    help="also assert the wall-clock tok/s win (only "
                         "meaningful where forwards dominate wall time, "
                         "i.e. on an accelerator)")
    ap.add_argument("--out", default="results/spec")
    args = ap.parse_args()

    ks = [int(v) for v in args.ks.split(",") if v.strip()]
    n, max_new = args.requests, args.max_new
    if args.smoke:
        ks, n, max_new = [2], 3, 8

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.key(0))
    rc = RunConfig(q_chunk=16, kv_chunk=16, executor=args.executor,
                   schedule_policy="dynamic", moe_stats=False)
    dcfg = make_draft_config(cfg, reduce=True, layers=1, d_model=32)
    dparams = init_params(dcfg, jax.random.key(1))
    drafts = {"self": (cfg, params), "smollm": (dcfg, dparams)}
    samplings = [SamplingConfig(),
                 SamplingConfig(method="temperature", temperature=0.8,
                                seed=7)]
    max_steps = 2048

    print(f"# {args.arch} (reduced) — speculative decoding sweep, "
          f"k={ks} x sampling=[greedy, temperature] x draft=[self, "
          f"smollm] vs k=0 baseline [executor={args.executor}]")
    print("name,us_per_call,derived")
    records = []
    for sampling in samplings:
        base = run_cell(cfg, params, rc=rc, sampling=sampling, k=0,
                        draft=None, n=n, max_new=max_new,
                        max_steps=max_steps)
        emit(f"spec_{sampling.method}_k0", base["wall_s"],
             f"fwd={base['target_forwards']}")
        records.append(dict(base, draft="none"))
        for k in ks:
            for dname, draft in drafts.items():
                rec = run_cell(cfg, params, rc=rc, sampling=sampling,
                               k=k, draft=draft, n=n, max_new=max_new,
                               max_steps=max_steps)
                rec["baseline_forwards"] = base["target_forwards"]
                rec["forward_reduction"] = \
                    base["target_forwards"] / max(rec["target_forwards"], 1)
                emit(f"spec_{sampling.method}_k{k}_{dname}", rec["wall_s"],
                     f"acc={rec['acceptance_rate']:.2f} "
                     f"fwd={rec['target_forwards']} "
                     f"tpf={rec['tokens_per_forward']:.2f}")
                records.append(rec)

                assert rec["drafted"] >= rec["accepted"] >= 0, rec
                if sampling.method == "greedy":
                    # the correctness bar: speculative greedy output is
                    # token-identical to the baseline for ANY draft
                    assert rec["outputs"] == base["outputs"], \
                        (f"greedy spec k={k} draft={dname} diverged "
                         f"from baseline")
                if dname == "self":
                    # self-draft: every proposal is the target's own
                    # next token, so acceptance is high by construction
                    # and the forward-count win must materialize on CPU
                    assert 0.0 < rec["acceptance_rate"] <= 1.0, rec
                    assert rec["target_forwards"] \
                        < base["target_forwards"], \
                        (f"k={k} self-draft ran "
                         f"{rec['target_forwards']} target forwards, "
                         f"baseline {base['target_forwards']}")
                    if args.tpu_assert:
                        assert rec["tok_per_s_wall"] \
                            > base["tok_per_s_wall"], (rec, base)

    greedy_identity = all(
        rec["outputs"] == base_rec["outputs"]
        for base_rec in records
        if base_rec["spec_k"] == 0 and base_rec["sampling"] == "greedy"
        for rec in records
        if rec["spec_k"] > 0 and rec["sampling"] == "greedy")
    for rec in records:
        rec.pop("outputs", None)        # artifact stays small + diffable
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "_smoke" if args.smoke else ""
    out_path = out_dir / f"{args.arch}{suffix}.json"
    out_path.write_text(json.dumps(
        {"arch": args.arch, "reduced": True,
         "executor": args.executor,
         "greedy_identity": greedy_identity,
         "records": records}, indent=1))
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()
