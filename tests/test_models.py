"""Per-architecture smoke tests (assignment deliverable f): REDUCED config
of the same family, one forward/train step on CPU, output shapes + no NaNs;
plus decode == prefill-continuation consistency for every decodable arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import RunConfig, forward, init_cache, init_params, loss_fn

RC = RunConfig(q_chunk=16, kv_chunk=16, loss_chunk=16)
B, S = 2, 32


def make_batch(cfg, T=S, seed=1):
    rng = np.random.default_rng(seed)
    if cfg.encoder_only:
        return {"features": jnp.asarray(
                    rng.normal(size=(B, T, cfg.d_model)) * 0.3, jnp.float32),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32),
                "mask": jnp.zeros((B, T), bool).at[:, ::4].set(True)}
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)}
    if cfg.cross_attn_every:
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_image_tokens, cfg.d_model)) * 0.3,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    h, _, _ = jax.jit(lambda p, b: forward(p, cfg, RC, b, mode="train"))(
        params, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, RC, b))(params, batch)
    assert np.isfinite(float(loss))
    # a grad step moves the loss: the gradient is a descent direction, so
    # SOME small enough step must improve.  A single fixed step size can
    # overshoot on stiff architectures (zamba2's 81-layer hybrid stack) —
    # backtrack instead of asserting one arbitrary lr improves marginally.
    g = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, RC, b)[0]))(
        params, batch)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
               for l in jax.tree.leaves(g))
    loss_at = jax.jit(lambda p, b: loss_fn(p, cfg, RC, b)[0])
    losses2 = []
    for eta in (0.3, 0.1, 0.03):
        p2 = jax.tree.map(lambda p, gg: p - eta * gg, params, g)
        losses2.append(float(loss_at(p2, batch)))
        if losses2[-1] < float(loss):
            break
    assert min(losses2) < float(loss), (losses2, float(loss))


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if get_config(a).has_decode])
def test_decode_matches_prefill(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    T = 16
    batch_full = make_batch(cfg, T + 1)
    toks = batch_full["tokens"]
    sub = lambda t: dict(batch_full, tokens=t)
    cache = init_cache(cfg, B, T + 4)
    _, cache, _ = forward(params, cfg, RC, sub(toks[:, :T]), mode="prefill",
                          cache=cache)
    logits_d, _, _ = forward(params, cfg, RC, sub(toks[:, T:T + 1]),
                             mode="decode", cache=cache, pos=T)
    cache2 = init_cache(cfg, B, T + 4)
    logits_ref, _, _ = forward(params, cfg, RC, sub(toks), mode="prefill",
                               cache=cache2)
    rel = float(jnp.max(jnp.abs(logits_d - logits_ref))) / \
        (float(jnp.max(jnp.abs(logits_ref))) + 1e-9)
    assert rel < 2e-3, f"{arch}: decode/prefill mismatch {rel}"


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "moonshot-v1-16b-a3b"])
def test_moe_impls_agree_in_model(arch):
    """Full model forward identical across dense/xla/pallas dispatch."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    outs = {}
    for impl in ("dense", "xla", "pallas"):
        rc = RC._replace(executor=impl)
        h, _, _ = forward(params, cfg, rc, batch, mode="train")
        outs[impl] = np.asarray(h, np.float32)
    np.testing.assert_allclose(outs["dense"], outs["xla"],
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs["dense"], outs["pallas"],
                               rtol=2e-4, atol=2e-4)


def test_unroll_matches_scan():
    cfg = reduced(get_config("qwen2-7b"), layers=3)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    h1, _, _ = forward(params, cfg, RC, batch, mode="train")
    h2, _, _ = forward(params, cfg, RC._replace(unroll=True), batch,
                       mode="train")
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)


def test_exact_config_shapes():
    """The full (non-reduced) configs expose the assigned dimensions."""
    import repro.analysis.flops as F
    expected = {
        "hubert-xlarge": (48, 1280), "deepseek-v2-236b": (60, 5120),
        "moonshot-v1-16b-a3b": (48, 2048), "qwen2-7b": (28, 3584),
        "smollm-360m": (32, 960), "gemma2-9b": (42, 3584),
        "starcoder2-3b": (30, 3072), "rwkv6-1.6b": (24, 2048),
        "llama-3.2-vision-11b": (40, 4096), "zamba2-7b": (81, 3584),
    }
    for arch, (L, d) in expected.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model) == (L, d)
    # parameter-count sanity (right order of magnitude vs names)
    approx = {"deepseek-v2-236b": 236e9, "qwen2-7b": 7.6e9,
              "smollm-360m": 0.36e9, "gemma2-9b": 9.2e9,
              "starcoder2-3b": 3.0e9, "rwkv6-1.6b": 1.6e9,
              "zamba2-7b": 7.2e9,
              # assigned pool pins 48L (the released Moonlight has 27):
              # 48 x 64e x (3*2048*1408) alone is ~26B — check the assigned
              # config's own arithmetic, not the marketing name
              "moonshot-v1-16b-a3b": 28.4e9}
    for arch, n in approx.items():
        got = F.total_params(get_config(arch))
        assert 0.55 * n < got < 1.6 * n, f"{arch}: {got:.3e} vs {n:.3e}"
