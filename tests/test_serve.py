"""Serving engine: greedy generation via the slot engine == teacher-forced
argmax continuation; slot reuse under more requests than slots."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import RunConfig, forward, init_cache, init_params
from repro.serve.engine import Request, ServeEngine

RC = RunConfig(q_chunk=16, kv_chunk=16)


def greedy_reference(cfg, params, prompt, n_new):
    """Teacher-forced reference: repeatedly prefill the growing sequence."""
    toks = list(prompt)
    for _ in range(n_new):
        cache = init_cache(cfg, 1, len(toks) + 1)
        logits, _, _ = forward(params, cfg, RC,
                               {"tokens": jnp.asarray([toks], jnp.int32)},
                               mode="prefill", cache=cache)
        toks.append(int(jnp.argmax(logits, -1)[0]))
    return toks[len(prompt):]


def test_engine_matches_teacher_forcing():
    cfg = reduced(get_config("smollm-360m"), layers=2, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    prompt = np.asarray([1, 5, 9, 2], np.int32)
    n_new = 5
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=RC)
    req = Request(rid=0, prompt=prompt, max_new=n_new)
    eng.run([req])
    ref = greedy_reference(cfg, params, prompt, n_new)
    assert req.out == ref, (req.out, ref)


def test_slot_reuse_many_requests():
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    reqs = [Request(rid=i, prompt=np.asarray([i + 1, i + 2], np.int32),
                    max_new=3) for i in range(5)]
    eng = ServeEngine(cfg, params, slots=2, capacity=16, rc=RC)
    done = eng.run(reqs, max_steps=64)
    assert len(done) == len(reqs)          # no request lost or unfinished
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)


def test_engine_decode_isolated_between_slots():
    """Two different prompts decoded concurrently must match their solo
    runs (cache isolation across slots)."""
    cfg = reduced(get_config("smollm-360m"), layers=2, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    p1 = np.asarray([3, 1, 4], np.int32)
    p2 = np.asarray([2, 7, 1, 8], np.int32)
    solo = []
    for p in (p1, p2):
        r = Request(rid=0, prompt=p, max_new=4)
        ServeEngine(cfg, params, slots=1, capacity=32, rc=RC).run([r])
        solo.append(r.out)
    r1, r2 = (Request(rid=1, prompt=p1, max_new=4),
              Request(rid=2, prompt=p2, max_new=4))
    ServeEngine(cfg, params, slots=2, capacity=32, rc=RC).run([r1, r2])
    assert [r1.out, r2.out] == solo


# ---------------------------------------------------------------------------
# Batched continuous-batching engine (one decode dispatch per step)
# ---------------------------------------------------------------------------
import pytest

from repro.configs import REGISTRY  # noqa: F401  (arch names below)
from repro.models.lm import slice_cache_slots, update_cache_slots


def make_per_slot_reference(cfg, rc, params, capacity):
    """The PRE-REFACTOR engine's per-slot loop: an isolated B=1 cache per
    request, one jitted batch-1 decode (scalar pos) and one host sync per
    slot per step.  Since slots were fully isolated, a request's tokens
    equal its solo greedy decode with the old retirement rule."""
    prefill = jax.jit(lambda p, b, c: forward(p, cfg, rc, b, mode="prefill",
                                              cache=c))
    decode = jax.jit(lambda p, b, c, pos: forward(p, cfg, rc, b,
                                                  mode="decode", cache=c,
                                                  pos=pos))

    def greedy(req):
        cache = init_cache(cfg, 1, capacity)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache, _ = prefill(params, {"tokens": toks}, cache)
        out = [int(jnp.argmax(logits, -1)[0])]
        pos = len(req.prompt)
        while True:
            last = jnp.asarray([[out[-1]]], jnp.int32)
            logits, cache, _ = decode(params, {"tokens": last}, cache,
                                      jnp.int32(pos))
            out.append(int(jnp.argmax(logits, -1)[0]))
            pos += 1
            if (req.eos is not None and out[-1] == req.eos) \
                    or len(out) >= req.max_new or pos >= capacity - 1:
                return out
    return greedy


def moe_cfg(layers=2):
    return reduced(get_config("moonshot-v1-16b-a3b"), layers=layers,
                   d_model=64, vocab=256)


def test_batched_decode_matches_per_slot_engine_moe():
    """Greedy outputs of the batched engine (one dispatch per step across
    all slots) are identical to the pre-refactor per-slot loop."""
    cfg = moe_cfg()
    params = init_params(cfg, jax.random.key(0))
    rc = RunConfig(q_chunk=16, kv_chunk=16, schedule_policy="dynamic",
                   moe_stats=True)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        rng.integers(3, 7)).astype(np.int32),
                    max_new=5)
            for i in range(5)]
    eng = ServeEngine(cfg, params, slots=3, capacity=32, rc=rc)
    done = eng.run(reqs)
    assert len(done) == 5 and not eng.dropped
    ref = make_per_slot_reference(cfg, rc, params, 32)
    for r in reqs:
        assert r.out == ref(Request(rid=r.rid, prompt=r.prompt,
                                    max_new=r.max_new)), r.rid


def test_slot_permutation_invariance():
    """Submission order / slot count change which cache row and decode
    batch a request lands in — never its tokens."""
    cfg = reduced(get_config("smollm-360m"), layers=2, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.integers(2, 6)).astype(np.int32)
               for _ in range(4)]

    def run_order(order, slots):
        reqs = {i: Request(rid=i, prompt=prompts[i], max_new=4)
                for i in range(4)}
        eng = ServeEngine(cfg, params, slots=slots, capacity=32, rc=RC)
        eng.run([reqs[i] for i in order])
        assert all(r.done for r in reqs.values())
        return {i: r.out for i, r in reqs.items()}

    base = run_order([0, 1, 2, 3], 2)
    assert run_order([3, 1, 0, 2], 2) == base
    assert run_order([2, 0, 3, 1], 4) == base
    assert run_order([1, 3, 2, 0], 1) == base


def test_eos_retire_readmit_churn_telemetry_intact():
    """EOS-triggered retirement (detected on device), slot refill under
    more requests than slots, and per-request plan telemetry keyed by rid
    surviving the churn.  The RNG seed is pinned (override with
    REPRO_SERVE_SEED) so any failure replays exactly."""
    import os
    seed = int(os.environ.get("REPRO_SERVE_SEED", "3"))
    cfg = moe_cfg()
    params = init_params(cfg, jax.random.key(0))
    rc = RunConfig(q_chunk=16, kv_chunk=16, schedule_policy="dynamic",
                   moe_stats=True)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.integers(3, 7)).astype(np.int32)
               for _ in range(6)]
    ref = make_per_slot_reference(cfg, rc, params, 32)
    solo = [ref(Request(rid=i, prompt=p, max_new=6))
            for i, p in enumerate(prompts)]
    # request 0 retires early on EOS: its reference 2nd token as eos
    reqs = [Request(rid=0, prompt=prompts[0], max_new=6, eos=solo[0][1])]
    reqs += [Request(rid=i, prompt=prompts[i], max_new=3 + (i % 3))
             for i in range(1, 6)]
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=rc)
    done = eng.run(reqs)
    assert len(done) == 6 and all(r.done for r in reqs)
    assert reqs[0].out == solo[0][:2]            # on-device EOS cut
    for r in reqs[1:]:
        assert r.out == solo[r.rid][:r.max_new]
    # telemetry: every retired request carries the shared step plan's aux
    for r in reqs:
        assert r.stats and r.stats["serve/decode_batch"] >= 1.0
        assert any(k.startswith("sched/") for k in r.stats)
        assert all(np.isfinite(v) for v in r.stats.values())
    assert eng._last_aux == {}                    # all popped by rid


def _count_plans(monkeypatch):
    """Patch plan_dispatch to record the token count of every traced plan."""
    import repro.core.dispatch as dispatch_mod
    calls = []
    real = dispatch_mod.plan_dispatch

    def counting(x, w_router, dcfg, **kw):
        calls.append(int(x.shape[0]))
        return real(x, w_router, dcfg, **kw)

    monkeypatch.setattr(dispatch_mod, "plan_dispatch", counting)
    return calls


def test_one_plan_per_step_covers_exactly_active_slots(monkeypatch):
    """One step = one jit call; each MoE layer builds exactly ONE
    DispatchPlan.  Under the paged engine the FIRST step's plan covers all
    prompt tokens of all admitting slots at once (chunked prefill riding
    the shared step), and steady decode plans cover exactly the active
    slots.  (rc.unroll python-loops the layer stack so the traced
    plan_dispatch calls are per-layer, not once per scanned group body.)"""
    cfg = moe_cfg(layers=3)                       # 1 dense prefix + 2 moe
    params = init_params(cfg, jax.random.key(0))
    rc = RunConfig(q_chunk=16, kv_chunk=16, schedule_policy="dynamic",
                   unroll=True)
    calls = _count_plans(monkeypatch)
    eng = ServeEngine(cfg, params, slots=4, capacity=32, rc=rc)
    assert eng.paged
    for i in range(3):
        eng.admit(Request(rid=i, prompt=np.asarray([1 + i, 2, 3], np.int32),
                          max_new=8))
    assert calls == []                # paged admission runs NO forward
    n_moe_layers = cfg.n_layers - cfg.moe.first_dense_layers
    assert eng.step() == 9            # 3 slots x 3 prompt tokens, one batch
    assert len(calls) == n_moe_layers, calls      # one plan per MoE layer
    assert all(t == 9 for t in calls), calls      # covering ALL chunk tokens
    calls.clear()
    assert eng.step() == 3                        # traces the n=3 decode
    assert len(calls) == n_moe_layers, calls
    assert all(t == 3 for t in calls), calls      # covering active tokens
    calls.clear()
    assert eng.step() == 3                        # compiled: no re-trace,
    assert calls == []                            # still one jit call


def test_one_plan_per_step_contiguous_mode(monkeypatch):
    """kv_block_size=0 keeps the pre-paging engine: whole-prompt prefill at
    admission, decode plans of exactly the active slots."""
    cfg = moe_cfg(layers=3)
    params = init_params(cfg, jax.random.key(0))
    rc = RunConfig(q_chunk=16, kv_chunk=16, schedule_policy="dynamic",
                   unroll=True)
    calls = _count_plans(monkeypatch)
    eng = ServeEngine(cfg, params, slots=4, capacity=32, rc=rc,
                      kv_block_size=0)
    assert not eng.paged
    for i in range(3):
        eng.admit(Request(rid=i, prompt=np.asarray([1 + i, 2, 3], np.int32),
                          max_new=8))
    calls.clear()                                 # drop prefill traces
    assert eng.step() == 3                        # traces the n=3 step
    n_moe_layers = cfg.n_layers - cfg.moe.first_dense_layers
    assert len(calls) == n_moe_layers, calls      # one plan per MoE layer
    assert all(t == 3 for t in calls), calls      # covering active tokens
    calls.clear()
    assert eng.step() == 3                        # compiled: no re-trace,
    assert calls == []                            # still one jit call


def test_run_surfaces_dropped_requests():
    """Requests still in flight when max_steps runs out keep done=False
    with their partial output and are collected in engine.dropped."""
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    reqs = [Request(rid=i, prompt=np.asarray([i + 1, i + 2], np.int32),
                    max_new=6) for i in range(3)]
    eng = ServeEngine(cfg, params, slots=1, capacity=16, rc=RC)
    done = eng.run(reqs, max_steps=3)
    assert len(done) < 3
    assert eng.dropped and all(not r.done for r in eng.dropped)
    assert {r.rid for r in done} | {r.rid for r in eng.dropped} == {0, 1, 2}
    in_flight = [r for r in eng.dropped if r.out]
    assert in_flight                              # partial output retained
    assert all(len(r.out) < r.max_new for r in in_flight)
    # a later run with budget finishes the stragglers and clears dropped
    done2 = eng.run([r for r in reqs if not r.done], max_steps=64)
    assert not eng.dropped and all(r.done for r in reqs) and done2


def test_telemetry_keyed_by_rid():
    """Per-request aux is keyed by rid (id() of a retired request can be
    recycled after GC) and is cleaned up at retirement."""
    import gc
    cfg = moe_cfg()
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, capacity=32)
    req = Request(rid=7, prompt=np.asarray([1, 2, 3], np.int32), max_new=3)
    assert eng.admit(req)
    assert set(eng._last_aux) == {7}
    eng.run([], max_steps=8)                      # drain the admitted slot
    assert req.done and eng._last_aux == {}
    del req
    gc.collect()
    batch2 = [Request(rid=i, prompt=np.asarray([4, 5], np.int32), max_new=3)
              for i in range(2)]
    eng.run(batch2)
    assert all(r.done and r.stats for r in batch2)
    assert eng._last_aux == {}


def test_admission_policies():
    from repro.serve.admission import (available_admission_policies,
                                       get_admission)
    reqs = [Request(rid=0, prompt=np.zeros(5, np.int32)),
            Request(rid=1, prompt=np.zeros(2, np.int32)),
            Request(rid=2, prompt=np.zeros(2, np.int32))]
    assert get_admission("fcfs")(reqs) == 0
    assert get_admission("sjf")(reqs) == 1        # shortest; fcfs tie-break
    assert {"fcfs", "sjf"} <= set(available_admission_policies())
    with pytest.raises(ValueError, match="unknown admission policy"):
        get_admission("nope")


def test_sjf_admission_end_to_end():
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    reqs = [Request(rid=i, prompt=np.arange(1, 2 + i, dtype=np.int32),
                    max_new=3) for i in range(4)]
    eng = ServeEngine(cfg, params, slots=2, capacity=16, rc=RC,
                      admission="sjf")
    done = eng.run(list(reversed(reqs)), max_steps=64)
    assert len(done) == 4 and all(r.done for r in reqs)


@pytest.mark.parametrize("arch", ["smollm-360m", "deepseek-v2-236b"])
def test_vector_pos_decode_matches_scalar(arch):
    """forward(mode=decode) with a (B,) position vector over a batched
    cache equals per-row scalar-pos decodes — including the MLA latent
    cache scatter (deepseek)."""
    cfg = reduced(get_config(arch), layers=2, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    full = init_cache(cfg, 2, 16)
    prompts = [np.asarray([1, 5, 9], np.int32),
               np.asarray([2, 7, 1, 8, 3], np.int32)]
    toks, poss = [], []
    for i, p in enumerate(prompts):
        sub = slice_cache_slots(full, i, 1)
        logits, new_sub, _ = forward(params, cfg, RC,
                                     {"tokens": jnp.asarray(p)[None]},
                                     mode="prefill", cache=sub)
        full = update_cache_slots(full, new_sub, i)
        toks.append(int(jnp.argmax(logits, -1)[0]))
        poss.append(len(p))
    last = jnp.asarray([[t] for t in toks], jnp.int32)
    logits_b, full_b, _ = forward(params, cfg, RC, {"tokens": last},
                                  mode="decode", cache=full,
                                  pos=jnp.asarray(poss, jnp.int32))
    for i in range(2):
        sub = slice_cache_slots(full, i, 1)
        logits_i, sub_n, _ = forward(params, cfg, RC,
                                     {"tokens": last[i:i + 1]},
                                     mode="decode", cache=sub,
                                     pos=jnp.int32(poss[i]))
        np.testing.assert_allclose(np.asarray(logits_b[i]),
                                   np.asarray(logits_i[0]),
                                   rtol=2e-5, atol=2e-5)
        sub_b = slice_cache_slots(full_b, i, 1)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5),
            sub_b, sub_n)


def test_resumed_run_does_not_readmit_active_requests():
    """A second run() finishing stragglers must not re-prefill a request
    that is still occupying a slot (that would duplicate its output)."""
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    reqs = [Request(rid=i, prompt=np.asarray([i + 1, i + 2], np.int32),
                    max_new=6) for i in range(3)]
    eng = ServeEngine(cfg, params, slots=1, capacity=16, rc=RC)
    eng.run(reqs, max_steps=3)                    # r0 left in flight
    assert eng.dropped
    eng.run([r for r in reqs if not r.done], max_steps=64)
    ref = make_per_slot_reference(cfg, RC, params, 16)
    for r in reqs:
        assert r.done and len(r.out) == r.max_new
        assert r.out == ref(Request(rid=r.rid, prompt=r.prompt,
                                    max_new=r.max_new)), r.rid


def test_slot_reuse_resets_recurrent_state():
    """Reusing a slot row must not leak the retired occupant's recurrent
    state (rwkv shift/state have no positional masking, unlike KV rows)."""
    cfg = reduced(get_config("rwkv6-1.6b"), layers=2, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    prompts = [np.asarray([3, 1, 4], np.int32),
               np.asarray([2, 7, 1, 8], np.int32)]
    ref = make_per_slot_reference(cfg, RC, params, 16)
    solo = [ref(Request(rid=i, prompt=p, max_new=4))
            for i, p in enumerate(prompts)]
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    eng = ServeEngine(cfg, params, slots=1, capacity=16, rc=RC)
    eng.run(reqs)                                 # slot 0 serves both
    assert [r.out for r in reqs] == solo


def test_duplicate_active_rid_rejected():
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, capacity=16, rc=RC)
    assert eng.admit(Request(rid=5, prompt=np.asarray([1, 2], np.int32)))
    with pytest.raises(ValueError, match="rid 5 is already active"):
        eng.admit(Request(rid=5, prompt=np.asarray([3, 4], np.int32)))


# ---------------------------------------------------------------------------
# Paged KV cache + prefix caching + chunked prefill (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------
def _mk_reqs(cfg, n, rng, lo=3, hi=9, max_new=5, prefix=()):
    reqs = []
    for i in range(n):
        body = rng.integers(0, cfg.vocab_size,
                            rng.integers(lo, hi)).astype(np.int32)
        reqs.append(Request(
            rid=i, prompt=np.concatenate([np.asarray(prefix, np.int32),
                                          body]).astype(np.int32),
            max_new=max_new))
    return reqs


def _outs(reqs):
    return {r.rid: list(r.out) for r in reqs}


@pytest.mark.parametrize("arch", ["smollm-360m", "moonshot-v1-16b-a3b",
                                  "deepseek-v2-236b", "gemma2-9b"])
@pytest.mark.parametrize("block,chunk", [(4, 2), (16, 64)])
def test_paged_matches_contiguous_greedy(arch, block, chunk):
    """THE acceptance criterion: greedy serving outputs are token-identical
    between the paged cache (any block size / chunk size / prefix cache)
    and the pre-refactor contiguous cache, on MoE and dense configs."""
    cfg = reduced(get_config(arch), layers=2, d_model=32, vocab=128)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    proto = _mk_reqs(cfg, 5, rng)
    ref = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
           for r in proto]
    ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                kv_block_size=0).run(ref)
    assert all(r.done for r in ref)
    paged = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
             for r in proto]
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                      kv_block_size=block, prefill_chunk=chunk)
    assert eng.paged
    eng.run(paged)
    assert _outs(paged) == _outs(ref)


def test_block_table_permutation_invariance():
    """Metamorphic: physically relabeling the pool blocks mid-run (tables
    remapped accordingly) must not change any greedy token — the table
    indirection is the only consumer of physical block ids."""
    cfg = moe_cfg()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(11)
    base = _mk_reqs(cfg, 4, rng, max_new=6)

    def run_perm(permute: bool):
        reqs = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                for r in base]
        eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                          kv_block_size=4, prefill_chunk=3)
        pending = list(reqs)
        for _ in range(64):
            while pending and eng.n_active < eng.slots:
                eng.admit(pending.pop(0))
            if permute and _ == 3:        # mid-flight relabel
                perm = np.random.default_rng(5).permutation(
                    eng.kv.n_blocks)
                eng.kv.permute_physical_blocks(perm)
            if eng.step() == 0 and not pending:
                break
        assert all(r.done for r in reqs)
        return _outs(reqs)

    assert run_perm(True) == run_perm(False)


def test_prefix_cache_shares_blocks_and_skips_dispatch(monkeypatch):
    """Shared-prefix requests hit the content-hash index: the cached
    tokens never enter a dispatch plan (fewer/smaller prefill plans,
    counted via plan_dispatch) and outputs are unchanged."""
    cfg = moe_cfg()
    params = init_params(cfg, jax.random.key(0))
    rc = RunConfig(q_chunk=16, kv_chunk=16, schedule_policy="dynamic",
                   moe_stats=True, unroll=True)
    prefix = list(range(1, 9))                    # 8 tokens = 2 blocks of 4
    rng = np.random.default_rng(13)
    proto = _mk_reqs(cfg, 3, rng, lo=2, hi=4, max_new=4, prefix=prefix)

    def run(prefix_cache: bool):
        reqs = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                for r in proto]
        eng = ServeEngine(cfg, params, slots=1, capacity=32, rc=rc,
                          kv_block_size=4, prefill_chunk=64,
                          prefix_cache=prefix_cache)
        eng.run(reqs)
        assert all(r.done for r in reqs)
        return reqs

    calls_on = _count_plans(monkeypatch)
    reqs_on = run(True)
    tokens_on = sum(calls_on)
    # later same-prefix requests served 8 tokens from shared blocks
    assert reqs_on[0].stats["serve/prefix_hit_tokens"] == 0.0
    for r in reqs_on[1:]:
        assert r.stats["serve/prefix_hit_tokens"] == 8.0
    calls_on.clear()
    reqs_off = run(False)
    tokens_off = sum(calls_on)
    assert tokens_off > tokens_on      # cached tokens never dispatched
    assert _outs(reqs_on) == _outs(reqs_off)   # ... with identical tokens


def test_prefix_cache_survives_retirement():
    """Blocks of a retired request park in the LRU pool and are revived by
    a later same-prefix admission (hit across non-overlapping lifetimes)."""
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    prompt = np.arange(1, 11, dtype=np.int32)     # 10 tokens, bs=4: 2 full
    eng = ServeEngine(cfg, params, slots=1, capacity=32, rc=RC,
                      kv_block_size=4)
    a = Request(rid=0, prompt=prompt, max_new=3)
    eng.run([a])
    assert a.done and eng.n_active == 0
    b = Request(rid=1, prompt=prompt.copy(), max_new=3)
    eng.run([b])
    assert b.stats["serve/prefix_hit_tokens"] == 8.0
    assert b.out == a.out                          # revived KV is identical
    assert eng.kv.stats()["prefix_hits"] == 2


def test_chunked_prefill_rides_decode_plan(monkeypatch):
    """A long prompt admitted while another slot decodes: each step's
    single plan covers decode token + prefill chunk together — decode
    never stalls (it yields a token every step) and the plan token count
    is 1 + chunk."""
    cfg = moe_cfg(layers=3)
    params = init_params(cfg, jax.random.key(0))
    rc = RunConfig(q_chunk=16, kv_chunk=16, schedule_policy="dynamic",
                   unroll=True)
    calls = _count_plans(monkeypatch)
    eng = ServeEngine(cfg, params, slots=2, capacity=64, rc=rc,
                      kv_block_size=8, prefill_chunk=4, prefix_cache=False)
    short = Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new=32)
    eng.admit(short)
    eng.step()                                    # short's prompt chunk
    n_before = len(short.out)
    long = Request(rid=1,
                   prompt=np.arange(2, 2 + 13, dtype=np.int32),  # 13 toks
                   max_new=4)
    assert eng.admit(long)
    calls.clear()
    n_moe = cfg.n_layers - cfg.moe.first_dense_layers
    for expected_chunk in (4, 4, 4, 1):           # 13 = 4+4+4+1
        assert eng.step() == 1 + expected_chunk
        assert calls[-n_moe:] == [1 + expected_chunk] * n_moe \
            or calls == []                        # (jit cache: no retrace)
        calls.clear()
    # the short request decoded one token in EVERY mixed step
    assert len(short.out) == n_before + 4
    assert len(long.out) == 1                     # first token just sampled


def test_paged_rejects_unpageable_family_and_falls_back():
    cfg = reduced(get_config("rwkv6-1.6b"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=16, rc=RC)
    assert not eng.paged                          # auto fallback
    with pytest.raises(ValueError, match="non-pageable"):
        ServeEngine(cfg, params, slots=1, capacity=16, rc=RC,
                    kv_block_size=8)


def test_paged_prompt_exceeding_capacity_raises_at_admission():
    """Over-long prompts fail loudly BEFORE claiming a slot: a mid-step
    failure would take every active request's state down with it."""
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=8, rc=RC,
                      kv_block_size=4)
    req = Request(rid=0, prompt=np.arange(1, 12, dtype=np.int32), max_new=2)
    with pytest.raises(ValueError, match="exceeds slot capacity"):
        eng.admit(req)
    assert eng.n_active == 0 and not req.out     # nothing claimed
    # capacity NOT a multiple of block size: CAPACITY governs, not the
    # block-rounded table (a prompt in the rounding slack would diverge
    # from the contiguous engine's (slots, capacity) rows)
    eng10 = ServeEngine(cfg, params, slots=1, capacity=10, rc=RC,
                        kv_block_size=4)
    with pytest.raises(ValueError, match="exceeds slot capacity"):
        eng10.admit(Request(rid=1, prompt=np.arange(1, 13, dtype=np.int32),
                            max_new=2))
    ok = Request(rid=2, prompt=np.arange(1, 9, dtype=np.int32), max_new=3)
    ref = Request(rid=2, prompt=ok.prompt, max_new=3)
    ServeEngine(cfg, params, slots=1, capacity=10, rc=RC,
                kv_block_size=0).run([ref])
    eng10.run([ok])
    assert ok.done and ok.out == ref.out


def test_paged_prompt_at_exact_capacity_matches_contiguous():
    """A prompt that exactly fills the slot's blocks: the capacity-edge
    decode write is dropped (like the contiguous cache's out-of-bounds
    scatter) and the request retires by the same `capacity - 1` rule —
    token-identical outputs, no crash, other slots unaffected."""
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    prompt = np.arange(1, 9, dtype=np.int32)     # 8 == 2 blocks x 4
    other = Request(rid=1, prompt=np.asarray([9, 3], np.int32), max_new=4)
    ref = Request(rid=0, prompt=prompt, max_new=4)
    ref_other = Request(rid=1, prompt=other.prompt, max_new=4)
    ServeEngine(cfg, params, slots=2, capacity=8, rc=RC,
                kv_block_size=0).run([ref, ref_other])
    req = Request(rid=0, prompt=prompt, max_new=4)
    eng = ServeEngine(cfg, params, slots=2, capacity=8, rc=RC,
                      kv_block_size=4)
    eng.run([req, other])
    assert req.done and req.out == ref.out
    assert other.done and other.out == ref_other.out


def test_admission_order_determinism_paged():
    """Prefix sharing must not make outputs depend on who computed the
    shared blocks first: any admission order yields identical tokens."""
    cfg = reduced(get_config("smollm-360m"), layers=2, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    prefix = list(range(3, 12))
    rng = np.random.default_rng(17)
    proto = _mk_reqs(cfg, 4, rng, lo=2, hi=5, max_new=4, prefix=prefix)

    def run_order(order):
        reqs = {r.rid: Request(rid=r.rid, prompt=r.prompt,
                               max_new=r.max_new) for r in proto}
        eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                          kv_block_size=4, prefill_chunk=3)
        eng.run([reqs[i] for i in order])
        assert all(r.done for r in reqs.values())
        return {i: r.out for i, r in reqs.items()}

    base = run_order([0, 1, 2, 3])
    assert run_order([3, 1, 0, 2]) == base
    assert run_order([2, 3, 1, 0]) == base


def test_prefix_hit_admission_policy():
    """The prefix_hit policy admits the pending request with the longest
    currently-cached prefix first (FCFS on a cold cache / contiguous
    engine), consulting the paged engine's read-only probe."""
    from repro.serve.admission import get_admission
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=32, rc=RC,
                      kv_block_size=4)
    warm_prefix = np.arange(1, 9, dtype=np.int32)          # 2 full blocks
    seed = Request(rid=0, prompt=np.concatenate(
        [warm_prefix, [9]]).astype(np.int32), max_new=2)
    eng.run([seed])                                        # registers blocks
    assert eng.kv.probe_prefix(seed.prompt) == 8
    cold = Request(rid=1, prompt=np.asarray([20, 21], np.int32), max_new=2)
    warm = Request(rid=2, prompt=np.concatenate(
        [warm_prefix, [30, 31]]).astype(np.int32), max_new=2)
    policy = get_admission("prefix_hit")
    assert policy([cold, warm], engine=eng) == 1           # warm first
    assert policy([cold, warm]) == 0                       # no engine: fcfs
    # end-to-end: warm admitted first and actually hits
    eng2 = ServeEngine(cfg, params, slots=1, capacity=32, rc=RC,
                       kv_block_size=4, admission="prefix_hit")
    eng2.run([Request(rid=0, prompt=seed.prompt, max_new=2)])
    done = eng2.run([cold, warm])
    assert len(done) == 2
    assert warm.stats["serve/prefix_hit_tokens"] == 8.0


# ----------------------------------------------------------------------
# Open-stream front-end: token streaming + preempt/resume (DESIGN.md §11)
# ----------------------------------------------------------------------
from tests.hypothesis_compat import given, settings, st  # noqa: E402


def _dense_cfg():
    return reduced(get_config("smollm-360m"), layers=1, d_model=32)


@pytest.mark.parametrize("mkcfg", [_dense_cfg, moe_cfg],
                         ids=["dense", "moe"])
@pytest.mark.parametrize("kvb", [4, 0], ids=["paged", "contiguous"])
def test_streaming_parity_with_closed_batch(mkcfg, kvb):
    """Streamed tokens (frontend submit/poll + on_token callbacks) are
    bitwise-identical to the closed-batch ``run()`` output, dense and
    MoE, paged and contiguous — streaming taps the step's one host sync
    and never adds device work."""
    from repro.serve.frontend import ServingFrontend
    cfg = mkcfg()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(11)
    proto = _mk_reqs(cfg, 4, rng, max_new=4)

    ref = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
           for r in proto]
    ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                kv_block_size=kvb).run(ref)
    ref_outs = _outs(ref)

    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                      kv_block_size=kvb)
    fe = ServingFrontend(eng)
    streamed = {}
    handles = [fe.submit(r.prompt, max_new=r.max_new, rid=r.rid,
                         on_token=lambda req, tok:
                         streamed.setdefault(req.rid, []).append(tok))
               for r in proto]
    done = fe.drain()
    assert len(done) == 4 and all(r.done for r in handles)
    assert _outs(handles) == ref_outs      # final outputs identical
    assert streamed == ref_outs            # ...and so is the live stream


@pytest.mark.parametrize("kvb", [4, 0], ids=["paged", "contiguous"])
def test_preempt_resume_token_identity(kvb):
    """A request preempted mid-decode and later resumed produces output
    bitwise-identical to an uninterrupted run (paged: host-side table
    park; contiguous: greedy re-prefill of prompt + emitted tokens)."""
    cfg = moe_cfg()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(5)
    proto = _mk_reqs(cfg, 4, rng, max_new=5)
    ref = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
           for r in proto]
    ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                kv_block_size=kvb).run(ref)

    reqs = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
            for r in proto]
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                      kv_block_size=kvb)
    pending = eng.enqueue(reqs)
    eng.schedule(pending)
    for _ in range(2):
        eng.step()
    victim = eng.preempt(0)
    assert not victim.done
    assert victim.stats.get("serve/preempted") == 1.0     # censored marker
    assert all(np.isfinite(v) for v in victim.stats.values())
    pending.append(victim)
    for _ in range(200):
        eng.schedule(pending)
        if eng.step() == 0 and not pending:
            break
    assert all(r.done for r in reqs)
    assert _outs(reqs) == _outs(ref)
    assert eng.n_preempted == 1 and eng.n_resumed == 1
    # the preempted request's completion stats replace the censored ones
    assert "serve/preempted" not in victim.stats or victim.done


_FUZZ_CFG = None


def _fuzz_setup():
    """Shared (cfg, params, reference outs) for the fuzzed preemption
    property — built once so hypothesis examples reuse the jit cache."""
    global _FUZZ_CFG
    if _FUZZ_CFG is None:
        cfg = _dense_cfg()
        params = init_params(cfg, jax.random.key(0))
        rng = np.random.default_rng(23)
        proto = _mk_reqs(cfg, 5, rng, max_new=6)
        refs = {}
        for kvb in (4, 0):
            ref = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                   for r in proto]
            ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                        kv_block_size=kvb).run(ref)
            refs[kvb] = _outs(ref)
        assert refs[4] == refs[0]
        _FUZZ_CFG = (cfg, params, proto, refs[4])
    return _FUZZ_CFG


@settings(max_examples=8, deadline=None)
@given(steps_a=st.integers(min_value=0, max_value=4),
       slot=st.integers(min_value=0, max_value=1),
       steps_b=st.integers(min_value=0, max_value=4),
       kvb=st.sampled_from([4, 0]))
def test_fuzzed_preemption_points_token_identity(steps_a, slot, steps_b,
                                                 kvb):
    """Churn-suite extension: preempt at FUZZED points — after
    ``steps_a`` steps evict ``slot``, run ``steps_b`` more steps, evict
    slot 0 again (possibly a resumed request, possibly mid-prefill) —
    final outputs must equal the uninterrupted batch, paged and
    contiguous."""
    cfg, params, proto, ref_outs = _fuzz_setup()
    reqs = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
            for r in proto]
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                      kv_block_size=kvb)
    pending = eng.enqueue(reqs)

    def run_steps(n):
        for _ in range(n):
            eng.schedule(pending)
            if eng.step() == 0 and not pending:
                return
    run_steps(steps_a)
    if eng.n_active > slot:
        pending.append(eng.preempt(slot))
    run_steps(steps_b)
    if eng.n_active > 0:
        pending.append(eng.preempt(0))
    for _ in range(300):
        eng.schedule(pending)
        if eng.step() == 0 and not pending:
            break
    assert all(r.done for r in reqs)
    assert _outs(reqs) == ref_outs
    assert eng.n_resumed == eng.n_preempted


def test_park_reclaim_falls_back_to_replay():
    """Under pool pressure the paged cache reclaims parked tables (LRU)
    instead of failing allocation; the evicted request still resumes —
    via replay re-prefill — with identical tokens."""
    cfg = _dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(9)
    # prompts long enough that two active slots need the whole pool
    proto = _mk_reqs(cfg, 3, rng, lo=10, hi=12, max_new=4)
    ref = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
           for r in proto]
    ServeEngine(cfg, params, slots=2, capacity=16, rc=RC,
                kv_block_size=4, prefix_cache=False).run(ref)

    reqs = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
            for r in proto]
    eng = ServeEngine(cfg, params, slots=2, capacity=16, rc=RC,
                      kv_block_size=4, prefix_cache=False)
    pending = eng.enqueue(reqs)
    eng.schedule(pending)
    for _ in range(2):
        eng.step()
    pending.append(eng.preempt(0))            # parks a table, KV pinned
    assert eng.kv.stats()["parked_tables"] == 1
    for _ in range(300):                      # pool pressure reclaims it
        eng.schedule(pending)
        if eng.step() == 0 and not pending:
            break
    assert eng.kv.park_reclaims >= 1
    assert all(r.done for r in reqs)
    assert _outs(reqs) == _outs(ref)
