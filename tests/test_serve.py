"""Serving engine: greedy generation via the slot engine == teacher-forced
argmax continuation; slot reuse under more requests than slots."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import RunConfig, forward, init_cache, init_params
from repro.serve.engine import Request, ServeEngine

RC = RunConfig(q_chunk=16, kv_chunk=16)


def greedy_reference(cfg, params, prompt, n_new):
    """Teacher-forced reference: repeatedly prefill the growing sequence."""
    toks = list(prompt)
    for _ in range(n_new):
        cache = init_cache(cfg, 1, len(toks) + 1)
        logits, _, _ = forward(params, cfg, RC,
                               {"tokens": jnp.asarray([toks], jnp.int32)},
                               mode="prefill", cache=cache)
        toks.append(int(jnp.argmax(logits, -1)[0]))
    return toks[len(prompt):]


def test_engine_matches_teacher_forcing():
    cfg = reduced(get_config("smollm-360m"), layers=2, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    prompt = np.asarray([1, 5, 9, 2], np.int32)
    n_new = 5
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=RC)
    req = Request(rid=0, prompt=prompt, max_new=n_new)
    eng.run([req])
    ref = greedy_reference(cfg, params, prompt, n_new)
    assert req.out == ref, (req.out, ref)


def test_slot_reuse_many_requests():
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    reqs = [Request(rid=i, prompt=np.asarray([i + 1, i + 2], np.int32),
                    max_new=3) for i in range(5)]
    eng = ServeEngine(cfg, params, slots=2, capacity=16, rc=RC)
    done = eng.run(reqs, max_steps=64)
    assert len(done) == len(reqs)          # no request lost or unfinished
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)


def test_engine_decode_isolated_between_slots():
    """Two different prompts decoded concurrently must match their solo
    runs (cache isolation across slots)."""
    cfg = reduced(get_config("smollm-360m"), layers=2, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    p1 = np.asarray([3, 1, 4], np.int32)
    p2 = np.asarray([2, 7, 1, 8], np.int32)
    solo = []
    for p in (p1, p2):
        r = Request(rid=0, prompt=p, max_new=4)
        ServeEngine(cfg, params, slots=1, capacity=32, rc=RC).run([r])
        solo.append(r.out)
    r1, r2 = (Request(rid=1, prompt=p1, max_new=4),
              Request(rid=2, prompt=p2, max_new=4))
    ServeEngine(cfg, params, slots=2, capacity=32, rc=RC).run([r1, r2])
    assert [r1.out, r2.out] == solo
