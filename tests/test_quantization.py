"""Unified quantization API (ISSUE 4): scheme registry, pytree QuantTensor,
executor dequant contract, serving parity, checkpoint round-trip,
deprecation shims.

Acceptance matrix: every registered scheme x executor {xla, pallas} x
policy {fixed, dynamic} on the paper MoE configs stays inside the
scheme's DECLARED relative-error bound vs the fp32 dense oracle; the
``none`` scheme is bitwise-identical to the unquantized path; and the
pre-existing int8 serving path is reproduced exactly by ``int8_expert``
(greedy-token parity through ServeEngine).
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.configs.paper import PAPER_CONFIGS
from repro.core import apply_moe, dispatch_config, init_moe_params
from repro.execution import available_executors, get_executor
from repro.quantization import (QuantTensor, available_schemes,
                                expert_weights, get_scheme, is_quantized,
                                params_scheme, quantize_moe_params,
                                quantize_params_tree, resolve_quant_cli)
from repro.quantization.schemes import pack_int4, unpack_int4

QUANT_SCHEMES = [s for s in available_schemes() if s != "none"]


def shrunk_paper_moe(name: str) -> MoEConfig:
    """A paper Table-1 config shrunk to CPU size, preserving its routing
    structure (gating flavor, top_k, expert-count ordering)."""
    p = PAPER_CONFIGS[name]
    return MoEConfig(n_experts=min(p.n_experts, 16),
                     top_k=min(p.top_k, 4), d_ff_expert=32,
                     gating=p.gating, block_m=8)


def make_quant_layer(moe: MoEConfig, scheme: str, d_model: int = 16,
                     seed: int = 0):
    params = init_moe_params(jax.random.key(seed), moe, d_model)
    x = jax.random.normal(jax.random.key(seed + 1), (2, 32, d_model))
    qp = quantize_moe_params(params, scheme) if scheme != "none" else params
    return params, qp, x


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------
def test_registry_contents():
    assert available_schemes() == ["int4_packed", "int8_channel",
                                   "int8_expert", "none"]
    with pytest.raises(ValueError, match=r"unknown quant scheme 'fp8'"):
        get_scheme("fp8")
    # declared contracts are ordered the way the layouts imply
    assert get_scheme("int8_channel").rel_error_bound \
        <= get_scheme("int8_expert").rel_error_bound \
        < get_scheme("int4_packed").rel_error_bound
    assert get_scheme("int4_packed").bits == 4
    assert get_scheme("none").kernel_format == "dense"


def test_int4_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    q4 = jnp.asarray(rng.integers(-7, 8, size=(3, 5, 10, 7)))
    packed = pack_int4(q4)
    assert packed.shape == (3, 5, 5, 7) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)),
                                  np.asarray(q4))


@pytest.mark.parametrize("scheme", QUANT_SCHEMES)
def test_scheme_lifecycle(scheme):
    """quantize -> logical shape preserved, per-block dequant == full
    materialization, stored payload strictly smaller than dense fp32."""
    w = jax.random.normal(jax.random.key(0), (8, 16, 24)) * 0.3
    qt = get_scheme(scheme).quantize(w)
    assert isinstance(qt, QuantTensor)
    assert qt.scheme == scheme and qt.shape == (8, 16, 24)
    full = qt.materialize()
    assert full.shape == (8, 16, 24)
    np.testing.assert_array_equal(np.asarray(qt[5]), np.asarray(full[5]))
    assert qt.nbytes < w.size * 4
    # weight-level error within the quantization step everywhere
    err = jnp.max(jnp.abs(full - w) / jnp.maximum(qt.s, 1e-12))
    assert float(err) <= 0.51, float(err)


def test_quantize_params_tree_stacked_group_axis():
    from repro.configs import get_config, reduced
    from repro.models import init_params
    cfg = reduced(get_config("moonshot-v1-16b-a3b"))
    for scheme in ("int8_expert", "int4_packed"):
        params = jax.eval_shape(lambda k: quantize_params_tree(
            init_params(cfg, k), scheme), jax.random.key(0))
        moe = params["body"]["b0"]["moe"]
        qt = moe["w_gate"]
        assert isinstance(qt, QuantTensor) and qt.scheme == scheme
        assert qt.q.ndim == 4 and qt.q.dtype == jnp.int8   # (G, E, K, N)
        assert params_scheme(moe) == scheme
        assert moe["router"].dtype == jnp.float32          # untouched
    # 'none' is the identity
    p = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))
    assert quantize_params_tree(p, "none") is p


def test_requantize_guard():
    moe = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8, block_m=8)
    params = init_moe_params(jax.random.key(0), moe, 8)
    qp = quantize_moe_params(params, "int8_expert")
    assert quantize_moe_params(qp, "int8_expert")["w_gate"] is qp["w_gate"]
    with pytest.raises(ValueError, match="already quantized"):
        quantize_moe_params(qp, "int4_packed")


# ----------------------------------------------------------------------
# QuantTensor as a pytree (satellite)
# ----------------------------------------------------------------------
def test_quant_tensor_pytree_roundtrip():
    qt = get_scheme("int8_channel").quantize(
        jax.random.normal(jax.random.key(0), (4, 8, 6)))
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert len(leaves) == 2                      # q and s — dtype is NOT a leaf
    assert leaves[0].dtype == jnp.int8 and leaves[1].dtype == jnp.float32
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(qt2, QuantTensor)
    assert qt2.scheme == qt.scheme and qt2.dtype == qt.dtype
    np.testing.assert_array_equal(np.asarray(qt2.q), np.asarray(qt.q))
    # keyed flattening names the leaves (checkpoint/sharding paths)
    kl, _ = jax.tree_util.tree_flatten_with_path(qt)
    assert [str(p[0]) for p, _ in kl] == [".q", ".s"]


def test_quant_tensor_tree_map_preserves_static_meta():
    qt = get_scheme("int8_expert").quantize(
        jax.random.normal(jax.random.key(0), (4, 8, 6)))
    mapped = jax.tree.map(lambda l: jnp.zeros_like(l), qt)
    assert isinstance(mapped, QuantTensor)
    assert mapped.scheme == "int8_expert" and mapped.dtype == qt.dtype
    assert float(jnp.max(jnp.abs(mapped.q))) == 0.0
    # meta survives a scan-style leading-axis slice too
    stacked = get_scheme("int4_packed").quantize(
        jax.random.normal(jax.random.key(1), (3, 4, 8, 6)))
    sl = jax.tree.map(lambda l: l[2], stacked)
    assert sl.scheme == "int4_packed" and sl.shape == (4, 8, 6)


def test_quant_tensor_jit_retraces_only_on_scheme_change():
    traces = []

    @jax.jit
    def f(qt):
        traces.append(qt.scheme)
        return jnp.sum(qt[0])

    w = jax.random.normal(jax.random.key(0), (4, 8, 6))
    qt = get_scheme("int8_expert").quantize(w)
    f(qt)
    f(jax.tree.map(lambda l: l + 1 - 1, qt))     # new payload, same meta
    assert traces == ["int8_expert"]             # no retrace
    # same leaves, different static scheme tag -> retrace (int8_channel's
    # dequant broadcasts the (E,1,1) scales fine)
    f(QuantTensor(qt.q, qt.s, qt.dtype, "int8_channel"))
    assert traces == ["int8_expert", "int8_channel"]


# ----------------------------------------------------------------------
# QuantTensor property tests (ISSUE 5 satellite): round-trip bound and
# pytree identity for EVERY registered scheme over random shapes — incl.
# the K-odd edge case int4_packed stores with a tagged pad row.
# ----------------------------------------------------------------------
from hypothesis_compat import given, settings, st  # noqa: E402


@st.composite
def stack_shapes(draw):
    lead = draw(st.sampled_from([(), (3,)]))       # optional layer-group axis
    E = draw(st.integers(1, 8))
    K = draw(st.integers(1, 17))                   # odd K included
    N = draw(st.integers(1, 16))
    scheme = draw(st.sampled_from(QUANT_SCHEMES))
    seed = draw(st.integers(0, 2 ** 16))
    scale = draw(st.sampled_from([1e-3, 0.3, 10.0]))
    return lead + (E, K, N), scheme, seed, scale


@given(stack_shapes())
@settings(max_examples=25, deadline=None)
def test_quantize_dequantize_roundtrip_bound(case):
    """Element-wise round-trip error <= half a quantization step of the
    per-element scale, for every scheme at every drawn shape/magnitude —
    including odd K (int4 pad row must not leak into the output)."""
    shape, scheme, seed, scale = case
    w = jax.random.normal(jax.random.key(seed), shape) * scale
    qt = get_scheme(scheme).quantize(w)
    assert qt.shape == shape, (scheme, qt.shape, shape)
    back = qt.materialize()
    assert back.shape == shape
    # error <= half a step of each element's own scale
    err = jnp.abs(back - w) / jnp.maximum(qt.s, 1e-12)
    assert float(jnp.max(err)) <= 0.51, (case, float(jnp.max(err)))
    # per-block gather dequant == materialized slice (odd-K strip incl.)
    idx = (0,) * (len(shape) - 3) + (shape[-3] - 1,)
    np.testing.assert_array_equal(np.asarray(qt[idx]),
                                  np.asarray(back[idx]))


@given(stack_shapes())
@settings(max_examples=15, deadline=None)
def test_quant_tensor_pytree_flatten_unflatten_identity(case):
    """tree_flatten -> tree_unflatten is the identity for every scheme:
    leaves are exactly (q, s), static aux (dtype, scheme, meta) survives,
    and a jit boundary round-trips the tagged tree unchanged."""
    shape, scheme, seed, scale = case
    w = jax.random.normal(jax.random.key(seed), shape) * scale
    qt = get_scheme(scheme).quantize(w)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert len(leaves) == 2
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert (qt2.scheme, qt2.dtype, qt2.meta) == (qt.scheme, qt.dtype,
                                                 qt.meta)
    assert qt2.shape == qt.shape == shape
    np.testing.assert_array_equal(np.asarray(qt2.q), np.asarray(qt.q))
    np.testing.assert_array_equal(np.asarray(qt2.s), np.asarray(qt.s))
    qt3 = jax.jit(lambda t: t)(qt)                 # identity through jit
    assert (qt3.scheme, qt3.meta) == (qt.scheme, qt.meta)
    np.testing.assert_array_equal(np.asarray(qt3.materialize()),
                                  np.asarray(qt.materialize()))


def test_int4_odd_k_padding_edge_case():
    """K odd: the packed payload stores (K+1)//2 byte rows, the pad row is
    tagged in static meta, dequant strips it (shape + values), and the
    kernel operand split falls back to the dense layout rather than
    feeding a padded payload to the in-kernel dequant."""
    from repro.kernels.ops import _weight_operands
    w = jax.random.normal(jax.random.key(3), (4, 7, 6)) * 0.5
    qt = get_scheme("int4_packed").quantize(w)
    assert qt.meta == (("pad_k", 1),)
    assert qt.q.shape == (4, 4, 6)                 # ceil(7/2) byte rows
    assert qt.shape == (4, 7, 6)
    back = qt.materialize()
    assert back.shape == (4, 7, 6)
    np.testing.assert_array_equal(np.asarray(qt[2]), np.asarray(back[2]))
    err = jnp.max(jnp.abs(back - w) / jnp.maximum(qt.s, 1e-12))
    assert float(err) <= 0.51
    wq, ws, fmt, (K, N) = _weight_operands(qt)
    assert fmt == "dense" and (K, N) == (7, 6) and ws is None
    np.testing.assert_array_equal(np.asarray(wq), np.asarray(back))
    # even K stays on the compressed in-kernel path
    qt_even = get_scheme("int4_packed").quantize(w[:, :6, :])
    assert qt_even.meta == ()
    _, _, fmt_even, _ = _weight_operands(qt_even)
    assert fmt_even == "int4"


# ----------------------------------------------------------------------
# Acceptance: scheme x executor x policy on the paper configs
# ----------------------------------------------------------------------
@pytest.mark.parametrize("executor", ["xla", "pallas"])
@pytest.mark.parametrize("paper", sorted(PAPER_CONFIGS))
def test_scheme_error_bounds_on_paper_configs(paper, executor):
    """Quantized layer output stays inside the scheme's declared bound of
    the fp32 dense oracle for every scheme x policy on this config."""
    moe = shrunk_paper_moe(paper)
    for scheme in QUANT_SCHEMES:
        params, qp, x = make_quant_layer(moe, scheme)
        y_ref, _ = apply_moe(params, x, dispatch_config(moe,
                                                        executor="dense"))
        bound = get_scheme(scheme).rel_error_bound
        for policy in ("fixed", "dynamic"):
            cfg = dispatch_config(moe, executor=executor,
                                  schedule_policy=policy)
            y_q, _ = apply_moe(qp, x, cfg)
            rel = float(jnp.max(jnp.abs(y_q - y_ref))
                        / jnp.max(jnp.abs(y_ref)))
            assert rel <= bound, (scheme, policy, rel, bound)


@pytest.mark.parametrize("executor", ["xla", "pallas", "dense"])
def test_none_scheme_bitwise_identical(executor):
    """`none` is the identity: quantize_params_tree returns the very same
    tree, and the capability-contract dispatch path (expert_weights +
    supports_scheme + prepare_weights) is bitwise-equal to calling the
    pipeline on the raw arrays directly."""
    from repro.core.dispatch import moe_ffn
    moe = shrunk_paper_moe("qwen2-moe-57b")
    params, _, x = make_quant_layer(moe, "none")
    assert quantize_params_tree({"blk": params}, "none")["blk"] is params
    for policy in ("fixed", "dynamic"):
        cfg = dispatch_config(moe, executor=executor,
                              schedule_policy=policy)
        y1, _ = apply_moe(params, x, cfg)
        y2, _ = moe_ffn(x.reshape(-1, x.shape[-1]), params["router"],
                        params["w_gate"], params["w_up"], params["w_down"],
                        cfg)
        np.testing.assert_array_equal(np.asarray(y1),
                                      np.asarray(y2.reshape(x.shape)))


def test_in_scan_dequant_matches_materialized_bitwise():
    """The per-block dequant hook (w[be] in the xla scan, in-kernel for
    pallas) produces the SAME values as materializing the dense stack up
    front — the contract that makes int8_expert reproduce the
    pre-redesign serving path exactly."""
    moe = shrunk_paper_moe("mixtral-8x7b")
    for scheme in QUANT_SCHEMES:
        params, qp, x = make_quant_layer(moe, scheme)
        dense_params = dict(qp)
        for k in ("w_gate", "w_up", "w_down"):
            dense_params[k] = qp[k].materialize()
        for executor in ("xla", "pallas"):
            cfg = dispatch_config(moe, executor=executor)
            y_lazy, _ = apply_moe(qp, x, cfg)
            y_dense, _ = apply_moe(dense_params, x, cfg)
            np.testing.assert_array_equal(
                np.asarray(y_lazy), np.asarray(y_dense),
                err_msg=f"{scheme} on {executor}")


def test_executor_capability_contract():
    for name in available_executors():
        ex = get_executor(name)
        for scheme in available_schemes():
            assert ex.supports_scheme(scheme)
        assert not ex.supports_scheme("not-a-scheme")
    # prepare_weights: dense materializes, in-scan backends pass through
    qt = get_scheme("int8_expert").quantize(
        jax.random.normal(jax.random.key(0), (4, 8, 6)))
    w = {"w_gate": qt, "w_up": qt, "w_down": qt}
    out = get_executor("dense").prepare_weights(w, None)
    assert not any(isinstance(v, QuantTensor) for v in out.values())
    for name in ("xla", "pallas"):
        out = get_executor(name).prepare_weights(w, None)
        assert all(v is qt for v in out.values())


def test_unsupported_scheme_raises(monkeypatch):
    from repro.execution import base as exbase
    moe = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8, block_m=8)
    params, qp, x = make_quant_layer(moe, "int4_packed", d_model=8)

    class NoQuant(exbase.Executor):
        def supports_scheme(self, scheme):
            return scheme == "none"

    monkeypatch.setitem(exbase._EXECUTORS, "noquant", NoQuant())
    cfg = dispatch_config(moe, executor="noquant")
    with pytest.raises(ValueError, match="does not support quant scheme"):
        apply_moe(qp, x, cfg)
    y, _ = apply_moe(params, x, cfg._replace(executor="xla"))  # sanity
    assert y.shape == x.shape


def test_expert_weights_dtype_retarget():
    moe = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8, block_m=8)
    params = init_moe_params(jax.random.key(0), moe, 8)
    eff = expert_weights(params, jnp.float32)
    assert eff["w_gate"] is params["w_gate"]         # dense passthrough
    qp = quantize_moe_params(params, "int8_expert")
    assert is_quantized(qp) and not is_quantized(params)
    eff = expert_weights(qp, jnp.bfloat16)
    assert eff["w_gate"].dtype == np.dtype(jnp.bfloat16)
    assert eff["w_gate"][0].dtype == jnp.bfloat16


# ----------------------------------------------------------------------
# Serving parity (acceptance) + engine integration
# ----------------------------------------------------------------------
def _greedy_tokens(cfg, params, rc, prompt, n_new):
    from repro.serve.engine import Request, ServeEngine
    req = Request(rid=0, prompt=prompt, max_new=n_new)
    ServeEngine(cfg, params, slots=2, capacity=32, rc=rc).run([req])
    return req.out


def test_int8_expert_reproduces_preexisting_serving_path():
    """The pre-redesign int8 serving path = quantize at load (same scale
    formula) + dequantized expert blocks in the dispatch scans.  Greedy
    tokens through ServeEngine under int8_expert must match a run on the
    materialized-dequant params exactly, and rc.quant='none' must match
    the unquantized params exactly."""
    from repro.configs import get_config, reduced
    from repro.models import RunConfig, init_params
    cfg = reduced(get_config("moonshot-v1-16b-a3b"), layers=2, d_model=64,
                  vocab=256)
    params = init_params(cfg, jax.random.key(0))
    prompt = np.asarray([3, 7, 11, 2, 9], np.int32)
    rc = RunConfig(q_chunk=16, kv_chunk=16)

    qp = quantize_params_tree(params, "int8_expert")
    dense_deq = jax.tree.map(
        lambda n: n.materialize() if isinstance(n, QuantTensor) else n,
        qp, is_leaf=lambda n: isinstance(n, QuantTensor))

    toks_q = _greedy_tokens(cfg, params, rc._replace(quant="int8_expert"),
                            prompt, 6)
    toks_deq = _greedy_tokens(cfg, dense_deq, rc, prompt, 6)
    assert toks_q == toks_deq
    # none == unquantized, bitwise all the way to tokens
    toks_none = _greedy_tokens(cfg, params, rc._replace(quant="none"),
                               prompt, 6)
    toks_raw = _greedy_tokens(cfg, params, rc, prompt, 6)
    assert toks_none == toks_raw


def test_engine_quantizes_from_runconfig():
    from repro.configs import get_config, reduced
    from repro.models import RunConfig, init_params
    from repro.serve.engine import ServeEngine
    cfg = reduced(get_config("moonshot-v1-16b-a3b"), layers=2, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=16,
                      rc=RunConfig(q_chunk=16, kv_chunk=16,
                                   quant="int4_packed"))
    moe = eng.params["body"]["b0"]["moe"]
    assert params_scheme(moe) == "int4_packed"
    # idempotent: already-tagged params admitted unchanged
    eng2 = ServeEngine(cfg, eng.params, slots=1, capacity=16, rc=eng.rc)
    assert eng2.params["body"]["b0"]["moe"]["w_gate"] is moe["w_gate"]


# ----------------------------------------------------------------------
# Checkpoint round-trip (tentpole: manager handles quantized trees)
# ----------------------------------------------------------------------
def test_checkpoint_roundtrip_quantized_tree(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config, reduced
    from repro.models import init_params
    cfg = reduced(get_config("moonshot-v1-16b-a3b"), layers=2, d_model=32)
    params = quantize_params_tree(init_params(cfg, jax.random.key(0)),
                                  "int4_packed")
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, {"params": params})
    target = jax.eval_shape(lambda: {"params": params})
    restored = mgr.restore(target)["params"]
    moe = restored["body"]["b0"]["moe"]
    qt = moe["w_gate"]
    assert isinstance(qt, QuantTensor) and qt.scheme == "int4_packed"
    assert qt.q.dtype == jnp.int8                 # compressed on disk too
    flat_a = jax.tree_util.tree_leaves(params)
    flat_b = jax.tree_util.tree_leaves(restored)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # quantized checkpoint into a dense target: structure error, loudly
    dense_target = jax.eval_shape(
        lambda: {"params": init_params(cfg, jax.random.key(0))})
    with pytest.raises(ValueError, match="STRUCTURES differ"):
        mgr.restore(dense_target)


# ----------------------------------------------------------------------
# Deprecation coverage (satellite)
# ----------------------------------------------------------------------
def test_quant_experts_flag_deprecated():
    with pytest.warns(DeprecationWarning, match="--quant-experts"):
        assert resolve_quant_cli(None, True) == "int8_expert"
    with pytest.warns(DeprecationWarning):
        # explicit scheme wins over the legacy on/off flag
        assert resolve_quant_cli("int4_packed", True) == "int4_packed"
    with pytest.warns(DeprecationWarning):
        # ... including an EXPLICIT "none" (only an unset --quant maps)
        assert resolve_quant_cli("none", True) == "none"
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_quant_cli(None, False) == "none"
        assert resolve_quant_cli("int8_channel", False) == "int8_channel"
    with pytest.raises(ValueError, match="unknown quant scheme"):
        resolve_quant_cli("int7", False)


def test_dispatch_impl_alias_deprecated():
    from repro.core.dispatch import MoEDispatchConfig
    cfg = MoEDispatchConfig(n_experts=4, top_k=1, executor="pallas")
    with pytest.warns(DeprecationWarning, match="impl is deprecated"):
        assert cfg.impl == "pallas"
    moe = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8, block_m=8)
    with pytest.warns(DeprecationWarning, match=r"impl=.*deprecated"):
        assert dispatch_config(moe, impl="dense").executor == "dense"
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert dispatch_config(moe, executor="xla").executor == "xla"
