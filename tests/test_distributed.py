"""Multi-device tests — each case runs in a SUBPROCESS with
--xla_force_host_platform_device_count=8 so the main pytest process keeps
the plain 1-device CPU (per the dry-run isolation requirement)."""
import json
import pathlib
import subprocess
import sys

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str):
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_ep_dispatch_matches_single_device():
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import apply_moe, dispatch_config, init_moe_params
from repro.core.distributed import apply_moe_ep
from repro.configs.base import MoEConfig
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh, shard_map
mesh = make_debug_mesh(data=2, model=4)
moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared_experts=1, block_m=8)
params = init_moe_params(jax.random.key(0), moe, 16)
x = jax.random.normal(jax.random.key(1), (4, 32, 16))
dcfg = dispatch_config(moe, executor="xla")
y_ref, _ = apply_moe(params, x, dcfg)
with set_mesh(mesh):
    y_ep, _ = jax.jit(lambda p, x: apply_moe_ep(p, x, dcfg, capacity_factor=8.0))(params, x)
    y_r, _ = jax.jit(lambda p, x: apply_moe_ep(p, x, dcfg, token_layout="replicated"))(params, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
print("OK")
""")


def test_ep_capacity_drops_tokens_deterministically():
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import dispatch_config, init_moe_params
from repro.core.distributed import apply_moe_ep
from repro.configs.base import MoEConfig
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh, shard_map
mesh = make_debug_mesh(data=1, model=4)
moe = MoEConfig(n_experts=4, top_k=1, d_ff_expert=16, block_m=8)
params = init_moe_params(jax.random.key(0), moe, 8)
x = jax.random.normal(jax.random.key(1), (1, 64, 8))
# drops belong to the capacity_factor POLICY now; fixed/dynamic never drop
# under the padding-free sharded layout (they never dropped single-device)
dcfg = dispatch_config(moe, executor="xla",
                       schedule_policy="capacity_factor")
with set_mesh(mesh):
    tight, _ = jax.jit(lambda p, x: apply_moe_ep(p, x, dcfg, capacity_factor=0.25))(params, x)
    loose, _ = jax.jit(lambda p, x: apply_moe_ep(p, x, dcfg, capacity_factor=8.0))(params, x)
t, l = np.asarray(tight), np.asarray(loose)
dropped_rows = (np.abs(t).sum(-1) == 0).sum()
assert dropped_rows > 0, "tight capacity must drop some tokens"
# run twice -> identical (deterministic drop policy: lowest slot wins)
with set_mesh(mesh):
    tight2, _ = jax.jit(lambda p, x: apply_moe_ep(p, x, dcfg, capacity_factor=0.25))(params, x)
np.testing.assert_array_equal(t, np.asarray(tight2))
print("OK", int(dropped_rows))
""")


def test_ep_replicated_schedule_policies_match_single_device():
    """capacity_factor / dynamic policies under EP replicated dispatch ==
    the same policy on a single device (global-capacity drop semantics)."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import apply_moe, dispatch_config, init_moe_params
from repro.configs.base import MoEConfig
from repro.core.distributed import apply_moe_ep
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh
mesh = make_debug_mesh(data=1, model=4)
moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, block_m=8,
                capacity_factor=0.5)
params = init_moe_params(jax.random.key(0), moe, 16)
x = jax.random.normal(jax.random.key(1), (1, 64, 16))
for pol in ("capacity_factor", "dynamic"):
    dcfg = dispatch_config(moe, executor="xla", schedule_policy=pol)
    y_ref, _ = apply_moe(params, x, dcfg)
    if pol == "capacity_factor":
        assert float(jnp.max(jnp.abs(
            y_ref - apply_moe(params, x, dcfg._replace(executor="dense"))[0]
        ))) > 1e-6, "cf=0.5 must actually drop tokens"
    with set_mesh(mesh):
        y_r, _ = jax.jit(lambda p, x: apply_moe_ep(
            p, x, dcfg, token_layout="replicated"))(params, x)
    np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
print("OK")
""")


def test_ep_gathers_compressed_bytes_for_every_scheme():
    """Quantized expert params flow through BOTH EP layouts for every
    registered scheme (not just int8): the shard_map partition specs are
    built per leaf, so a QuantTensor's compressed payload + scales shard
    over the EP axis and each rank dequantizes only its own experts'
    blocks.  Output must match the single-device quantized run exactly."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import apply_moe, dispatch_config, init_moe_params
from repro.core.distributed import apply_moe_ep
from repro.configs.base import MoEConfig
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh, shard_map
from repro.quantization import quantize_moe_params

mesh = make_debug_mesh(data=2, model=4)
moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, block_m=8)
params = init_moe_params(jax.random.key(0), moe, 16)
x = jax.random.normal(jax.random.key(1), (4, 32, 16))
for sch in ("int8_expert", "int8_channel", "int4_packed"):
    qp = quantize_moe_params(params, sch)
    dcfg = dispatch_config(moe, executor="xla")
    y_ref, _ = apply_moe(qp, x, dcfg)
    with set_mesh(mesh):
        y_sh, _ = jax.jit(lambda p, x: apply_moe_ep(
            p, x, dcfg, capacity_factor=8.0))(qp, x)
        y_r, _ = jax.jit(lambda p, x: apply_moe_ep(
            p, x, dcfg, token_layout="replicated"))(qp, x)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-6, err_msg=sch)
    np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-6, err_msg=sch)
print("OK")
""")


def test_full_model_sharded_train_step_matches_single_device():
    """qwen2 reduced: jitted sharded train step on a 2x4 mesh == unsharded."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduced
from repro.models import RunConfig
from repro.train.step import init_train_state, make_train_step
from repro.optim.adamw import OptConfig
from repro.data.pipeline import make_batch
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh, shard_map
from repro.distributed.sharding import param_specs, batch_specs
from repro.distributed.ctx import use_rules
from repro.distributed.sharding import activation_rules

cfg = reduced(get_config("qwen2-7b"), layers=2, d_model=64, n_heads=4)
rc = RunConfig(q_chunk=0, kv_chunk=16, loss_chunk=16)
opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10, weight_decay=0.0)
state = init_train_state(cfg, jax.random.key(0), rc)
batch = make_batch(cfg, 8, 32, step=0)

s_ref, m_ref = jax.jit(make_train_step(cfg, rc, opt, 1))(state, batch)

mesh = make_debug_mesh(data=2, model=4)
ps = param_specs(state["params"], cfg, mesh)
ss = {"params": ps, "opt": {"m": ps, "v": ps, "step": P()}}
bs = batch_specs(cfg, mesh, "train", 8)
ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
with set_mesh(mesh), use_rules(mesh, activation_rules(cfg, mesh, "train", 8)):
    f = jax.jit(make_train_step(cfg, rc, opt, 1),
                in_shardings=(ns(ss), ns(bs)), out_shardings=(ns(ss), None))
    s_sh, m_sh = f(jax.device_put(state, ns(ss)),
                   {k: jax.device_put(v, ns(bs)[k]) for k, v in batch.items()})
assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-4
d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                 s_ref["params"], jax.device_get(s_sh["params"]))
assert max(jax.tree.leaves(d)) < 1e-4, max(jax.tree.leaves(d))
print("OK")
""")


def test_elastic_restore_to_different_mesh(tmp_path):
    """Checkpoint on 1 device -> restore sharded on 8 (elastic re-shard)."""
    run_sub(f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh, shard_map
m = CheckpointManager(r"{tmp_path}", async_save=False)
state = {{"w": jnp.arange(32.0).reshape(8, 4), "step": jnp.int32(7)}}
m.save(7, state)
mesh = make_debug_mesh(data=2, model=4)
sh = {{"w": NamedSharding(mesh, P("data", "model")),
      "step": NamedSharding(mesh, P())}}
restored = m.restore(state, shardings=sh)
assert restored["w"].sharding.spec == P("data", "model")
np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(state["w"]))
print("OK")
""")


def test_compressed_psum_pod_axis():
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.optim.compress import compressed_psum
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh, shard_map
mesh = make_debug_mesh(data=1, model=1, pod=8)
g = jax.random.normal(jax.random.key(0), (8, 64))
def body(gl):
    return compressed_psum(gl[0], "pod")[None]
with set_mesh(mesh):
    out = jax.jit(shard_map(body, mesh=mesh,
        in_specs=P("pod", None), out_specs=P("pod", None)))(g)
ref = jnp.sum(g, 0)
got = np.asarray(out)[0]
rel = np.abs(got - np.asarray(ref)).max() / (np.abs(np.asarray(ref)).max())
assert rel < 2e-2, rel   # int8 quantization tolerance
print("OK", rel)
""")


def test_flash_decode_shard_map_combine():
    """Explicit shard_map LSE combine over seq-sharded KV == full attn."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.models.attention import flash_attention, combine_stats, naive_attention
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh, shard_map
mesh = make_debug_mesh(data=2, model=4)
B, S, H, D = 4, 64, 4, 16
ks = jax.random.split(jax.random.key(0), 3)
q = jax.random.normal(ks[0], (B, 1, H, D))
k = jax.random.normal(ks[1], (B, S, H, D))
v = jax.random.normal(ks[2], (B, S, H, D))
pos = jnp.int32(S - 1)
def local(q, k, v):
    idx = jax.lax.axis_index("model")
    off = idx * k.shape[1]
    acc, l, m = flash_attention(q, k, v, causal=False, kv_limit=pos,
                                kv_offset=off, q_chunk=1, kv_chunk=16,
                                return_stats=True)
    out = combine_stats(acc, l, m, "model")
    return jnp.moveaxis(out, 3, 1).reshape(q.shape[0], 1, -1, out.shape[-1])
with set_mesh(mesh):
    f = jax.jit(shard_map(local, mesh=mesh,
        in_specs=(P("data", None, None, None), P("data", "model", None, None),
                  P("data", "model", None, None)),
        out_specs=P("data", None, None, None)))
    out = f(q, k, v)
ref = naive_attention(q, k, v, causal=False, kv_limit=pos)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)
print("OK")
""")


# ----------------------------------------------------------------------
# Padding-free sharded EP (ISSUE 10): policy-honoring dispatch
# ----------------------------------------------------------------------
def test_ep_sharded_policies_match_single_device_with_drops():
    """Every schedule policy produces the SAME outputs, drop set, and
    ScheduleStats under the padding-free sharded layout, the overlapped
    variant, and the replicated layout as on a single device — including
    the capacity_factor drop regime (cf=0.5 drops half the assignments).
    fixed/dynamic use a token-sharded 2x4 mesh; the capacity cell uses
    data=1 (capacity semantics are per data shard, matching GShard)."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import apply_moe, dispatch_config, init_moe_params
from repro.configs.base import MoEConfig
from repro.core.distributed import apply_moe_ep
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh
moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, block_m=8,
                capacity_factor=0.5)
params = init_moe_params(jax.random.key(0), moe, 16)
x = jax.random.normal(jax.random.key(1), (4, 32, 16))
for pol in ("fixed", "dynamic", "capacity_factor"):
    mesh = make_debug_mesh(data=1 if pol == "capacity_factor" else 2,
                           model=4)
    dcfg = dispatch_config(moe, executor="xla", schedule_policy=pol,
                           emit_stats=True)
    y_ref, aux_ref = apply_moe(params, x, dcfg)
    with set_mesh(mesh):
        run = lambda **kw: jax.jit(lambda p, x: apply_moe_ep(
            p, x, dcfg, **kw))(params, x)
        y_sh, aux_sh = run()
        y_ov, aux_ov = run(overlap=2)
        y_rp, aux_rp = run(token_layout="replicated")
    for tag, y in (("sharded", y_sh), ("overlap", y_ov),
                   ("replicated", y_rp)):
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"{tag} {pol}")
    # stats parity: drops + useful rows are GLOBAL totals = single-device
    for k in ("sched/dropped_rows", "sched/useful_rows"):
        ref_v = float(aux_ref[k])
        for tag, aux in (("sharded", aux_sh), ("overlap", aux_ov),
                         ("replicated", aux_rp)):
            assert float(aux[k]) == ref_v, (pol, tag, k, float(aux[k]),
                                            ref_v)
    if pol == "capacity_factor":
        assert float(aux_sh["sched/dropped_rows"]) > 0, \
            "cf=0.5 cell must exercise the drop regime"
print("OK")
""")


def test_ep_overlap_token_identical_to_non_overlapped():
    """The overlapped dispatch is token-identical to the non-overlapped
    path on the same mesh (full-batch routing + drop decisions are made
    BEFORE chunking), and overlap=0 goes down the literal n_micro=1
    straight-line code path."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import dispatch_config, init_moe_params
from repro.configs.base import MoEConfig
from repro.core.distributed import apply_moe_ep
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh
mesh = make_debug_mesh(data=2, model=4)
moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, block_m=8,
                capacity_factor=0.5)
params = init_moe_params(jax.random.key(0), moe, 16)
x = jax.random.normal(jax.random.key(1), (4, 32, 16))
for pol in ("fixed", "dynamic", "capacity_factor"):
    dcfg = dispatch_config(moe, executor="xla", schedule_policy=pol)
    with set_mesh(mesh):
        y0, _ = jax.jit(lambda p, x: apply_moe_ep(p, x, dcfg))(params, x)
        for n_micro in (2, 4):
            y1, _ = jax.jit(lambda p, x, n=n_micro: apply_moe_ep(
                p, x, dcfg, overlap=n))(params, x)
            np.testing.assert_allclose(
                np.asarray(y1), np.asarray(y0), rtol=1e-6, atol=1e-6,
                err_msg=f"{pol} n_micro={n_micro}")
print("OK")
""")


def test_ep_serve_engine_counts_dropped_tokens():
    """EP serving surfaces dispatch drops: with moe_stats on, retired
    requests carry the ``sched/*`` keys and the obs registry exposes the
    ``serve/ep_dropped_tokens`` counter (satellite: the skew table stays
    honest under EP)."""
    run_sub("""
import numpy as np, jax
from repro.configs import get_config, reduced
from repro.models import RunConfig
from repro.obs import Observability
from repro.serve.engine import Request, ServeEngine
from repro.serve.distributed import DistributedServeLoop
from repro.launch.mesh import make_ep_mesh
from repro.compat import set_mesh
cfg = reduced(get_config("moonshot-v1-16b-a3b"))
from repro.models import init_params
params = init_params(cfg, jax.random.key(0))
rc = RunConfig(q_chunk=64, kv_chunk=64, ep=True, moe_stats=True,
               schedule_policy="capacity_factor", capacity_factor=0.5)
obs = Observability.memory()
with set_mesh(make_ep_mesh(2)):
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=rc, obs=obs)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5)
                    .astype(np.int32), max_new=3) for i in range(3)]
    done = DistributedServeLoop(eng, n_hosts=2).run(reqs, max_steps=64)
assert len(done) == 3, [r.done for r in reqs]
for r in done:
    assert "sched/dropped_rows" in r.stats, sorted(r.stats)
names = {c["name"] for c in obs.metrics.snapshot()["counters"]}
assert "serve/ep_dropped_tokens" in names, sorted(names)
print("OK")
""")


def test_distributed_serve_loop_matches_engine_run():
    """Single-host sanity (no mesh): the per-host admission loop with
    n_hosts=1 completes the same request set as ServeEngine.run, and the
    round-robin partition is deterministic."""
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models import RunConfig, init_params
    from repro.serve.distributed import (DistributedServeLoop,
                                         partition_requests)
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced(get_config("moonshot-v1-16b-a3b"))
    params = init_params(cfg, None or __import__("jax").random.key(0))
    rng = np.random.default_rng(0)

    def mk_reqs():
        return [Request(rid=i, prompt=np.arange(3 + i % 2,
                                                dtype=np.int32),
                        max_new=3) for i in range(4)]

    rc = RunConfig(q_chunk=64, kv_chunk=64)
    ref = ServeEngine(cfg, params, slots=2, capacity=32, rc=rc) \
        .run(mk_reqs(), max_steps=64)
    reqs = mk_reqs()
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=rc)
    done = DistributedServeLoop(eng, n_hosts=2).run(reqs, max_steps=64)
    assert len(done) == len(ref) == 4
    assert {r.rid: r.out for r in done} == {r.rid: r.out for r in ref}

    parts = partition_requests(reqs, 3)
    assert [len(p) for p in parts] == [2, 1, 1]
    assert [r.rid for r in parts[0]] == [0, 3]
    import pytest
    with pytest.raises(ValueError):
        partition_requests(reqs, 0)


def test_static_schedule_alignment_guard():
    """_static_schedule refuses unaligned capacities loudly instead of
    silently misassigning block_expert (satellite bugfix)."""
    from repro.core.distributed import _static_schedule

    s = _static_schedule(32, 4, 8, 8)             # aligned: fine
    assert int(s.capacity) == 32
    with pytest.raises(ValueError, match="block_m-aligned"):
        _static_schedule(36, 4, 8, 9)             # 9 % 8 != 0
    with pytest.raises(ValueError, match="block_m-aligned"):
        _static_schedule(34, 2, 8, 16)            # rows 34 % 8 != 0


def test_capacity_factor_resolution_order():
    """apply_moe_ep resolves capacity headroom as
    ``explicit arg > cfg.capacity_factor`` — the ONE documented order
    (satellite bugfix: removes the PR 1 'pass 2.0 explicitly' footgun)."""
    from repro.configs.base import MoEConfig
    from repro.core import dispatch_config
    from repro.core.distributed import _resolve_capacity_factor

    moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, block_m=8,
                    capacity_factor=1.5)
    cfg = dispatch_config(moe, executor="xla")
    assert cfg.capacity_factor == 1.5             # defaulted from MoEConfig
    assert _resolve_capacity_factor(cfg, None) == 1.5
    assert _resolve_capacity_factor(cfg, 0.25) == 0.25
    cfg2 = dispatch_config(moe, executor="xla", capacity_factor=3.0)
    assert _resolve_capacity_factor(cfg2, None) == 3.0
