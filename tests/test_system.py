"""End-to-end system behaviour: train -> checkpoint -> restore -> serve
with the SAME weights, exercising the full stack (data pipeline, loop,
optimizer, checkpoint manager, serving engine) in one flow."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import RunConfig, forward, init_cache
from repro.optim.adamw import OptConfig
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import train

RC = RunConfig(q_chunk=16, kv_chunk=16, loss_chunk=32)


def test_train_checkpoint_serve_roundtrip(tmp_path):
    cfg = reduced(get_config("smollm-360m"), layers=2, d_model=64, vocab=64)
    opt = OptConfig(lr=1e-2, warmup_steps=5, total_steps=60,
                    weight_decay=0.0)
    out = train(cfg, RC, opt, steps=30, batch=8, seq=64,
                ckpt_dir=str(tmp_path), save_every=10, log_every=10,
                log=lambda s: None)
    assert out["history"][-1]["ce"] < out["history"][0]["ce"]

    # restore the final checkpoint into a fresh tree and serve with it
    from repro.checkpoint.manager import CheckpointManager
    from repro.train.step import init_train_state
    mgr = CheckpointManager(str(tmp_path))
    abstract = jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.key(0), RC))
    state = mgr.restore(abstract)

    params = state["params"]
    # served greedy continuation == direct decode with trained params
    prompt = np.asarray([3, 1, 4, 1], np.int32)
    eng = ServeEngine(cfg, params, slots=1, capacity=32, rc=RC)
    req = Request(rid=0, prompt=prompt, max_new=4)
    eng.run([req])
    assert len(req.out) == 4

    # trained model should beat chance on its own Markov stream
    from repro.data.pipeline import make_batch
    from repro.models import loss_fn
    batch = make_batch(cfg, 8, 64, step=999, seed=1)
    loss, _ = loss_fn(params, cfg, RC,
                      {k: jnp.asarray(v) for k, v in batch.items()})
    assert float(loss) < 0.8 * np.log(cfg.vocab_size)
