"""Observability layer (repro.obs): sink units, Chrome-trace validity,
per-request latency accounting, and the two serve-path contracts —
Request.stats key-schema parity across cache layouts, and greedy outputs
bitwise-identical with observability on or off."""
import json

import numpy as np
import jax
import pytest

from repro.configs import get_config, reduced
from repro.execution.base import set_plan_hook
from repro.models import RunConfig, init_params
from repro.obs import (LAT_KEYS, NOOP, MetricsRegistry, NullMetrics,
                       Observability, RequestTimeline, SpanTracer, aggregate,
                       available_sinks, get_sink, latency_summary,
                       percentile, validate_chrome_trace)
from repro.serve.engine import Request, ServeEngine

RC = RunConfig(q_chunk=16, kv_chunk=16)


class VirtualClock:
    """Deterministic injectable clock: advances ``dt`` per read."""

    def __init__(self, dt=1.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------
def test_counters_and_labels_are_separate_series():
    m = MetricsRegistry()
    m.inc("serve/admitted")
    m.inc("serve/admitted", 2.0)
    m.inc("serve/recompiles", kind="decode_step")
    m.inc("serve/recompiles", kind="prefill_step")
    assert m.counter_value("serve/admitted") == 3.0
    assert m.counter_value("serve/recompiles", kind="decode_step") == 1.0
    assert m.counter_value("serve/recompiles", kind="prefill_step") == 1.0
    assert m.counter_value("serve/recompiles") == 0.0   # unlabeled series


def test_gauges_overwrite():
    m = MetricsRegistry()
    m.set_gauge("kv/blocks_in_use", 3)
    m.set_gauge("kv/blocks_in_use", 7)
    assert m.gauge_value("kv/blocks_in_use") == 7.0


def test_histogram_percentiles_nearest_rank():
    m = MetricsRegistry()
    for v in range(1, 101):
        m.observe("lat", float(v))
    (h,) = m.snapshot()["histograms"]
    assert h["count"] == 100 and h["min"] == 1.0 and h["max"] == 100.0
    assert h["p50"] == 50.0 and h["p99"] == 99.0
    assert percentile([3.0, 1.0, 2.0], 50) == 2.0
    assert percentile([5.0], 99) == 5.0


def test_snapshot_json_roundtrip(tmp_path):
    m = MetricsRegistry()
    m.inc("serve/steps", 4)
    m.observe("serve/ttft_s", 0.25)
    p = tmp_path / "metrics.json"
    text = m.to_json(p, extra={"latency": {"ttft_s": {"p50": 0.25}}})
    doc = json.loads(p.read_text())
    assert doc == json.loads(text)
    assert doc["counters"][0]["name"] == "serve/steps"
    assert doc["latency"]["ttft_s"]["p50"] == 0.25


def test_null_metrics_absorbs_everything():
    n = NullMetrics()
    n.inc("x")
    n.observe("y", 1.0)
    n.set_gauge("z", 2.0)
    assert n.snapshot() == {"counters": [], "gauges": [], "histograms": []}
    assert n.counter_value("x") == 0.0


def test_sink_registry():
    assert {"null", "memory"} <= set(available_sinks())
    assert get_sink("null") is NOOP
    assert get_sink("memory").enabled
    with pytest.raises(ValueError, match="unknown observability sink"):
        get_sink("nope")


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------
def test_tracer_emits_valid_chrome_trace():
    clk = VirtualClock(dt=0.5)
    tr = SpanTracer(clock=clk)
    with tr.span("serve/step", step=0):
        with tr.span("serve/forward", tokens=2):
            pass
        tr.instant("recompile", kind="paged_step")
    doc = tr.to_chrome_trace()
    v = validate_chrome_trace(
        doc, required_names=("serve/step", "serve/forward", "recompile"))
    assert v["events"] == 3
    spans = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    # inner span closed before the outer: strictly shorter duration
    assert spans["serve/forward"]["dur"] < spans["serve/step"]["dur"]
    assert spans["serve/forward"]["args"] == {"tokens": 2}


def test_validate_chrome_trace_rejects_garbage():
    with pytest.raises(AssertionError):
        validate_chrome_trace({"no": "envelope"})
    ok = SpanTracer(clock=VirtualClock())
    with ok.span("a"):
        pass
    with pytest.raises(AssertionError, match="missing"):
        validate_chrome_trace(ok.to_chrome_trace(), required_names=("b",))


def test_null_tracer_spans_are_free():
    with NOOP.tracer.span("anything", deep=1):
        NOOP.tracer.instant("x")
    assert NOOP.tracer.save("/nonexistent/never/written.json") is None


# ---------------------------------------------------------------------------
# Latency accounting
# ---------------------------------------------------------------------------
def test_request_timeline_virtual_clock():
    tl = RequestTimeline(submit=0.0, admit=1.0)
    for t in (3.0, 4.0, 6.0):
        tl.on_token(t)
    s = tl.finalize(end=7.0)
    assert set(s) == set(LAT_KEYS)
    assert s["lat/queue_wait_s"] == 1.0
    assert s["lat/ttft_s"] == 3.0            # first token - submit
    assert s["lat/tpot_s"] == 1.5            # (6 - 3) / 2 inter-token gaps
    assert s["lat/e2e_s"] == 7.0
    assert s["lat/decode_tokens"] == 3.0


def test_single_token_tpot_is_finite_zero():
    tl = RequestTimeline(submit=0.0, admit=0.0)
    tl.on_token(2.0)
    s = tl.finalize(end=2.0)
    assert s["lat/tpot_s"] == 0.0 and np.isfinite(s["lat/tpot_s"])


def test_aggregate_nearest_rank():
    a = aggregate([0.1 * i for i in range(1, 101)])
    assert a["n"] == 100
    assert a["p50"] == pytest.approx(5.0)
    assert a["p99"] == pytest.approx(9.9)
    assert aggregate([]) is None


# ---------------------------------------------------------------------------
# Straggler wiring (satellite: runtime/fault.py -> serve loop)
# ---------------------------------------------------------------------------
def test_slow_step_flagged_on_virtual_clock():
    clk = VirtualClock(dt=0.0)
    obs = Observability.memory(clock=clk, straggler_window=8,
                               straggler_factor=2.0)
    for step, dur in enumerate([1.0, 1.0, 1.0, 1.0, 10.0]):
        obs.step_begin(step)
        clk.t += dur
        obs.step_end(step, scope="serve")
    assert obs.metrics.counter_value("serve/slow_steps") == 1.0
    (ev,) = [e for e in obs.tracer.events if e["name"] == "slow_step"]
    assert ev["args"]["step"] == 4 and ev["args"]["slowdown"] == 10.0


# ---------------------------------------------------------------------------
# Serve-path contracts
# ---------------------------------------------------------------------------
def _mk_reqs(cfg, n, max_new=4):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                    max_new=max_new) for i in range(n)]


def _run(cfg, *, obs=None, kv_block_size=None):
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=RC,
                      kv_block_size=kv_block_size, obs=obs)
    reqs = _mk_reqs(cfg, 4)
    try:
        done = eng.run(reqs, max_steps=256)
    finally:
        set_plan_hook(None)         # engine installs a process-global hook
    assert len(done) == len(reqs)
    return reqs, eng


DENSE = lambda: reduced(get_config("smollm-360m"), layers=2, d_model=32)
MOE = lambda: reduced(get_config("moonshot-v1-16b-a3b"), layers=2,
                      d_model=64, vocab=128)


@pytest.mark.parametrize("mk_cfg", [DENSE, MOE], ids=["dense", "moe"])
@pytest.mark.parametrize("kv_block", [None, 0], ids=["paged", "contiguous"])
def test_greedy_bitwise_identity_obs_on_off(mk_cfg, kv_block):
    """The overhead contract: attaching the full in-memory bundle must not
    change a single generated token (tracing adds no device-side ops)."""
    cfg = mk_cfg()
    base, _ = _run(cfg, obs=None, kv_block_size=kv_block)
    inst, _ = _run(cfg, obs=Observability.memory(), kv_block_size=kv_block)
    assert [r.out for r in base] == [r.out for r in inst]


@pytest.mark.parametrize("mk_cfg", [DENSE, MOE], ids=["dense", "moe"])
def test_request_stats_schema_parity_paged_vs_contiguous(mk_cfg):
    """Both cache layouts must materialize the SAME Request.stats key
    families (lat/* + serve/*) so downstream aggregation never branches
    on engine internals; every value stays finite."""
    cfg = mk_cfg()
    paged, eng = _run(cfg, kv_block_size=None)
    contig, _ = _run(cfg, kv_block_size=0)
    assert eng.paged
    for rp, rc_ in zip(paged, contig):
        assert set(rp.stats) == set(rc_.stats), (rp.stats, rc_.stats)
        assert set(LAT_KEYS) <= set(rp.stats)
        assert {"serve/prefix_hit_tokens", "serve/prefill_forwards"} \
            <= set(rp.stats)
        for r in (rp, rc_):
            assert all(np.isfinite(v) for v in r.stats.values()), r.stats
            assert r.stats["lat/decode_tokens"] == len(r.out)
            assert r.stats["lat/ttft_s"] <= r.stats["lat/e2e_s"]


def test_latency_summary_shape():
    reqs, _ = _run(DENSE())
    lat = latency_summary(reqs)
    assert set(lat) == {"ttft_s", "tpot_s", "queue_wait_s", "e2e_s"}
    for agg in lat.values():
        assert set(agg) == {"n", "mean", "p50", "p99"}
        assert agg["n"] == len(reqs)


def test_engine_metrics_and_trace_absorbed():
    obs = Observability.memory()
    reqs, eng = _run(MOE(), obs=obs)
    m = obs.metrics
    assert m.counter_value("serve/admitted") == len(reqs)
    assert m.counter_value("serve/completed") == len(reqs)
    assert m.counter_value("serve/steps") > 0
    # paged-cache telemetry mirrored as gauges each step
    assert m.gauge_value("kv/blocks_total") == eng.kv.n_blocks
    # per-request latency absorbed into histograms at retirement
    hists = {h["name"]: h for h in m.snapshot()["histograms"]}
    assert hists["serve/ttft_s"]["count"] == len(reqs)
    assert hists["serve/tpot_s"]["count"] == len(reqs)
    # the step timeline is a valid Chrome trace with the span skeleton
    v = validate_chrome_trace(
        obs.tracer.to_chrome_trace(),
        required_names=("serve/admit", "serve/step", "serve/assemble",
                        "serve/forward", "serve/host_sync", "serve/retire"))
    assert v["events"] > 0
    # straggler monitor saw every engine step
    assert len(obs.straggler.window) == m.counter_value("serve/steps")


def test_recompile_and_plan_trace_events():
    """Trace-time hooks fire once per compiled shape: the MoE paged run
    compiles >= 1 step shape, each traced plan_dispatch counts under
    moe/plans_traced, and both leave instants in the trace."""
    obs = Observability.memory()
    _run(MOE(), obs=obs)
    m = obs.metrics
    assert m.counter_value("serve/recompiles", kind="paged_step") >= 1
    assert m.counter_value("moe/plans_traced", executor="xla",
                           policy="fixed") >= 1
    names = {e["name"] for e in obs.tracer.events}
    assert {"recompile", "plan_trace"} <= names


def test_plan_hook_restores_previous():
    calls = []
    prev = set_plan_hook(lambda **kw: calls.append(kw))
    try:
        assert prev is None
        restored = set_plan_hook(None)
        assert callable(restored)
    finally:
        set_plan_hook(None)


def test_quantized_expert_bytes_gauge():
    cfg = MOE()
    params = init_params(cfg, jax.random.key(0))
    obs = Observability.memory()
    rc = RunConfig(q_chunk=16, kv_chunk=16, quant="int8_expert")
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=rc, obs=obs)
    try:
        eng.run(_mk_reqs(cfg, 2))
    finally:
        set_plan_hook(None)
    assert obs.metrics.gauge_value("serve/quant_expert_bytes",
                                   scheme="int8_expert") > 0


def test_dropped_requests_counted():
    cfg = DENSE()
    params = init_params(cfg, jax.random.key(0))
    obs = Observability.memory()
    eng = ServeEngine(cfg, params, slots=1, capacity=32, rc=RC, obs=obs)
    reqs = _mk_reqs(cfg, 2, max_new=8)
    try:
        eng.run(reqs, max_steps=3)
    finally:
        set_plan_hook(None)
    assert eng.dropped
    assert obs.metrics.counter_value("serve/dropped") == len(eng.dropped)
    assert "serve/step_budget_exhausted" in \
        {e["name"] for e in obs.tracer.events}


# ---------------------------------------------------------------------------
# Train-loop wiring
# ---------------------------------------------------------------------------
def test_train_loop_emits_spans_and_metrics():
    from repro.optim.adamw import OptConfig
    from repro.train.loop import train

    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32)
    obs = Observability.memory()
    out = train(cfg, RC, OptConfig(lr=1e-3), steps=3, batch=2, seq=8,
                log=lambda s: None, obs=obs)
    assert len(out["history"]) > 0
    names = {e["name"] for e in obs.tracer.events}
    assert {"train/data", "train/step"} <= names
    assert obs.metrics.counter_value("train/steps_logged") > 0
    hists = {h["name"] for h in obs.metrics.snapshot()["histograms"]}
    assert any(n.startswith("train/") for n in hists)
