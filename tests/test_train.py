"""Training substrate: optimizer math, loss descent, checkpoint/restart
(fault injection), straggler detection, gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import RunConfig
from repro.optim.adamw import OptConfig, apply_updates, init_opt_state
from repro.optim.compress import (compress_with_feedback, dequantize,
                                  init_error_state, quantize)

RC = RunConfig(q_chunk=16, kv_chunk=16, loss_chunk=16)
OPT = OptConfig(lr=1e-2, warmup_steps=2, total_steps=100, weight_decay=0.0)


def test_adamw_matches_manual():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.1, 0.2])}
    st = init_opt_state(p)
    newp, st2, m = apply_updates(p, g, st, OPT)
    # manual: step1, m=0.1g... bias-corrected mh = g, vh = g^2
    lr = 1e-2 * (1 / 2)                 # warmup 1/2
    expect = p["w"] - lr * g["w"] / (jnp.abs(g["w"]) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), np.asarray(expect),
                               rtol=1e-5)
    assert int(st2["step"]) == 1
    assert float(m["grad_norm"]) == pytest.approx(
        float(jnp.sqrt(0.1 ** 2 + 0.2 ** 2)), rel=1e-5)


def test_loss_decreases_markov(tmp_path):
    from repro.train.loop import train
    cfg = reduced(get_config("smollm-360m"), layers=2, d_model=64, vocab=64)
    out = train(cfg, RC, OptConfig(lr=1e-2, warmup_steps=5,
                                   total_steps=80, weight_decay=0.0),
                steps=40, batch=8, seq=64, log_every=5,
                log=lambda s: None)
    hist = out["history"]
    # markov branch=4: floor ln(4)=1.39; init ~ln(64)=4.16
    assert hist[-1]["ce"] < hist[0]["ce"] - 1.5, hist


def test_grad_accum_equivalence():
    """accum=2 over half-batches == accum=1 over the full batch."""
    from repro.train.step import init_train_state, make_train_step
    from repro.data.pipeline import make_batch
    cfg = reduced(get_config("qwen2-7b"), layers=2, d_model=64)
    state = init_train_state(cfg, jax.random.key(0), RC)
    b1 = make_batch(cfg, 8, 16, step=0)
    b2 = {k: v.reshape((2, 4) + v.shape[1:]) for k, v in b1.items()}
    s1, m1 = jax.jit(make_train_step(cfg, RC, OPT, 1))(state, b1)
    s2, m2 = jax.jit(make_train_step(cfg, RC, OPT, 2))(state, b2)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1["params"], s2["params"])
    assert max(jax.tree.leaves(d)) < 3e-5
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)


def test_checkpoint_restart_after_failure(tmp_path):
    """Crash at step 12 -> resume from ckpt 10 -> identical final state to
    an uninterrupted run (deterministic data pipeline)."""
    from repro.train.loop import train
    cfg = reduced(get_config("smollm-360m"), layers=2, d_model=32)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=40,
                    weight_decay=0.0)
    kw = dict(steps=16, batch=4, seq=16, save_every=5, log_every=50,
              log=lambda s: None)

    with pytest.raises(RuntimeError, match="injected"):
        train(cfg, RC, opt, ckpt_dir=str(tmp_path / "a"), fail_at=12, **kw)
    out_resumed = train(cfg, RC, opt, ckpt_dir=str(tmp_path / "a"), **kw)
    assert out_resumed["resumed_from"] == 10

    out_clean = train(cfg, RC, opt, ckpt_dir=str(tmp_path / "b"), **kw)
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        out_resumed["state"]["params"], out_clean["state"]["params"])
    assert max(jax.tree.leaves(diff)) < 1e-6


def test_checkpoint_atomic_and_gc(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    m = CheckpointManager(str(tmp_path), keep_last=2, async_save=False)
    state = {"w": jnp.arange(4.0), "n": jnp.int32(3)}
    for s in (1, 2, 3):
        m.save(s, jax.tree.map(lambda x: x + s, state))
    ckpts = sorted(p.name for p in tmp_path.glob("ckpt_*"))
    assert ckpts == ["ckpt_00000002", "ckpt_00000003"]   # gc keeps last 2
    assert m.latest_step() == 3
    restored = m.restore(state)
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(state["w"] + 3))


def test_straggler_monitor():
    """Deterministic virtual clock — no wall-time sleeps, so the verdict
    cannot flake under host load."""
    from repro.runtime.fault import StragglerMonitor
    now = [0.0]
    mon = StragglerMonitor(window=16, factor=2.0, warmup=3,
                           clock=lambda: now[0])
    for i in range(6):
        mon.start_step(i)
        now[0] += 0.01                      # six steady 10ms steps
        assert mon.end_step() is None
    mon.start_step(6)
    now[0] += 0.08                          # one 8x step -> must flag
    flag = mon.end_step()
    assert flag is not None and flag["slowdown"] == pytest.approx(8.0)
    assert mon.flagged == [flag]


def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 0.1
    err = init_error_state(g)
    acc_true, acc_q = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(50):
        (q, s), err = compress_with_feedback(g, err)
        acc_q = acc_q + dequantize(q, s)
        acc_true = acc_true + g
    # error feedback keeps the long-run average unbiased
    np.testing.assert_allclose(np.asarray(acc_q) / 50,
                               np.asarray(acc_true) / 50, atol=2e-4)


def test_quantize_roundtrip_bound():
    g = jnp.linspace(-1, 1, 255)
    q, s = quantize(g)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(dequantize(q, s) - g))) <= float(s) * 0.51
