"""Back-compat coverage for the core/quant.py shim (serving path, §Perf
cell 3): the pre-registry entry points keep working on top of the unified
quantization API (repro.quantization, DESIGN.md §8)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core import apply_moe, dispatch_config, init_moe_params
from repro.core.quant import (QuantTensor, effective_expert_weights,
                              is_quantized, quantize_expert,
                              quantize_moe_params, quantize_params_tree)


def test_quantize_roundtrip_error_bound():
    w = jax.random.normal(jax.random.key(0), (4, 16, 24)) * 0.2
    q, s = quantize_expert(w)
    assert q.dtype == jnp.int8 and s.shape == (4, 1, 1)
    deq = q.astype(jnp.float32) * s
    # symmetric int8: max error <= scale/2 per element
    assert float(jnp.max(jnp.abs(deq - w))) <= float(jnp.max(s)) * 0.51


def test_quant_tensor_indexing_matches_dequant():
    w = jax.random.normal(jax.random.key(1), (8, 4, 6))
    q, s = quantize_expert(w)
    qt = QuantTensor(q, s, jnp.float32, "int8_expert")
    np.testing.assert_allclose(np.asarray(qt[3]),
                               np.asarray(q[3].astype(jnp.float32) * s[3]))
    assert qt.shape == (8, 4, 6)


def test_quantized_moe_layer_close_to_fp():
    moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                    n_shared_experts=1, block_m=8)
    params = init_moe_params(jax.random.key(0), moe, 16)
    qparams = dict(quantize_moe_params(
        {k: v for k, v in params.items() if k != "shared"}),
        shared=params["shared"])
    assert is_quantized(qparams)
    x = jax.random.normal(jax.random.key(1), (4, 32, 16))
    cfg = dispatch_config(moe, executor="xla")
    y, _ = apply_moe(params, x, cfg)
    yq, _ = apply_moe(qparams, x, cfg)
    rel = float(jnp.max(jnp.abs(y - yq))) / float(jnp.max(jnp.abs(y)))
    assert rel < 0.05, rel


def test_quantize_full_model_tree():
    from repro.configs import get_config, reduced
    from repro.models import init_params
    cfg = reduced(get_config("moonshot-v1-16b-a3b"))
    params = jax.eval_shape(lambda k: quantize_params_tree(
        init_params(cfg, k)), jax.random.key(0))
    body_moe = params["body"]["b0"]["moe"]
    # default scheme is int8_expert — the original layout, now scheme-tagged
    qt = body_moe["w_gate"]
    assert isinstance(qt, QuantTensor) and qt.scheme == "int8_expert"
    assert qt.q.dtype == jnp.int8
    # stacked group axis preserved
    assert qt.q.ndim == 4


def test_effective_weights_passthrough_for_fp():
    moe = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8, block_m=8)
    params = init_moe_params(jax.random.key(0), moe, 8)
    eff = effective_expert_weights(params, jnp.float32)
    assert eff["w_gate"] is params["w_gate"]
