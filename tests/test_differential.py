"""Cross-subsystem differential fuzz suite (ISSUE 5 satellite).

PR 1-4 grew four orthogonal registries — schedule policy x executor x
quant scheme x serving path — that were only spot-checked at hand-picked
points.  This suite fuzzes the cross-product: hypothesis-driven draws over
(config shape x policy x executor x scheme x batch skew), asserting the
xla and pallas executors against the dense fp32 oracle within each
scheme's DECLARED ``rel_error_bound``, plus tight xla-vs-pallas agreement
on the SAME plan (routing built once, executed twice — so a top-k tie can
never make the comparison vacuous).

Runs under tests/hypothesis_compat.py: with hypothesis installed these
are real property tests (CI pins ``--hypothesis-seed=0``); without it the
shim replays a deterministic fixed-example set (REPRO_FUZZ_SEED /
REPRO_FUZZ_EXAMPLES).

Marked ``slow``: tier-1 (`pytest -q`, addopts ``-m "not slow"``) skips
this module; the CI ``fuzz`` stage runs it with the pinned seed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.dispatch import MoEDispatchConfig
from repro.execution import execute, plan_dispatch
from repro.kernels import ref
from repro.quantization import available_schemes, get_scheme
from repro.scheduling import available_policies, expert_capacity

pytestmark = pytest.mark.slow

# the independent numpy capacity-drop oracle lives with the policy tests
from test_scheduling_policies import expected_keep  # noqa: E402

# fp32 re-association floor: even the 'none' scheme (declared bound 0.0,
# bitwise through ONE backend) differs from the dense oracle by operation
# order; this matches the tolerance the hand-picked oracle tests use
FP_REORDER_FLOOR = 5e-4


@st.composite
def dispatch_cases(draw):
    E = draw(st.sampled_from([4, 8, 16]))
    return dict(
        T=draw(st.sampled_from([8, 24, 64])),
        E=E,
        k=draw(st.integers(1, min(4, E))),
        M=draw(st.sampled_from([8, 16])),
        d=draw(st.sampled_from([8, 16])),
        f=draw(st.sampled_from([16, 32])),
        # router-column skew: 0 = balanced, 2.0 = zipf-hot expert 0 —
        # drives the dynamic policy's adaptive blocks and real capacity
        # drops at small capacity factors
        alpha=draw(st.sampled_from([0.0, 1.2, 2.0])),
        policy=draw(st.sampled_from(sorted(available_policies()))),
        scheme=draw(st.sampled_from(available_schemes())),
        capacity_factor=draw(st.sampled_from([0.5, 1.25, 2.0])),
        fuse_gate_up=draw(st.booleans()),
        fold_combine=draw(st.booleans()),
        seed=draw(st.integers(0, 2 ** 16)),
    )


def _build(case):
    T, E, k, M, d, f = (case[x] for x in "TEkMdf")
    ks = jax.random.split(jax.random.key(case["seed"]), 5)
    x = jax.random.normal(ks[0], (T, d))
    wr = jax.random.normal(ks[1], (d, E)) * 0.3
    if case["alpha"] > 0:        # tilt routing mass toward low expert ids
        wr = wr + 2.0 * case["alpha"] * jnp.linspace(1.0, 0.0, E)[None, :]
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.3
    wu = jax.random.normal(ks[3], (E, d, f)) * 0.3
    wd = jax.random.normal(ks[4], (E, f, d)) * 0.3
    cfg = MoEDispatchConfig(
        n_experts=E, top_k=k, block_m=M, executor="xla",
        schedule_policy=case["policy"],
        capacity_factor=case["capacity_factor"],
        fuse_gate_up=case["fuse_gate_up"],
        fold_combine=case["fold_combine"])
    return x, wr, wg, wu, wd, cfg


def _quantize_weights(wg, wu, wd, scheme):
    if scheme == "none":
        return {"w_gate": wg, "w_up": wu, "w_down": wd}
    sch = get_scheme(scheme)
    return {"w_gate": sch.quantize(wg), "w_up": sch.quantize(wu),
            "w_down": sch.quantize(wd)}


def _oracle(x, wg, wu, wd, plan, cfg):
    """Dense fp32 oracle on the plan's routing, with capacity-policy drops
    zeroed exactly as the bucket overflow rule prescribes."""
    weights, indices = plan.weights, plan.indices
    if cfg.schedule_policy == "capacity_factor":
        T, k = indices.shape
        cap = expert_capacity(T, k, cfg.n_experts, cfg.block_m,
                              cfg.capacity_factor)
        keep = expected_keep(np.asarray(indices), cap)
        weights = jnp.where(jnp.asarray(keep), weights, 0.0)
    return ref.moe_ffn_dense_ref(x, wg, wu, wd, weights, indices)


@given(dispatch_cases())
@settings(max_examples=30, deadline=None)
def test_fuzz_executor_x_policy_x_scheme_vs_dense_oracle(case):
    """ONE plan, BOTH in-scan executors, every scheme: each backend stays
    inside the scheme's declared rel_error_bound of the fp32 dense oracle,
    and the two backends agree tightly with each other (same routing, same
    schedule, same dequantized blocks — only GEMM order differs)."""
    x, wr, wg, wu, wd, cfg = _build(case)
    plan = plan_dispatch(x, wr, cfg, with_schedule=True)
    w = _quantize_weights(wg, wu, wd, case["scheme"])
    oracle = _oracle(x, wg, wu, wd, plan, cfg)
    scale = float(jnp.max(jnp.abs(oracle))) or 1.0
    bound = max(get_scheme(case["scheme"]).rel_error_bound,
                FP_REORDER_FLOOR)

    outs = {}
    for executor in ("xla", "pallas"):
        y = execute(plan, x, w, cfg, executor=executor)
        rel = float(jnp.max(jnp.abs(y - oracle))) / scale
        assert rel <= bound, (case, executor, rel, bound)
        outs[executor] = y
    cross = float(jnp.max(jnp.abs(outs["xla"] - outs["pallas"]))) / scale
    assert cross <= FP_REORDER_FLOOR, (case, cross)


@given(dispatch_cases())
@settings(max_examples=15, deadline=None)
def test_fuzz_policies_agree_when_nothing_drops(case):
    """Differential across SCHEDULE POLICIES: on the same routing, any
    two drop-free policies are just different padded layouts of the same
    math — outputs must agree to fp reorder tolerance."""
    x, wr, wg, wu, wd, cfg = _build(case)
    w = {"w_gate": wg, "w_up": wu, "w_down": wd}
    ys = []
    for policy in ("fixed", "dynamic"):
        c = cfg._replace(schedule_policy=policy)
        plan = plan_dispatch(x, wr, c, with_schedule=True)
        ys.append(execute(plan, x, w, c))
    scale = float(jnp.max(jnp.abs(ys[0]))) or 1.0
    diff = float(jnp.max(jnp.abs(ys[0] - ys[1]))) / scale
    assert diff <= FP_REORDER_FLOOR, (case, diff)


@given(dispatch_cases())
@settings(max_examples=10, deadline=None)
def test_fuzz_in_scan_dequant_matches_materialized(case):
    """Differential across WEIGHT REPRESENTATIONS: executing a plan on
    compressed weights (per-block in-scan dequant) must be BITWISE equal
    to materializing the dense stack first — on fuzzed shapes, not just
    the hand-picked ones in test_quantization.py."""
    if case["scheme"] == "none":
        return
    x, wr, wg, wu, wd, cfg = _build(case)
    plan = plan_dispatch(x, wr, cfg, with_schedule=True)
    w = _quantize_weights(wg, wu, wd, case["scheme"])
    w_mat = {k: v.materialize() for k, v in w.items()}
    for executor in ("xla", "pallas"):
        y_lazy = execute(plan, x, w, cfg, executor=executor)
        y_mat = execute(plan, x, w_mat, cfg, executor=executor)
        np.testing.assert_array_equal(np.asarray(y_lazy), np.asarray(y_mat),
                                      err_msg=str((case, executor)))


@st.composite
def serve_cases(draw):
    return dict(
        policy=draw(st.sampled_from(["fixed", "dynamic"])),
        scheme=draw(st.sampled_from(["none", "int8_expert"])),
        block=draw(st.sampled_from([4, 8])),
        chunk=draw(st.integers(2, 8)),
        prefix_cache=draw(st.booleans()),
        seed=draw(st.integers(0, 2 ** 16)),
    )


@given(serve_cases())
@settings(max_examples=5, deadline=None)
def test_fuzz_serving_paged_equals_contiguous(case):
    """End-to-end serving differential: greedy tokens through the PAGED
    engine (fuzzed block size / chunk size / prefix caching) equal the
    contiguous engine's under fuzzed policy x scheme — the cache layout
    must never reach the sampled tokens."""
    from repro.configs import get_config, reduced
    from repro.models import RunConfig, init_params
    from repro.serve.engine import Request, ServeEngine
    cfg = reduced(get_config("moonshot-v1-16b-a3b"), layers=2, d_model=32,
                  vocab=128)
    params = init_params(cfg, jax.random.key(0))
    rc = RunConfig(q_chunk=16, kv_chunk=16, schedule_policy=case["policy"],
                   quant=case["scheme"], moe_stats=True)
    rng = np.random.default_rng(case["seed"])
    shared = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)

    def mk():
        return [Request(rid=i,
                        prompt=np.concatenate(
                            [shared, rng.integers(0, cfg.vocab_size, 1 + i)]
                        ).astype(np.int32), max_new=4)
                for i in range(3)]

    rng_state = rng.bit_generator.state
    ref_reqs = mk()
    ServeEngine(cfg, params, slots=2, capacity=32, rc=rc,
                kv_block_size=0).run(ref_reqs)
    rng.bit_generator.state = rng_state
    paged_reqs = mk()
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=rc,
                      kv_block_size=case["block"],
                      prefill_chunk=case["chunk"],
                      prefix_cache=case["prefix_cache"])
    eng.run(paged_reqs)
    assert [r.out for r in paged_reqs] == [r.out for r in ref_reqs], case


# ----------------------------------------------------------------------
# Sharded EP vs single-device dispatch (policy x scheme x skew fuzz)
# ----------------------------------------------------------------------
def test_fuzz_sharded_ep_matches_single_device():
    """Padding-free sharded EP == single-device dispatch over seeded
    (policy x quant-scheme x router-skew) draws, including the drop
    regime: the capacity_factor policy's drop SET must reproduce the
    single-device first-come-first-kept order exactly, whatever dim the
    tokens were split on.  One subprocess (8 forced host devices) loops
    all draws; the cross-layout bound is the fp-reorder floor since both
    sides run the identical (de)quantized weights."""
    import pathlib
    import subprocess
    import sys

    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import apply_moe, dispatch_config, init_moe_params
from repro.configs.base import MoEConfig
from repro.core.distributed import apply_moe_ep
from repro.launch.mesh import make_debug_mesh
from repro.compat import set_mesh
from repro.quantization import quantize_moe_params

POLICIES = ("fixed", "dynamic", "capacity_factor")
SCHEMES = ("none", "int8_expert", "int4_packed")
ALPHAS = (0.0, 1.2, 2.0)     # router-skew: uniform .. zipf2.0 stress
saw_drops = 0
rng = np.random.default_rng(0)
for draw in range(6):
    pol = POLICIES[draw % 3]
    sch = SCHEMES[int(rng.integers(3))]
    alpha = ALPHAS[int(rng.integers(3))]
    B, S, d = int(rng.integers(1, 5)), 32, 16
    moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, block_m=8,
                    capacity_factor=float(rng.choice([0.5, 1.0])))
    params = init_moe_params(jax.random.key(draw), moe, d)
    # zipf-scaled router columns concentrate routing mass on low experts
    f = (np.arange(moe.n_experts) + 1.0) ** (-alpha)
    params["router"] = params["router"] * jnp.asarray(
        3.0 * f / f.mean(), params["router"].dtype)
    if sch != "none":
        params = quantize_moe_params(params, sch)
    x = jax.random.normal(jax.random.key(100 + draw), (B, S, d))
    # capacity semantics are per data shard -> data=1 for the drop cells
    mesh = make_debug_mesh(data=1 if pol == "capacity_factor" else 2,
                           model=4)
    dcfg = dispatch_config(moe, executor="xla", schedule_policy=pol,
                           emit_stats=True)
    y_ref, aux_ref = apply_moe(params, x, dcfg)
    with set_mesh(mesh):
        y_ep, aux_ep = jax.jit(lambda p, x: apply_moe_ep(
            p, x, dcfg))(params, x)
    np.testing.assert_allclose(
        np.asarray(y_ep), np.asarray(y_ref), rtol=5e-4, atol=5e-4,
        err_msg=f"draw={draw} pol={pol} scheme={sch} alpha={alpha}")
    assert float(aux_ep["sched/dropped_rows"]) \
        == float(aux_ref["sched/dropped_rows"]), (draw, pol, sch, alpha)
    saw_drops += float(aux_ref["sched/dropped_rows"]) > 0
assert saw_drops > 0, "fuzz must exercise the drop regime at least once"
print("OK drops_in", saw_drops, "draws")
"""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": src, "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout
