"""Chunked flash attention vs naive oracle across every mask mode, plus
hypothesis property tests on shape/chunk invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.models.attention import (combine_stats, flash_attention,
                                    naive_attention)


def mk(B, Sq, Skv, Hq, Hkv, D, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(ks[0], (B, Sq, Hq, D)),
            jax.random.normal(ks[1], (B, Skv, Hkv, D)),
            jax.random.normal(ks[2], (B, Skv, Hkv, D)))


@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=16),
    dict(causal=True, window=8),
    dict(causal=True, logit_softcap=30.0),
    dict(causal=True, window=16, logit_softcap=50.0),
])
@pytest.mark.parametrize("q_chunk,kv_chunk", [(16, 16), (64, 32), (0, 0)])
def test_flash_vs_naive(kwargs, q_chunk, kv_chunk):
    q, k, v = mk(2, 64, 64, 6, 2, 16)
    f = flash_attention(q, k, v, q_chunk=q_chunk or 10**9,
                        kv_chunk=kv_chunk or 10**9, **kwargs)
    n = naive_attention(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(f), np.asarray(n),
                               rtol=2e-5, atol=2e-5)


def test_decode_kv_limit_per_batch():
    q, k, v = mk(3, 1, 64, 4, 4, 8)
    lim = jnp.array([0, 17, 63])
    f = flash_attention(q, k, v, causal=False, kv_limit=lim,
                        q_chunk=1, kv_chunk=16)
    n = naive_attention(q, k, v, causal=False, kv_limit=lim)
    np.testing.assert_allclose(np.asarray(f), np.asarray(n),
                               rtol=2e-5, atol=2e-5)


def test_cross_attention_unequal_lengths():
    q, _, _ = mk(2, 32, 32, 8, 8, 16)
    _, k, v = mk(2, 32, 48, 8, 8, 16, seed=1)
    f = flash_attention(q, k, v, causal=False, q_chunk=8, kv_chunk=12)
    n = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(f), np.asarray(n),
                               rtol=2e-5, atol=2e-5)


def test_stats_combine_equals_full():
    """Sharded-KV LSE combination (flash-decode) == full attention."""
    q, k, v = mk(2, 4, 64, 4, 4, 8)
    parts = []
    for s in range(4):
        sl = slice(16 * s, 16 * (s + 1))
        parts.append(flash_attention(q, k[:, sl], v[:, sl], causal=False,
                                     kv_offset=16 * s, q_chunk=4,
                                     kv_chunk=8, return_stats=True))
    m = jnp.stack([p[2] for p in parts]).max(0)
    l = sum(p[1] * jnp.exp(p[2] - m) for p in parts)
    acc = sum(p[0] * jnp.exp(p[2] - m)[..., None] for p in parts)
    out = acc / l[..., None]
    B, Sq, Hq, D = q.shape
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, Hq, D)
    n = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(n),
                               rtol=2e-5, atol=2e-5)


@given(st.integers(1, 3), st.sampled_from([8, 24, 48]),
       st.sampled_from([(4, 4), (6, 2), (8, 1)]), st.sampled_from([4, 8]),
       st.booleans())
@settings(max_examples=20, deadline=None)
def test_chunk_invariance(B, S, heads, D, causal):
    """Property: output independent of chunking choices."""
    Hq, Hkv = heads
    q, k, v = mk(B, S, S, Hq, Hkv, D)
    ref_out = flash_attention(q, k, v, causal=causal,
                              q_chunk=10**9, kv_chunk=10**9)
    for qc, kc in [(1, 4), (4, 1), (3, 5)]:
        out = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   rtol=3e-5, atol=3e-5)


def test_fully_masked_rows_are_zero_not_nan():
    """Window smaller than chunk can fully mask early rows — must be 0."""
    q, k, v = mk(1, 8, 8, 2, 2, 4)
    out = flash_attention(q, k, v, causal=False, kv_limit=jnp.array([-1]),
                          q_chunk=4, kv_chunk=4)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_attention_block_vector_cache_pos_matches_scalar():
    """Batched decode with per-row cache positions (the serving path) must
    equal per-row scalar-pos decodes: same scatter write, same kv_limit."""
    from repro.models.attention import attention_block, init_attn
    B, cap, H, D, d = 3, 16, 2, 8, 16
    p = init_attn(jax.random.key(0), d, H, H, D, False)
    x = jax.random.normal(jax.random.key(1), (B, 1, d))
    cache = {"k": jax.random.normal(jax.random.key(2), (B, cap, H, D)),
             "v": jax.random.normal(jax.random.key(3), (B, cap, H, D))}
    pos = jnp.array([2, 0, 9], jnp.int32)
    kw = dict(n_heads=H, n_kv_heads=H, head_dim=D, causal=True,
              use_rope=True, rope_theta=1e4, q_chunk=10 ** 9,
              kv_chunk=10 ** 9)
    out_b, nc_b = attention_block(p, x, **kw, positions=pos[:, None],
                                  cache=cache, cache_pos=pos)
    for i in range(B):
        ci = {"k": cache["k"][i:i + 1], "v": cache["v"][i:i + 1]}
        out_i, nc_i = attention_block(
            p, x[i:i + 1], **kw, positions=jnp.full((1,), pos[i], jnp.int32),
            cache=ci, cache_pos=pos[i])
        np.testing.assert_allclose(np.asarray(out_b[i]), np.asarray(out_i[0]),
                                   rtol=2e-5, atol=2e-5)
        for key in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(nc_b[key][i]),
                                          np.asarray(nc_i[key][0]))
