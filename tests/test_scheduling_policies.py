"""Scheduling-policy invariants (ISSUE 1 acceptance criteria).

For every policy and Zipf alpha in {0, 1.2, 2.0}:
  * permute -> unpermute is a bijection on kept tokens;
  * per-expert counts are conserved (kept + dropped == routed), with drops
    exactly the capacity-bucket overflow for ``capacity_factor`` and zero
    otherwise;
  * every active block is owned by exactly one expert (the kernel contract);
  * ``dynamic`` never has more padding waste than ``fixed``, and strictly
    less on zipf2.0 at E = 64;
  * all three policies match the dense oracle on kept tokens through
    ``moe_ffn``;
  * schedules build inside jit from jnp primitives only (no host sync).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dispatch import MoEDispatchConfig, moe_ffn, route
from repro.kernels import ref
from repro.scheduling import (DEFAULT_POLICY_SWEEP, build_schedule,
                              expert_capacity, schedule_stats, sub_block)

ALPHAS = (0.0, 1.2, 2.0)
POLICIES = DEFAULT_POLICY_SWEEP
SHAPES = ((64, 2, 8, 8), (256, 4, 64, 32))          # (T, k, E, M)


def zipf_idx(T, k, E, alpha, seed=0):
    rng = np.random.default_rng(seed)
    if alpha <= 0:
        p = np.full(E, 1.0 / E)
    else:
        w = (np.arange(E) + 1.0) ** (-alpha)
        p = w / w.sum()
    return rng.choice(E, size=(T, k), p=p).astype(np.int32)


def expected_keep(idx, cap):
    """First-come-first-kept mask under a per-expert bucket of cap rows
    (mirrors scheduling.capacity_slots, independently in numpy)."""
    flat = idx.reshape(-1)
    seen = np.zeros(flat.max() + 1, np.int64)
    keep = np.zeros(flat.shape, bool)
    for i, e in enumerate(flat):
        keep[i] = seen[e] < cap
        seen[e] += 1
    return keep.reshape(idx.shape)


@pytest.mark.parametrize("alpha", ALPHAS)
@pytest.mark.parametrize("policy,kw", POLICIES)
@pytest.mark.parametrize("shape", SHAPES)
def test_schedule_invariants(alpha, policy, kw, shape):
    T, k, E, M = shape
    idx = zipf_idx(T, k, E, alpha)
    check_schedule_invariants(idx, E, M, policy, kw)


def check_schedule_invariants(idx: np.ndarray, E: int, M: int,
                              policy: str, kw: dict) -> None:
    """The full invariant battery for one (assignments, policy) point:
    permutation-bijection on kept tokens, per-expert token conservation,
    capacity-drop accounting (first-come-first-kept bucket overflow), and
    single-expert block ownership (the kernel contract)."""
    T, k = idx.shape
    sched = build_schedule(jnp.asarray(idx), E, M, policy=policy, **kw)
    src = np.asarray(sched.src_tok)
    pos = np.asarray(sched.pos)
    counts = np.asarray(sched.counts)
    be = np.asarray(sched.block_expert)
    active = np.asarray(sched.block_active)
    q = sched.block_m

    np.testing.assert_array_equal(counts,
                                  np.bincount(idx.reshape(-1), minlength=E))

    # kept assignments: pos row holds this token; they are pairwise distinct
    kept = src[pos] == (np.arange(T)[:, None] + np.zeros((1, k), np.int64))
    kept_pos = pos[kept]
    assert len(set(kept_pos.tolist())) == kept.sum()
    assert (src >= 0).sum() == kept.sum()

    # conservation: kept + dropped == routed, per expert
    kept_counts = np.bincount(idx[kept], minlength=E)
    if policy == "capacity_factor":
        cap = expert_capacity(T, k, E, M, kw["capacity_factor"])
        np.testing.assert_array_equal(kept_counts, np.minimum(counts, cap))
        np.testing.assert_array_equal(counts - kept_counts,
                                      np.maximum(counts - cap, 0))
        # dropped assignments are exactly the bucket overflow, stable order
        np.testing.assert_array_equal(kept, expected_keep(idx, cap))
    else:
        np.testing.assert_array_equal(kept_counts, counts)

    # every kept row sits at/after its expert's segment base
    seg_start = np.asarray(sched.seg_start)
    for t in range(T):
        for j in range(k):
            if kept[t, j]:
                assert pos[t, j] >= seg_start[idx[t, j]], (policy, t, j)

    # every active block is owned by one expert; inactive blocks are empty
    row_expert = np.full(sched.capacity, -1, np.int64)
    for t in range(T):
        for j in range(k):
            if kept[t, j]:
                row_expert[pos[t, j]] = idx[t, j]
    for b in range(sched.capacity // q):
        owners = row_expert[b * q:(b + 1) * q]
        owners = owners[owners >= 0]
        if active[b]:
            assert (owners == be[b]).all(), (policy, b)
        else:
            assert owners.size == 0, (policy, b)


@pytest.mark.parametrize("alpha", ALPHAS)
@pytest.mark.parametrize("shape", SHAPES)
def test_dynamic_waste_never_worse_than_fixed(alpha, shape):
    T, k, E, M = shape
    idx = jnp.asarray(zipf_idx(T, k, E, alpha))
    st_fixed = schedule_stats(build_schedule(idx, E, M, policy="fixed"))
    st_dyn = schedule_stats(build_schedule(idx, E, M, policy="dynamic"))
    assert int(st_dyn.padded_rows) <= int(st_fixed.padded_rows)
    assert int(st_dyn.useful_rows) == int(st_fixed.useful_rows) == T * k


def test_dynamic_strictly_beats_fixed_on_zipf2_at_64_experts():
    """The acceptance criterion: strictly lower padding waste than fixed on
    zipf2.0 assignments at E >= 64."""
    for E in (64, 128):
        T, k, M = 256, 4, 32
        idx = jnp.asarray(zipf_idx(T, k, E, 2.0))
        st_fixed = schedule_stats(build_schedule(idx, E, M, policy="fixed"))
        st_dyn = schedule_stats(build_schedule(idx, E, M, policy="dynamic"))
        assert float(st_dyn.pad_waste) < float(st_fixed.pad_waste), E


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("policy,kw", POLICIES)
def test_moe_ffn_matches_dense_oracle_on_kept_tokens(policy, kw, impl):
    T, k, E, M, d, f = 48, 2, 8, 8, 16, 24
    cf = 0.5 if policy == "capacity_factor" else None   # force real drops
    cfg = MoEDispatchConfig(
        n_experts=E, top_k=k, block_m=M, executor=impl,
        schedule_policy=policy,
        capacity_factor=(cf if cf is not None else 2.0), emit_stats=True)
    ks = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(ks[0], (T, d))
    wr = jax.random.normal(ks[1], (d, E)) * 0.3
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.3
    wu = jax.random.normal(ks[3], (E, d, f)) * 0.3
    wd = jax.random.normal(ks[4], (E, f, d)) * 0.3

    weights, indices, _ = route(x, wr, cfg)
    if cf is not None:
        cap = expert_capacity(T, k, E, M, cf)
        keep = expected_keep(np.asarray(indices), cap)
        weights = jnp.where(jnp.asarray(keep), weights, 0.0)
    oracle = ref.moe_ffn_dense_ref(x, wg, wu, wd, weights, indices)

    y, aux = moe_ffn(x, wr, wg, wu, wd, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=5e-4, atol=5e-4)
    assert "sched/pad_waste" in aux and "sched/drop_fraction" in aux
    drop = float(aux["sched/drop_fraction"])
    assert (drop > 0) == (cf is not None)


def test_policies_build_inside_jit_no_host_sync():
    """jnp-primitives-only construction: tracing must succeed (any host
    round-trip on a traced value would raise)."""
    T, k, E, M = 64, 2, 16, 16
    idx = jnp.asarray(zipf_idx(T, k, E, 1.2))
    for policy, kw in POLICIES:
        fn = jax.jit(lambda i: build_schedule(
            i, E, M, policy=policy, **kw).src_tok.sum())
        assert int(fn(idx)) >= 0


# ---------------------------------------------------------------------------
# Property tests over hypothesis-generated routings (ISSUE 5 satellite):
# the zipf fixtures above pin three skews; these fuzz the assignment space
# including the degenerate corners a sampled distribution never produces.
# ---------------------------------------------------------------------------
from hypothesis_compat import given, settings, st  # noqa: E402


@st.composite
def routing_draws(draw):
    E = draw(st.sampled_from([2, 8, 64]))
    k = draw(st.integers(1, min(4, E)))
    T = draw(st.sampled_from([16, 64, 256]))
    M = draw(st.sampled_from([8, 16, 32]))
    pattern = draw(st.sampled_from(
        ["random", "one_expert", "uniform_ties", "zipf2", "two_hot"]))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    if pattern == "one_expert":
        # fully degenerate: every assignment routed to expert 0 — the
        # worst case for capacity buckets and dynamic block sizing
        idx = np.zeros((T, k), np.int32)
    elif pattern == "uniform_ties":
        # perfectly uniform striping (exact ties everywhere): every
        # expert count identical, exercising tie-stable ordering
        idx = ((np.arange(T)[:, None] * k + np.arange(k)[None, :]) % E
               ).astype(np.int32)
    elif pattern == "two_hot":
        idx = rng.choice([0, E - 1], size=(T, k)).astype(np.int32)
    elif pattern == "zipf2":
        idx = zipf_idx(T, k, E, 2.0, seed=seed)
    else:
        idx = rng.integers(0, E, size=(T, k)).astype(np.int32)
    return idx, E, M


@given(routing_draws())
@settings(max_examples=20, deadline=None)
def test_policy_invariants_on_fuzzed_routings(case):
    """Bijection, conservation, and capacity-drop accounting hold for
    EVERY registered policy on fuzzed assignments, including all-one-
    expert and exactly-tied-uniform degenerate routings."""
    idx, E, M = case
    for policy, kw in POLICIES:
        check_schedule_invariants(idx, E, M, policy, kw)


@given(routing_draws())
@settings(max_examples=10, deadline=None)
def test_fuzzed_dynamic_padding_never_worse_than_fixed(case):
    idx, E, M = case
    st_fixed = schedule_stats(build_schedule(jnp.asarray(idx), E, M,
                                             policy="fixed"))
    st_dyn = schedule_stats(build_schedule(jnp.asarray(idx), E, M,
                                           policy="dynamic"))
    assert int(st_dyn.padded_rows) <= int(st_fixed.padded_rows)
    assert int(st_dyn.useful_rows) == int(st_fixed.useful_rows) \
        == idx.size


def test_dynamic_sub_block_divides_block_m():
    for M in (8, 16, 32, 128, 96):
        q = sub_block(M)
        assert M % q == 0 and q == 8        # sublane-aligned sub-tiling
    assert sub_block(12) == 12              # no aligned divisor -> fixed
    assert sub_block(4) == 4
    assert sub_block(32, block_m_min=4) == 8    # floor clamped to sublane
