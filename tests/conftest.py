"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
plain 1-device CPU; multi-device tests spawn subprocesses with their own
--xla_force_host_platform_device_count (see test_distributed.py)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
