"""Speculative decoding (repro.spec): greedy spec == non-spec baseline
token for token regardless of draft quality, k, or page size; ONE
DispatchPlan per MoE layer per verify step; host-side rollback via
block-table truncation; stochastic reproducibility (DESIGN.md §13)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import RunConfig, init_params
from repro.sampling import SamplingConfig
from repro.serve.engine import Request, ServeEngine
from repro.serve.kv_cache import PagedKVCache
from repro.spec import SpecEngine, make_draft_config

RC = RunConfig(q_chunk=16, kv_chunk=16)


def dense_cfg(layers=1):
    return reduced(get_config("smollm-360m"), layers=layers, d_model=32)


def moe_cfg(layers=2):
    return reduced(get_config("moonshot-v1-16b-a3b"), layers=layers,
                   d_model=64, vocab=256)


def perturb(params, eps, seed=0):
    """Slightly-wrong draft weights: agrees with the target on easy
    tokens, diverges on close calls — fuzzes the rejection point."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for i, leaf in enumerate(leaves):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            k = jax.random.fold_in(jax.random.key(seed), i)
            leaf = leaf + eps * jax.random.normal(k, leaf.shape, leaf.dtype)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def make_reqs(vocab, n=3, max_new=6, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, vocab,
                                        rng.integers(3, 9)).astype(np.int32),
                    max_new=max_new, **kw) for i in range(n)]


def run_engine(cfg, params, *, spec=None, k=2, kvbs=4, sampling=None,
               reqs=None, slots=2):
    sampling = sampling or SamplingConfig()
    reqs = reqs if reqs is not None else make_reqs(cfg.vocab_size)
    kw = dict(slots=slots, capacity=64, kv_block_size=kvbs,
              prefill_chunk=4, rc=RC, sampling=sampling)
    if spec is None:
        eng = ServeEngine(cfg, params, **kw)
    else:
        dcfg, dparams = spec
        eng = SpecEngine(cfg, params, draft_cfg=dcfg, draft_params=dparams,
                         spec_k=k, **kw)
    done = eng.run(reqs, max_steps=512)
    assert len(done) == len(reqs)
    return eng, {r.rid: list(r.out) for r in reqs}


# ---------------------------------------------------------------------------
# The correctness bar: greedy identity for ANY draft
# ---------------------------------------------------------------------------
# draft quality sweeps the acceptance spectrum: "self" accepts almost
# everything, "random" almost nothing, "perturbed" rejects mid-chain —
# together they fuzz every rollback point; identity must hold for all
@pytest.mark.parametrize("kvbs,k,draft", [
    (4, 1, "random"),
    (4, 2, "perturbed"),
    (4, 3, "self"),
    (8, 2, "perturbed"),
])
def test_greedy_spec_identity_dense(kvbs, k, draft):
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    if draft == "self":
        spec = (cfg, params)
    elif draft == "perturbed":
        spec = (cfg, perturb(params, 3e-2))
    else:
        dcfg = make_draft_config(cfg, reduce=True, layers=1, d_model=32)
        spec = (dcfg, init_params(dcfg, jax.random.key(1)))
    _, base = run_engine(cfg, params, kvbs=kvbs)
    eng, out = run_engine(cfg, params, spec=spec, k=k, kvbs=kvbs)
    assert out == base, f"spec k={k} kvbs={kvbs} draft={draft} diverged"
    assert eng.n_spec_rounds > 0
    assert eng.n_drafted >= eng.n_accepted >= 0
    assert 0.0 <= eng.acceptance_rate <= 1.0


def test_greedy_spec_identity_moe():
    """Identity on the MoE target: the verify forward routes n*(k+1)
    rows through the fused dispatch path."""
    cfg = moe_cfg()
    params = init_params(cfg, jax.random.key(0))
    spec = (cfg, perturb(params, 3e-2))
    _, base = run_engine(cfg, params)
    eng, out = run_engine(cfg, params, spec=spec, k=2)
    assert out == base
    assert eng.n_spec_rounds > 0


def test_spec_respects_eos_and_max_new():
    """Tokens emitted past an accepted eos (or the max_new budget) inside
    a round must be dropped exactly like the baseline drops them."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    # pick an eos that actually occurs: run once greedily, grab a token
    probe = make_reqs(cfg.vocab_size, n=2, max_new=8)
    run_engine(cfg, params, reqs=probe)
    eos = probe[0].out[2]
    for mk in (lambda: make_reqs(cfg.vocab_size, n=2, max_new=8, eos=eos),
               lambda: make_reqs(cfg.vocab_size, n=2, max_new=3)):
        _, base = run_engine(cfg, params, reqs=mk())
        _, out = run_engine(cfg, params, spec=(cfg, params), k=3,
                            reqs=mk())
        assert out == base


# ---------------------------------------------------------------------------
# Plan discipline: the verify forward is ONE batched dispatch
# ---------------------------------------------------------------------------
def _count_plans(monkeypatch):
    import repro.core.dispatch as dispatch_mod
    calls = []
    real = dispatch_mod.plan_dispatch

    def counting(x, w_router, dcfg, **kw):
        calls.append(int(x.shape[0]))
        return real(x, w_router, dcfg, **kw)

    monkeypatch.setattr(dispatch_mod, "plan_dispatch", counting)
    return calls


def test_one_plan_per_moe_layer_per_verify_step(monkeypatch):
    """A spec round = k draft forwards (dense draft: no plans) + ONE
    target verify forward building exactly one DispatchPlan per MoE
    layer, covering all n*(k+1) verify rows.  (rc.unroll python-loops
    the layer stack so traced plan calls are per-layer.)"""
    cfg = moe_cfg(layers=3)                       # 1 dense prefix + 2 moe
    params = init_params(cfg, jax.random.key(0))
    dcfg = make_draft_config(cfg, reduce=True, layers=1, d_model=32)
    dparams = init_params(dcfg, jax.random.key(1))
    rc = RunConfig(q_chunk=16, kv_chunk=16, schedule_policy="dynamic",
                   unroll=True)
    k = 2
    calls = _count_plans(monkeypatch)
    eng = SpecEngine(cfg, params, draft_cfg=dcfg, draft_params=dparams,
                     spec_k=k, slots=2, capacity=64, kv_block_size=4,
                     prefill_chunk=8, rc=rc)
    for i in range(2):
        eng.admit(Request(rid=i, prompt=np.asarray([1 + i, 2, 3], np.int32),
                          max_new=16))
    n_moe_layers = cfg.n_layers - cfg.moe.first_dense_layers
    first = True
    for _ in range(8):
        before = eng.n_spec_rounds
        calls.clear()
        eng.step()
        if eng.n_spec_rounds == before:
            continue                  # prefill / draft catch-up step
        if first:                     # traces the verify forward once:
            assert len(calls) == n_moe_layers, calls
            assert all(t == 2 * (k + 1) for t in calls), calls
            first = False
        else:                         # compiled: no re-trace, ONE jit call
            assert calls == [], calls
    assert not first and eng.n_spec_rounds >= 2


# ---------------------------------------------------------------------------
# Rollback bookkeeping
# ---------------------------------------------------------------------------
def test_truncate_slot_releases_blocks():
    cfg = dense_cfg()
    kv = PagedKVCache(cfg, slots=2, capacity=32, block_size=4,
                      prefix_cache=False)
    kv.ensure_allocated(0, 10)                    # positions 0..10: 3 blocks
    assert int(kv.n_alloc[0]) == 3
    free_before = len(kv.free)
    assert kv.truncate_slot(0, 5) == 1            # keep ceil(5/4) = 2
    assert int(kv.n_alloc[0]) == 2
    assert len(kv.free) == free_before + 1
    assert kv.truncate_slot(0, 5) == 0            # idempotent at the cut
    assert kv.truncate_slot(0, 0) == 2            # drop everything
    assert int(kv.n_alloc[0]) == 0
    # a later write re-allocates cleanly past the truncation
    kv.ensure_allocated(0, 3)
    assert int(kv.n_alloc[0]) == 1


# ---------------------------------------------------------------------------
# Stochastic speculation
# ---------------------------------------------------------------------------
def test_stochastic_spec_reproducible():
    """Same seeds => same speculative stochastic outputs, run to run."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    spec = (cfg, perturb(params, 3e-2))
    sampling = SamplingConfig(method="temperature", temperature=0.8, seed=3)
    eng1, one = run_engine(cfg, params, spec=spec, k=2, sampling=sampling)
    eng2, two = run_engine(cfg, params, spec=spec, k=2, sampling=sampling)
    assert one == two
    assert eng1.n_accepted == eng2.n_accepted
    assert eng1.n_drafted >= eng1.n_accepted
    assert eng1.n_spec_rounds > 0


def test_stochastic_self_draft_accepts():
    """Draft distribution == target distribution => rejection sampling
    accepts with probability 1: every drafted token lands."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    sampling = SamplingConfig(method="temperature", temperature=0.8, seed=5)
    eng, _ = run_engine(cfg, params, spec=(cfg, params), k=2,
                        sampling=sampling)
    assert eng.n_spec_rounds > 0
    assert eng.acceptance_rate > 0.5, eng.acceptance_rate


# ---------------------------------------------------------------------------
# Construction validation
# ---------------------------------------------------------------------------
def test_spec_engine_validation():
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    bad_vocab = make_draft_config(cfg, reduce=True, layers=1, d_model=32)
    bad_vocab = bad_vocab.replace(vocab_size=cfg.vocab_size + 1)
    with pytest.raises(ValueError, match="vocab"):
        SpecEngine(cfg, params, draft_cfg=bad_vocab,
                   draft_params=params, slots=2, capacity=32,
                   kv_block_size=4, rc=RC)
    with pytest.raises(ValueError, match="paged"):
        SpecEngine(cfg, params, draft_cfg=cfg, draft_params=params,
                   slots=2, capacity=32, kv_block_size=0, rc=RC)
    with pytest.raises(ValueError, match="spec_k"):
        SpecEngine(cfg, params, draft_cfg=cfg, draft_params=params,
                   spec_k=0, slots=2, capacity=32, kv_block_size=4, rc=RC)
