"""Plan/execute API (ISSUE 2): executor-registry parity, plan reuse across
backends, per-policy config plumbing, and registry error behavior.

* every registered executor x every schedule policy matches the dense
  oracle BOTH through the back-compat ``moe_ffn`` shim and through the
  two-phase ``plan_dispatch`` / ``execute`` API;
* the shim and the two-phase API are bitwise-identical;
* one ``DispatchPlan`` consumed by two different executors produces
  matching outputs (the plan is backend-independent);
* unknown executor names fail with the available registry listed;
* schedule policies declare the config fields they consume
  (``policy_config_kwargs`` replaces per-policy kwargs branching).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dispatch import MoEDispatchConfig, moe_ffn, route
from repro.execution import (DispatchPlan, available_executors, execute,
                             get_executor, plan_dispatch)
from repro.kernels import ref
from repro.scheduling import (available_policies, capacity_slots,
                              expert_capacity, policy_config_kwargs)

T, K, E, M, D, F = 48, 2, 8, 8, 16, 24


def make_layer(seed=2):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (T, D))
    wr = jax.random.normal(ks[1], (D, E)) * 0.3
    w = {"w_gate": jax.random.normal(ks[2], (E, D, F)) * 0.3,
         "w_up": jax.random.normal(ks[3], (E, D, F)) * 0.3,
         "w_down": jax.random.normal(ks[4], (E, F, D)) * 0.3}
    return x, wr, w


def dense_oracle(x, wr, w, cfg):
    """Ground truth on kept tokens: dense ref with capacity-dropped
    assignments zero-weighted.  Only schedule-consuming backends see the
    capacity policy's drops — the schedule-free dense executor computes the
    undropped routing exactly."""
    weights, indices, _ = route(x, wr, cfg)
    if cfg.schedule_policy == "capacity_factor" \
            and get_executor(cfg.executor).needs_schedule:
        cap = expert_capacity(T, K, E, M, cfg.capacity_factor)
        slot, _ = capacity_slots(indices.reshape(-1), E)
        weights = jnp.where((slot < cap).reshape(indices.shape), weights, 0.0)
    return ref.moe_ffn_dense_ref(x, w["w_gate"], w["w_up"], w["w_down"],
                                 weights, indices)


def test_builtin_executors_registered():
    assert available_executors() == ["dense", "pallas", "xla"]


@pytest.mark.parametrize("policy", sorted(available_policies()))
@pytest.mark.parametrize("executor", sorted(available_executors()))
def test_every_executor_every_policy_matches_oracle(executor, policy):
    x, wr, w = make_layer()
    cfg = MoEDispatchConfig(n_experts=E, top_k=K, block_m=M,
                            executor=executor, schedule_policy=policy,
                            capacity_factor=0.5)   # force real drops
    oracle = np.asarray(dense_oracle(x, wr, w, cfg))

    # (a) through the back-compat shim
    y_shim, aux = moe_ffn(x, wr, w["w_gate"], w["w_up"], w["w_down"], cfg)
    np.testing.assert_allclose(np.asarray(y_shim), oracle,
                               rtol=5e-4, atol=5e-4)
    assert set(aux) >= {"lb_loss", "router_z"}

    # (b) through the two-phase API — bitwise-identical to the shim
    plan = plan_dispatch(x, wr, cfg)
    y_two = execute(plan, x, w, cfg).astype(x.dtype)
    np.testing.assert_array_equal(np.asarray(y_two), np.asarray(y_shim))

    # the plan carries a schedule exactly when the backend needs one
    assert (plan.schedule is not None) == get_executor(executor).needs_schedule


@pytest.mark.parametrize("policy", sorted(available_policies()))
def test_one_plan_two_executors_identical(policy):
    """A DispatchPlan is backend-independent: the SAME plan consumed by the
    xla scan and the pallas kernels produces matching outputs."""
    x, wr, w = make_layer(seed=5)
    cfg = MoEDispatchConfig(n_experts=E, top_k=K, block_m=M, executor="xla",
                            schedule_policy=policy)
    plan = plan_dispatch(x, wr, cfg)
    y_xla = execute(plan, x, w, cfg, executor="xla")
    y_pal = execute(plan, x, w, cfg, executor="pallas")
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pal),
                               rtol=2e-4, atol=2e-4)
    # re-executing the identical plan is deterministic
    np.testing.assert_array_equal(
        np.asarray(y_xla), np.asarray(execute(plan, x, w, cfg,
                                              executor="xla")))


def test_plan_contents():
    x, wr, w = make_layer()
    cfg = MoEDispatchConfig(n_experts=E, top_k=K, block_m=M, executor="xla",
                            emit_stats=True)
    plan = plan_dispatch(x, wr, cfg)
    assert isinstance(plan, DispatchPlan)
    assert plan.weights.shape == (T, K) and plan.indices.shape == (T, K)
    assert plan.logits.shape == (T, E)
    assert plan.combine_scale.shape == (plan.schedule.capacity,)
    assert "sched/pad_waste" in plan.aux and "lb_loss" in plan.aux
    # EP-style plans skip schedule construction
    lean = plan_dispatch(x, wr, cfg, with_schedule=False)
    assert lean.schedule is None and lean.combine_scale is None
    np.testing.assert_array_equal(np.asarray(lean.indices),
                                  np.asarray(plan.indices))


def test_unknown_executor_error_lists_registry():
    x, wr, w = make_layer()
    cfg = MoEDispatchConfig(n_experts=E, top_k=K, block_m=M,
                            executor="triton")
    with pytest.raises(ValueError, match=r"unknown executor 'triton'"):
        moe_ffn(x, wr, w["w_gate"], w["w_up"], w["w_down"], cfg)
    with pytest.raises(ValueError, match=r"dense.*pallas.*xla"):
        get_executor("cuda")


def test_schedule_free_plan_rejected_loudly():
    """A plan without a schedule (dense-built, or with_schedule=False) must
    fail with guidance when handed to a schedule-consuming executor."""
    x, wr, w = make_layer()
    cfg = MoEDispatchConfig(n_experts=E, top_k=K, block_m=M,
                            executor="dense")
    plan = plan_dispatch(x, wr, cfg)            # dense: no schedule
    with pytest.raises(ValueError, match="with_schedule=True"):
        execute(plan, x, w, cfg, executor="xla")


def test_dense_has_no_phase_contract():
    """The dense oracle is whole-plan only — the EP paths must reject it
    loudly instead of silently running another backend."""
    dense = get_executor("dense")
    cfg = MoEDispatchConfig(n_experts=E, top_k=K, block_m=M,
                            executor="dense")
    with pytest.raises(NotImplementedError, match="dense"):
        dense.permute(jnp.zeros((8, 4)), None, cfg)
    with pytest.raises(NotImplementedError, match="dense"):
        dense.expert_ffn(jnp.zeros((8, 4)), {}, None, cfg)


def test_policy_declared_config_fields():
    cfg = MoEDispatchConfig(n_experts=E, top_k=K, block_m=M,
                            capacity_factor=1.25, block_m_min=16)
    assert policy_config_kwargs("fixed", cfg) == {}
    assert policy_config_kwargs("capacity_factor", cfg) == \
        {"capacity_factor": 1.25}
    assert policy_config_kwargs("dynamic", cfg) == {"block_m_min": 16}
    with pytest.raises(ValueError, match="unknown schedule policy"):
        policy_config_kwargs("nope", cfg)


def test_moe_stats_flow_through_model_scan():
    """RunConfig.moe_stats surfaces per-plan ScheduleStats through the
    layer-scan aux carry (what ServeEngine reports per request) — and is
    inert for the schedule-free dense executor."""
    from repro.configs import get_config, reduced
    from repro.models import RunConfig, init_params, loss_fn
    cfg = reduced(get_config("moonshot-v1-16b-a3b"), layers=3, d_model=32)
    params = init_params(cfg, jax.random.key(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    rc = RunConfig(q_chunk=16, kv_chunk=16, loss_chunk=16, moe_stats=True,
                   schedule_policy="dynamic")
    _, metrics = loss_fn(params, cfg, rc, batch)
    assert "sched/pad_waste" in metrics and "sched/occupancy" in metrics
    assert float(metrics["sched/useful_rows"]) > 0
    _, m_dense = loss_fn(params, cfg, rc._replace(executor="dense"), batch)
    assert not any(k.startswith("sched/") for k in m_dense)


def test_deprecated_impl_alias():
    """Pre-registry call sites keep working — but now under a
    DeprecationWarning: cfg.impl mirrors cfg.executor and dispatch_config
    accepts impl= (asserted warnings, ISSUE 4 satellite)."""
    from repro.configs.base import MoEConfig
    from repro.core.moe_layer import dispatch_config
    cfg = MoEDispatchConfig(n_experts=E, top_k=K, block_m=M,
                            executor="pallas")
    with pytest.warns(DeprecationWarning, match="impl is deprecated"):
        assert cfg.impl == "pallas"
    moe = MoEConfig(n_experts=E, top_k=K, d_ff_expert=F, block_m=M)
    with pytest.warns(DeprecationWarning, match=r"impl=.*deprecated"):
        assert dispatch_config(moe, impl="dense").executor == "dense"
