"""Kernel autotuner: cache round-trip + versioning, key schema, the
trace-time ops consult, sweep no-regression, and pick_block totality."""
import json
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.scheduling import BlockSchedule
from repro.tuning import (CACHE_VERSION, TuneCache, candidate_configs,
                          make_key, reset_cache, shape_bucket, sweep_kernel,
                          tune_moe_layer)


def _round_robin_sched(E, M, block_m):
    """Minimal schedule for the raw-kernel call paths ops.grouped_gemm
    consumes (block_expert / block_active / block_m)."""
    nb = M // block_m
    z = jnp.zeros((1,), jnp.int32)
    return BlockSchedule(
        counts=jnp.zeros((E,), jnp.int32),
        group_offsets=jnp.zeros((E + 1,), jnp.int32),
        src_tok=z, pos=z[None],
        block_expert=jnp.asarray(np.arange(nb) % E, jnp.int32),
        block_active=jnp.ones((nb,), jnp.int32),
        capacity=M, block_m=block_m)


# ---------------------------------------------------------------------------
# Cache persistence
# ---------------------------------------------------------------------------
def test_cache_roundtrip(tmp_path):
    c = TuneCache(device="cpu")
    key = make_key("grouped_gemm", M=100, K=64, N=32, E=4)
    c.put(key, block_m=64, block_n=32, block_k=16, us=12.5, default_us=20.0)
    path = tmp_path / "cache.json"
    c.save(path)
    back = TuneCache.load(path)
    assert back is not None
    assert back.device == "cpu"
    assert back.entries == c.entries
    assert back.lookup(key)["block_n"] == 32


def test_version_mismatch_invalidates(tmp_path):
    path = tmp_path / "cache.json"
    doc = TuneCache().to_doc()
    doc["version"] = CACHE_VERSION + 1
    path.write_text(json.dumps(doc))
    assert TuneCache.load(path) is None          # stale -> degrade, no crash
    with pytest.raises(ValueError):
        TuneCache.from_doc(doc)


def test_corrupt_or_missing_file_returns_none(tmp_path):
    bad = tmp_path / "cache.json"
    bad.write_text("{not json")
    assert TuneCache.load(bad) is None
    assert TuneCache.load(tmp_path / "absent.json") is None


def test_merge_local_overlays_packaged():
    key = make_key("grouped_gemm", M=8, K=16, N=16, E=2)
    base = TuneCache({key: {"block_m": 8, "block_n": 512, "block_k": 512}})
    local = TuneCache({key: {"block_m": 8, "block_n": 128, "block_k": 64}},
                      device="tpu")
    merged = base.merge(local)
    assert merged.lookup(key)["block_n"] == 128  # local wins
    assert merged.device == "tpu"


# ---------------------------------------------------------------------------
# Key schema
# ---------------------------------------------------------------------------
def test_key_schema_and_shape_bucket():
    assert shape_bucket(1) == 8 and shape_bucket(8) == 8
    assert shape_bucket(9) == 16 and shape_bucket(1000) == 1024
    key = make_key("fused_gate_up", M=300, K=64, N=256, E=8,
                   dtype="bfloat16", scheme="int8", executor="pallas")
    assert key == "fused_gate_up|E8|K64|N256|M512|bfloat16|int8|pallas"
    # same bucket -> same key; different quant scheme -> different key
    assert key == make_key("fused_gate_up", M=511, K=64, N=256, E=8,
                           dtype="bfloat16", scheme="int8")
    assert key != make_key("fused_gate_up", M=300, K=64, N=256, E=8,
                           dtype="bfloat16", scheme="int4")


# ---------------------------------------------------------------------------
# Trace-time consult in kernels/ops.py
# ---------------------------------------------------------------------------
@pytest.fixture
def env_cache(tmp_path, monkeypatch):
    """Point the process-wide cache at a fresh tmp file."""
    path = tmp_path / "cache.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    reset_cache()
    yield path
    reset_cache()


def test_tuned_blocks_consults_cache(env_cache):
    key = make_key("grouped_gemm", M=16, K=32, N=64, E=2)
    c = TuneCache()
    c.put(key, block_m=8, block_n=16, block_k=8)
    c.save(env_cache)
    reset_cache()
    assert ops._tuned_blocks("grouped_gemm", M=16, K=32, N=64, E=2,
                             dtype=jnp.float32, fmt="dense",
                             block_n=512, block_k=512) == (16, 8)
    # miss (different N) -> caller defaults untouched
    assert ops._tuned_blocks("grouped_gemm", M=16, K=32, N=128, E=2,
                             dtype=jnp.float32, fmt="dense",
                             block_n=512, block_k=512) == (512, 512)


def test_autotuned_grouped_gemm_matches_default(env_cache):
    """A cache hit changes only the tile geometry, never the numbers."""
    E, M, K, N = 2, 16, 32, 64
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, K, N)), jnp.float32)
    sched = _round_robin_sched(E, M, 8)
    base = ops.grouped_gemm(x, w, sched, interpret=True)
    c = TuneCache()
    c.put(make_key("grouped_gemm", M=M, K=K, N=N, E=E),
          block_m=8, block_n=16, block_k=8)
    c.save(env_cache)
    reset_cache()
    tuned = ops.grouped_gemm(x, w, sched, autotune=True, interpret=True)
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(base),
                               atol=1e-5, rtol=1e-5)


def test_invalid_cache_blocks_are_snapped(env_cache):
    """pick_block is the safety net: a cache record with a non-divisor
    block must not trip the kernel's divisibility asserts."""
    E, M, K, N = 2, 16, 32, 64
    c = TuneCache()
    c.put(make_key("grouped_gemm", M=M, K=K, N=N, E=E),
          block_m=8, block_n=48, block_k=7)      # neither divides
    c.save(env_cache)
    reset_cache()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, K, N)), jnp.float32)
    out = ops.grouped_gemm(x, w, _round_robin_sched(E, M, 8),
                           autotune=True, interpret=True)
    assert out.shape == (M, N)


# ---------------------------------------------------------------------------
# pick_block totality + warn-once
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 2, 3, 7, 8, 12, 16, 100, 127, 128, 384,
                               1009, 2018, 4096])
@pytest.mark.parametrize("target", [1, 4, 8, 128, 512])
def test_pick_block_always_divides(n, target):
    b = ops.pick_block(n, target)
    assert 1 <= b <= n and n % b == 0 and b <= max(1, min(n, target))


def test_pick_block_warns_once_on_degenerate_fallback():
    ops._block_warned.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        b = ops.pick_block(10007, 512)           # prime: only divisor is 1
        assert b == 1
        assert len(rec) == 1 and issubclass(rec[0].category, RuntimeWarning)
        ops.pick_block(10007, 512)               # same key: silent
        assert len(rec) == 1
        ops.pick_block(12, 512)                  # fine divisor: no warning
        assert len(rec) == 1


def test_pick_block_k_int4_even_invariant():
    assert ops._pick_block_k(32, 512, "int4") % 2 == 0
    assert ops._pick_block_k(6, 512, "int4") == 6
    b = ops._pick_block_k(2 * 7919, 512, "int4")  # 2*prime: falls back to 2
    assert b % 2 == 0 and (2 * 7919) % b == 0
    with pytest.raises(ValueError):
        ops._pick_block_k(9, 512, "int4")
    assert ops._pick_block_k(9, 512, "dense") in (1, 3, 9)


# ---------------------------------------------------------------------------
# Sweep machinery
# ---------------------------------------------------------------------------
def test_candidate_configs_include_default():
    cands, default = candidate_configs(64, 32, 64, "dense",
                                       targets=(16, 32), block_m=8)
    assert default in cands
    for bm, bn, bk in cands:
        assert 64 % bm == 0 and 64 % bn == 0 and 32 % bk == 0


def test_sweep_winner_not_worse_than_default():
    res = sweep_kernel("grouped_gemm", E=2, M=16, K=32, N=32, reps=1,
                       block_m=8, targets=(16, 32), interpret=True)
    assert res["winner"]["us"] <= res["default"]["us"]
    assert any(r["is_default"] for r in res["records"])
    assert res["key"].startswith("grouped_gemm|E2|K32|N32|M16|")


def test_sweep_rejects_non_pallas_executor():
    with pytest.raises(ValueError, match="pallas"):
        sweep_kernel("grouped_gemm", E=2, M=16, K=32, N=32,
                     executor="xla")


def test_tune_moe_layer_fills_cache():
    cache = TuneCache()
    out = tune_moe_layer(E=2, top_k=1, d_model=32, d_ffn=32, tokens=8,
                        reps=1, targets=(32,), cache=cache)
    assert {r["kernel"] for r in out} == {"fused_gate_up", "grouped_gemm"}
    assert set(cache.entries) == {r["key"] for r in out}
    for rec in cache.entries.values():
        assert rec["us"] <= rec["default_us"]


# ---------------------------------------------------------------------------
# Sub-block floor sweep (the dynamic policy's block_m_min, DESIGN.md §12)
# ---------------------------------------------------------------------------
from repro.tuning import sweep_sub_block  # noqa: E402


def test_sweep_sub_block_no_regression():
    res = sweep_sub_block(E=2, top_k=1, d_model=32, d_ffn=32, block_m=32,
                          tokens=32, reps=1, interpret=True)
    floors = [r["block_m_min"] for r in res["records"]]
    assert 8 in floors                  # hard default is ALWAYS a candidate
    assert sorted(r["sub_block"] for r in res["records"]) == [8, 16, 32]
    assert res["winner"]["us"] <= res["default"]["us"]
    assert res["default"]["sub_block"] == 8
    # key schema: the schedule owns no output tile (N=0), K carries block_m
    assert res["key"].startswith("sub_block|E2|K32|N0|M32|")


def test_sweep_sub_block_rejects_non_pallas():
    with pytest.raises(ValueError, match="pallas"):
        sweep_sub_block(E=2, top_k=1, d_model=32, d_ffn=32, block_m=32,
                        executor="xla")


def test_tune_moe_layer_sweeps_sub_block():
    cache = TuneCache()
    out = tune_moe_layer(E=2, top_k=1, d_model=32, d_ffn=32, tokens=32,
                         reps=1, targets=(32,), cache=cache, block_m=32)
    assert {r["kernel"] for r in out} \
        == {"fused_gate_up", "grouped_gemm", "sub_block"}
    key = next(r["key"] for r in out if r["kernel"] == "sub_block")
    rec = cache.lookup(key)
    assert rec is not None and "block_m_min" in rec     # put(**extra) field
    assert rec["us"] <= rec["default_us"]
    # the record's tile IS the winning grid granularity
    from repro.scheduling.dynamic import sub_block
    assert rec["block_m"] == sub_block(32, rec["block_m_min"])


def test_plan_schedule_consults_sub_block_record(env_cache):
    """Trace-time consult: under autotune=True the dynamic policy's floor
    comes from a swept sub_block record for this routing shape."""
    from repro.core.dispatch import MoEDispatchConfig
    from repro.execution.base import plan_schedule
    cfg = MoEDispatchConfig(n_experts=2, top_k=1, block_m=32,
                            executor="pallas", schedule_policy="dynamic",
                            autotune=True)
    idx = jnp.zeros((32, 1), jnp.int32)
    assert int(plan_schedule(idx, cfg).block_m) == 8    # miss: default floor
    c = TuneCache()
    c.put(make_key("sub_block", M=32, K=32, N=0, E=2),
          block_m=32, block_n=0, block_k=0, block_m_min=32)
    c.save(env_cache)
    reset_cache()
    assert int(plan_schedule(idx, cfg).block_m) == 32   # hit: swept floor
    # autotune=False keeps the config's own floor untouched
    off = cfg._replace(autotune=False)
    assert int(plan_schedule(idx, off).block_m) == 8
    # an explicit config floor still applies on a cache miss
    wide = cfg._replace(block_m_min=16, autotune=False)
    assert int(plan_schedule(idx, wide).block_m) == 16
