"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes as required by the kernel deliverable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schedule import build_schedule, schedule_capacity
from repro.kernels import ops, ref

CASES = [
    # (T, E, k, d, f, block_m)
    (32, 4, 1, 16, 32, 8),
    (64, 8, 2, 32, 48, 8),
    (128, 16, 4, 64, 64, 16),
    (256, 8, 2, 128, 256, 128),   # full MXU-aligned tile
]
DTYPES = [jnp.float32, jnp.bfloat16]


def make_inputs(T, E, k, d, f, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 6)
    logits = jax.random.normal(ks[0], (T, E), jnp.float32)
    x = (jax.random.normal(ks[1], (T, d)) * 0.5).astype(dtype)
    wg = (jax.random.normal(ks[2], (E, d, f)) * 0.2).astype(dtype)
    wu = (jax.random.normal(ks[3], (E, d, f)) * 0.2).astype(dtype)
    wd = (jax.random.normal(ks[4], (E, f, d)) * 0.2).astype(dtype)
    return logits, x, wg, wu, wd


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("gating,norm_topk", [("softmax", False),
                                              ("sigmoid", True),
                                              ("sigmoid", False)])
@pytest.mark.parametrize("T,E,k", [(32, 4, 1), (64, 8, 2), (128, 64, 6),
                                   (64, 256, 8)])
def test_router_kernel(T, E, k, gating, norm_topk):
    logits = jax.random.normal(jax.random.key(1), (T, E), jnp.float32)
    w_r, i_r = ref.router_ref(logits, k, gating=gating, norm_topk=norm_topk,
                              routed_scale=2.0)
    w_k, i_k = ops.router_topk(logits, top_k=k, gating=gating,
                               norm_topk=norm_topk, routed_scale=2.0)
    np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_k))
    np.testing.assert_allclose(np.asarray(w_r), np.asarray(w_k),
                               rtol=1e-5, atol=1e-6)


def test_router_masking_many_experts():
    """Paper §3.4: selected experts must never be re-selected even when
    scores are near zero (E=256 regime)."""
    T, E, k = 16, 256, 8
    logits = jnp.zeros((T, E)) - 10.0   # all scores tiny and EQUAL
    _, idx = ops.router_topk(logits, top_k=k, gating="softmax")
    for t in range(T):
        assert len(set(np.asarray(idx)[t].tolist())) == k


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("T,E,k,d,f,M", CASES)
def test_permute_kernel(T, E, k, d, f, M, dtype):
    logits, x, *_ = make_inputs(T, E, k, d, f, dtype)
    _, idx = ref.router_ref(logits, k)
    sched = build_schedule(idx, E, M)
    out_k = ops.permute(x, sched, block_d=min(d, 512))
    out_r = ref.permute_ref(x, sched)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("T,E,k,d,f,M", CASES)
def test_fused_gate_up_kernel(T, E, k, d, f, M, dtype):
    logits, x, wg, wu, _ = make_inputs(T, E, k, d, f, dtype)
    _, idx = ref.router_ref(logits, k)
    sched = build_schedule(idx, E, M)
    xp = ref.permute_ref(x, sched)
    out_k = ops.fused_gate_up(xp, wg, wu, sched, block_n=min(f, 128),
                              block_k=min(d, 128))
    out_r = ref.fused_gate_up_ref(xp, wg, wu, sched)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("with_scale", [False, True])
@pytest.mark.parametrize("T,E,k,d,f,M", CASES[:3])
def test_grouped_gemm_kernel(T, E, k, d, f, M, with_scale, dtype):
    logits, x, wg, _, wd = make_inputs(T, E, k, d, f, dtype)
    w, idx = ref.router_ref(logits, k)
    sched = build_schedule(idx, E, M)
    xp = ref.permute_ref(x, sched)
    h = ref.fused_gate_up_ref(xp, wg, wg, sched)
    scale = None
    if with_scale:
        from repro.core.dispatch import combine_scale_rows
        scale = combine_scale_rows(sched, w)
    out_k = ops.grouped_gemm(h, wd, sched, row_scale=scale,
                             block_n=min(d, 128), block_k=min(f, 128))
    out_r = ref.grouped_gemm_ref(h, wd, sched, row_scale=scale)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("folded", [False, True])
@pytest.mark.parametrize("T,E,k,d,f,M", CASES[:3])
def test_unpermute_kernel(T, E, k, d, f, M, folded, dtype):
    logits, x, wg, wu, wd = make_inputs(T, E, k, d, f, dtype)
    w, idx = ref.router_ref(logits, k)
    sched = build_schedule(idx, E, M)
    y = ref.permute_ref(x, sched)                 # any padded-layout tensor
    weights = None if folded else w
    out_k = ops.unpermute(y, sched, weights, block_d=min(d, 512))
    out_r = ref.unpermute_ref(y, sched, weights)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **tol(dtype))


def test_pipeline_matches_dense_oracle():
    """Whole 5-kernel pipeline == dense loop-over-experts oracle."""
    T, E, k, d, f, M = 96, 8, 2, 32, 64, 8
    logits, x, wg, wu, wd = make_inputs(T, E, k, d, f, jnp.float32)
    w, idx = ref.router_ref(logits, k)
    sched = build_schedule(idx, E, M)
    xp = ops.permute(x, sched)
    h = ops.fused_gate_up(xp, wg, wu, sched, block_n=32, block_k=16)
    from repro.core.dispatch import combine_scale_rows
    y = ops.grouped_gemm(h, wd, sched,
                         row_scale=combine_scale_rows(sched, w),
                         block_n=16, block_k=32)
    out = ops.unpermute(y, sched, None)
    dense = ref.moe_ffn_dense_ref(x, wg, wu, wd, w, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("T,E,k,d,f,M", CASES[:3])
def test_grouped_wgrad_kernel(T, E, k, d, f, M):
    """Training-backward tgmm (beyond-paper: the paper is inference-only)."""
    logits, x, _, _, _ = make_inputs(T, E, k, d, f, jnp.float32)
    _, idx = ref.router_ref(logits, k)
    sched = build_schedule(idx, E, M)
    xp = ref.permute_ref(x, sched)
    dy = ref.permute_ref(
        jax.random.normal(jax.random.key(9), (T, f)), sched)
    dw_k = ops.grouped_wgrad(xp, dy, sched, E, block_k=min(d, 128),
                             block_n=min(f, 128))
    dw_r = ref.grouped_wgrad_ref(xp, dy, sched, E)
    np.testing.assert_allclose(np.asarray(dw_k), np.asarray(dw_r),
                               rtol=1e-4, atol=1e-4)


def test_grouped_wgrad_empty_experts_zeroed():
    """Experts with zero routed tokens must get exactly-zero gradients
    (their output blocks are never visited by the kernel)."""
    T, E, k, d, f, M = 32, 8, 1, 16, 16, 8
    # route everything to experts {0, 3}: 1,2,4,5,6,7 are empty
    idx = jnp.asarray(np.random.default_rng(0).choice([0, 3], (T, k)),
                      jnp.int32)
    sched = build_schedule(idx, E, M)
    x = ref.permute_ref(jax.random.normal(jax.random.key(1), (T, d)), sched)
    dy = ref.permute_ref(jax.random.normal(jax.random.key(2), (T, f)), sched)
    dw = ops.grouped_wgrad(x, dy, sched, E, block_k=16, block_n=16)
    dw_r = ref.grouped_wgrad_ref(x, dy, sched, E)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_r),
                               rtol=1e-4, atol=1e-4)
    for e in (1, 2, 4, 5, 6, 7):
        assert np.all(np.asarray(dw)[e] == 0.0)
    assert float(jnp.sum(jnp.abs(dw[0]))) > 0
