"""Seeded sampling (repro.sampling): processor semantics, keyed-draw
determinism, and the engine-level identity bar — same per-request seed
=> same tokens, regardless of batching, slot order, or neighbors
(DESIGN.md §13, TESTING.md)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import RunConfig, init_params
from repro.sampling import (ROLE_ACCEPT, ROLE_SAMPLE, SamplingConfig,
                            available_samplers, get_sampler, process_logits,
                            row_key, sample_rows, uniform_rows)
from repro.serve.engine import Request, ServeEngine

RC = RunConfig(q_chunk=16, kv_chunk=16)


def dense_cfg(layers=1):
    return reduced(get_config("smollm-360m"), layers=layers, d_model=32)


# ---------------------------------------------------------------------------
# Processors
# ---------------------------------------------------------------------------
def test_registry_contents():
    assert {"greedy", "temperature", "top_k", "top_p"} \
        <= set(available_samplers())
    with pytest.raises(ValueError):
        get_sampler("nope")


def test_greedy_processor_is_identity():
    lg = jnp.asarray([[0.3, -1.0, 2.0]])
    out = process_logits(lg, SamplingConfig())
    assert (out == lg).all()


def test_temperature_scales_logits():
    lg = jnp.asarray([[2.0, -4.0, 0.5]])
    out = process_logits(lg, SamplingConfig(method="temperature",
                                            temperature=0.5))
    np.testing.assert_allclose(np.asarray(out), np.asarray(lg) / 0.5,
                               rtol=1e-6)


def test_top_k_masks_all_but_k_largest():
    lg = jnp.asarray([[1.0, 4.0, 2.0, 3.0, 0.0]])
    out = np.asarray(process_logits(
        lg, SamplingConfig(method="top_k", top_k=2)))
    assert np.isfinite(out[0, [1, 3]]).all()      # the two largest survive
    assert np.isneginf(out[0, [0, 2, 4]]).all()
    # k >= V or k == 0 disable truncation
    for k in (0, 5, 9):
        out = np.asarray(process_logits(
            lg, SamplingConfig(method="top_k", top_k=k)))
        assert np.isfinite(out).all()


def test_top_p_keeps_smallest_nucleus():
    # softmax([3, 2, 0, -1]) ~ [.70, .26, .035, .013]: p=.8 needs top-2
    lg = jnp.asarray([[3.0, 2.0, 0.0, -1.0]])
    out = np.asarray(process_logits(
        lg, SamplingConfig(method="top_p", top_p=0.8)))
    assert np.isfinite(out[0, [0, 1]]).all()
    assert np.isneginf(out[0, [2, 3]]).all()
    # tiny p still keeps the top-1 token (never an all -inf row)
    out = np.asarray(process_logits(
        lg, SamplingConfig(method="top_p", top_p=1e-6)))
    assert np.isfinite(out[0, 0]) and np.isneginf(out[0, 1:]).all()
    # p = 1.0 disables truncation
    out = np.asarray(process_logits(
        lg, SamplingConfig(method="top_p", top_p=1.0)))
    assert np.isfinite(out).all()


# ---------------------------------------------------------------------------
# Keyed draws
# ---------------------------------------------------------------------------
def test_greedy_sample_rows_is_exact_argmax():
    lg = jax.random.normal(jax.random.key(0), (7, 33))
    tok = sample_rows(lg, SamplingConfig(), jnp.zeros(7, jnp.int32),
                      jnp.zeros(7, jnp.int32))
    assert (np.asarray(tok) == np.asarray(jnp.argmax(lg, -1))).all()


def test_sample_rows_batched_equals_per_row_oracle():
    """The whole point of keyed draws: the token for (seed, counter) does
    not depend on which rows share the batch, or in what order."""
    cfg = SamplingConfig(method="temperature", temperature=0.7, seed=0)
    lg = jax.random.normal(jax.random.key(1), (6, 64))
    seeds = jnp.asarray([5, 5, 9, 9, 5, 2], jnp.int32)
    counters = jnp.asarray([0, 1, 0, 1, 2, 0], jnp.int32)
    batched = np.asarray(sample_rows(lg, cfg, seeds, counters))
    solo = np.asarray([
        sample_rows(lg[i:i + 1], cfg, seeds[i:i + 1], counters[i:i + 1])[0]
        for i in range(6)])
    assert (batched == solo).all()
    # row permutation permutes tokens, nothing else
    perm = np.asarray([3, 0, 5, 1, 4, 2])
    permuted = np.asarray(sample_rows(lg[perm], cfg, seeds[perm],
                                      counters[perm]))
    assert (permuted == batched[perm]).all()


def test_role_streams_are_independent():
    k0 = row_key(3, 7, ROLE_SAMPLE)
    k1 = row_key(3, 7, ROLE_ACCEPT)
    assert not (np.asarray(k0) == np.asarray(k1)).all()


def test_uniform_rows_columns_follow_counters():
    """Column i of uniform_rows uses counter+i: shifting a row's counter
    by one shifts its uniforms by one column."""
    seeds = jnp.asarray([4, 4], jnp.int32)
    u0 = np.asarray(uniform_rows(seeds, jnp.asarray([0, 3], jnp.int32), 4))
    u1 = np.asarray(uniform_rows(seeds, jnp.asarray([1, 4], jnp.int32), 4))
    np.testing.assert_array_equal(u0[:, 1:], u1[:, :-1])
    assert ((0.0 <= u0) & (u0 < 1.0)).all()


# ---------------------------------------------------------------------------
# Engine-level determinism (the tentpole's correctness bar)
# ---------------------------------------------------------------------------
TEMP = SamplingConfig(method="temperature", temperature=0.8, seed=11)


def _run(cfg, params, reqs, *, slots, sampling, **kw):
    eng = ServeEngine(cfg, params, slots=slots, capacity=32, rc=RC,
                      sampling=sampling, **kw)
    eng.run(reqs, max_steps=256)
    return {r.rid: list(r.out) for r in reqs}


@pytest.mark.parametrize("kv_block_size", [4, 0])
def test_batched_matches_unbatched_oracle(kv_block_size):
    """Same per-request seed => same tokens whether the request decodes
    alone or batched with neighbors (paged and contiguous engines)."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))

    def mk():
        return [Request(rid=i, prompt=np.asarray([1 + i, 5, 9], np.int32),
                        max_new=6, seed=100 + i) for i in range(3)]

    solo = {}
    for r in mk():
        solo.update(_run(cfg, params, [r], slots=1, sampling=TEMP,
                         kv_block_size=kv_block_size))
    batched = _run(cfg, params, mk(), slots=2, sampling=TEMP,
                   kv_block_size=kv_block_size)
    assert batched == solo
    assert any(len(t) == 6 for t in batched.values())


def test_slot_permutation_identity():
    """Submission order maps requests to different slots; per-request
    outputs must not change."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))

    def mk(order):
        reqs = [Request(rid=i, prompt=np.asarray([1 + i, 2, 7], np.int32),
                        max_new=5, seed=50 + i) for i in range(3)]
        return [reqs[i] for i in order]

    fwd = _run(cfg, params, mk([0, 1, 2]), slots=2, sampling=TEMP)
    rev = _run(cfg, params, mk([2, 1, 0]), slots=2, sampling=TEMP)
    assert fwd == rev


def test_per_request_seeds_are_independent():
    """Identical prompts with different seeds draw from independent
    streams; same seed reproduces exactly."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    prompt = np.asarray([3, 1, 4], np.int32)
    a = Request(rid=0, prompt=prompt, max_new=8, seed=1)
    b = Request(rid=1, prompt=prompt, max_new=8, seed=2)
    c = Request(rid=2, prompt=prompt, max_new=8, seed=1)
    out = _run(cfg, params, [a, b, c], slots=3, sampling=TEMP)
    assert out[0] == out[2]            # same seed, same stream
    assert out[0] != out[1]            # different seed, different stream


def test_seedless_requests_derive_from_engine_base():
    """Request.seed=None derives base+rid: reproducible across runs, and
    changing the engine base seed changes the draws."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))

    def mk():
        return [Request(rid=i, prompt=np.asarray([2, 6, 1], np.int32),
                        max_new=6) for i in range(2)]

    one = _run(cfg, params, mk(), slots=2, sampling=TEMP)
    two = _run(cfg, params, mk(), slots=2, sampling=TEMP)
    assert one == two
    other = _run(cfg, params, mk(), slots=2,
                 sampling=TEMP._replace(seed=99))
    assert one != other


def test_greedy_engine_ignores_seeds():
    """Greedy stays the exact argmax path: seeds cannot perturb it."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))

    def mk(seed):
        return [Request(rid=0, prompt=np.asarray([1, 5, 9, 2], np.int32),
                        max_new=5, seed=seed)]

    base = _run(cfg, params, mk(None), slots=1, sampling=SamplingConfig())
    seeded = _run(cfg, params, mk(1234), slots=1, sampling=SamplingConfig())
    assert base == seeded
