"""Open-stream front-end, SLO admission/preemption, and the trace-driven
load generator (DESIGN.md §11).

Everything time-dependent runs on a ``VirtualClock`` injected as the
observability clock with ``engine.step_time_hint`` pricing feasibility,
so admission decisions, preemptions and goodput numbers are pure
functions of (seed, config) — no wall-clock racing in CI.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import RunConfig, init_params
from repro.obs import drop_summary, latency_summary
from repro.serve.admission import get_admission
from repro.serve.engine import Request, ServeEngine
from repro.serve.frontend import ServingFrontend
from repro.serve.loadgen import (PATTERNS, VirtualClock, make_virtual_obs,
                                 replay, synth_trace)

RC = RunConfig(q_chunk=16, kv_chunk=16)


def dense_cfg():
    return reduced(get_config("smollm-360m"), layers=1, d_model=32)


def virt_engine(cfg, params, **kw):
    clock, obs = make_virtual_obs(enabled=kw.pop("metrics", False))
    eng = ServeEngine(cfg, params, rc=RC, obs=obs, **kw)
    return eng, clock


# ----------------------------------------------------------------------
# front-end queue semantics
# ----------------------------------------------------------------------
def test_submit_reports_each_completion_once():
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=RC)
    fe = ServingFrontend(eng)
    rng = np.random.default_rng(0)
    handles = [fe.submit(rng.integers(0, cfg.vocab_size, 4), max_new=3)
               for _ in range(4)]
    assert fe.outstanding == 4
    assert len({r.rid for r in handles}) == 4        # auto-rids unique
    seen = []
    for _ in range(200):
        seen += [r.rid for r in fe.poll()]
        if not fe.outstanding:
            break
    assert sorted(seen) == sorted(r.rid for r in handles)
    assert len(seen) == len(set(seen))               # no double report
    assert all(r.done and r.out for r in handles)


def test_duplicate_inflight_rid_rejected():
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    fe = ServingFrontend(ServeEngine(cfg, params, slots=1, capacity=32,
                                     rc=RC))
    fe.submit(np.asarray([1, 2, 3], np.int32), max_new=2, rid=7)
    with pytest.raises(ValueError):
        fe.submit(np.asarray([4, 5], np.int32), max_new=2, rid=7)


def test_drain_finalizes_censored_stats():
    """Requests still unfinished when drain()'s budget runs out carry
    finite censored lat/* stats and a serve/dropped marker — and remain
    resumable by a later drain (same tokens as an uninterrupted run)."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(3)]
    ref = [Request(rid=i, prompt=p, max_new=4)
           for i, p in enumerate(prompts)]
    ServeEngine(cfg, params, slots=1, capacity=32, rc=RC).run(ref)

    eng = ServeEngine(cfg, params, slots=1, capacity=32, rc=RC)
    fe = ServingFrontend(eng)
    handles = [fe.submit(p, max_new=4, rid=i)
               for i, p in enumerate(prompts)]
    fe.drain(max_steps=2)
    undone = [r for r in handles if not r.done]
    assert undone
    for r in undone:
        assert r.stats.get("serve/dropped") == 1.0
        assert all(np.isfinite(v) for v in r.stats.values())
    ds = drop_summary(handles)
    assert ds and ds["n"] == len(undone) and ds["wait_s"]
    # the all-dropped completion summary stays empty rather than lying
    assert not any(latency_summary([r for r in handles
                                    if r.done]).values()) or ds["n"] < 3
    fe.drain(max_steps=300)
    assert all(r.done for r in handles)
    assert {r.rid: r.out for r in handles} == {r.rid: r.out for r in ref}


# ----------------------------------------------------------------------
# slo admission policy
# ----------------------------------------------------------------------
def test_slo_admission_orders_by_deadline_feasibility():
    """Feasible deadline-holders admit earliest-deadline-first; blown
    deadlines drop to backfill behind no-deadline traffic."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    eng, clock = virt_engine(cfg, params, slots=1, capacity=64,
                             kv_block_size=4, prefill_chunk=4,
                             admission="slo")
    eng.step_time_hint = 0.05
    prompt = np.arange(8, dtype=np.int32)
    reqs = [Request(rid=0, prompt=prompt, max_new=2),            # no slo
            Request(rid=1, prompt=prompt, max_new=2, slo_ttft=0.5),
            Request(rid=2, prompt=prompt, max_new=2, slo_ttft=0.3),
            Request(rid=3, prompt=prompt, max_new=2, slo_ttft=0.01)]
    pending = eng.enqueue(reqs)
    policy = get_admission("slo")
    # rid 3 is already infeasible (2 prefill steps * 0.05 > 0.01): the
    # earliest FEASIBLE deadline (rid 2) wins the slot
    assert policy(pending, engine=eng) == 2
    pending.pop(2)
    assert policy(pending, engine=eng) == 1      # next feasible deadline
    pending.pop(1)
    # no-deadline FCFS beats the blown deadline (work-conserving order)
    assert policy(pending, engine=eng) == 0
    pending.pop(0)
    assert policy(pending, engine=eng) == 0      # backfill runs last
    assert pending[0].rid == 3


def test_slo_admission_prices_tpot_feasibility():
    """A request demanding a faster decode pace than the engine's current
    step-time estimate is infeasible AT ADMIT TIME: it drops to the
    backfill group behind feasible deadline-holders and no-deadline
    traffic, instead of admitting first and being preempted later."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    eng, clock = virt_engine(cfg, params, slots=1, capacity=64,
                             kv_block_size=4, prefill_chunk=4,
                             admission="slo")
    eng.step_time_hint = 0.05            # one decode token per 50ms step
    prompt = np.arange(4, dtype=np.int32)
    reqs = [Request(rid=0, prompt=prompt, max_new=2),            # no slo
            Request(rid=1, prompt=prompt, max_new=2,
                    slo_ttft=0.5, slo_tpot=0.01),   # pace unachievable
            Request(rid=2, prompt=prompt, max_new=2,
                    slo_ttft=0.5, slo_tpot=0.2)]    # pace achievable
    pending = eng.enqueue(reqs)
    policy = get_admission("slo")
    # rid 1's TTFT is reachable but its TPOT budget (10ms/token) is below
    # the engine's pace: the feasible competitor (rid 2) is admitted first
    assert policy(pending, engine=eng) == 2
    pending.pop(2)
    # ... and even no-deadline traffic beats the TPOT-infeasible request
    assert policy(pending, engine=eng) == 0
    pending.pop(0)
    assert policy(pending, engine=eng) == 0      # backfill runs last
    assert pending[0].rid == 1
    # a faster engine flips rid 1 back into the feasible group
    eng.step_time_hint = 0.005
    reqs2 = [Request(rid=3, prompt=prompt, max_new=2),
             Request(rid=4, prompt=prompt, max_new=2,
                     slo_ttft=0.5, slo_tpot=0.01)]
    pending2 = eng.enqueue(reqs2)
    assert policy(pending2, engine=eng) == 1


def test_slo_preempts_hopeless_prefill_for_feasible_arrival():
    """An active long prefill whose TTFT deadline became unreachable is
    parked the moment a feasible deadline-holder waits — and both
    requests finish with tokens identical to an unpreempted fcfs run."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    long_p = np.arange(1, 33, dtype=np.int32)        # 8 prefill steps
    short_p = np.asarray([40, 41, 42], np.int32)

    ref = [Request(rid=0, prompt=long_p, max_new=3),
           Request(rid=1, prompt=short_p, max_new=3)]
    ServeEngine(cfg, params, slots=2, capacity=64, rc=RC,
                kv_block_size=4, prefill_chunk=4).run(ref)

    eng, clock = virt_engine(cfg, params, slots=1, capacity=64,
                             kv_block_size=4, prefill_chunk=4,
                             admission="slo")
    eng.step_time_hint = 0.05
    fe = ServingFrontend(eng)
    h0 = fe.submit(long_p, max_new=3, slo_ttft=0.2)  # will blow TTFT
    clock.advance(0.05)
    fe.poll()                                        # admits the long one
    assert eng.n_active == 1
    h1 = fe.submit(short_p, max_new=3, slo_ttft=0.3)  # feasible rival
    for _ in range(300):
        clock.advance(0.05)
        fe.poll()
        if not fe.outstanding:
            break
    assert eng.n_preempted >= 1 and eng.n_resumed == eng.n_preempted
    assert h0.done and h1.done
    assert [h0.out, h1.out] == [ref[0].out, ref[1].out]
    # the preempted-and-resumed request keeps its original submit anchor
    assert h0.stats["lat/ttft_s"] > h1.stats["lat/ttft_s"]


def test_slo_never_preempts_without_demand():
    """Preemption is throttled by feasible waiting demand: an empty (or
    deadline-free) queue never evicts an over-budget active request."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    eng, clock = virt_engine(cfg, params, slots=1, capacity=64,
                             kv_block_size=4, prefill_chunk=4,
                             admission="slo")
    eng.step_time_hint = 0.05
    fe = ServingFrontend(eng)
    fe.submit(np.arange(1, 33, dtype=np.int32), max_new=3, slo_ttft=0.01)
    fe.submit(np.asarray([50, 51], np.int32), max_new=3)   # no deadline
    for _ in range(300):
        clock.advance(0.05)
        fe.poll()
        if not fe.outstanding:
            break
    assert eng.n_preempted == 0
    assert not fe.outstanding


# ----------------------------------------------------------------------
# prefix-probe memoization (admission satellite)
# ----------------------------------------------------------------------
def test_probe_prefix_memoized_until_pool_mutates(monkeypatch):
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=32, rc=RC,
                      kv_block_size=4)
    warm = Request(rid=0, prompt=np.arange(1, 10, dtype=np.int32),
                   max_new=2)
    eng.run([warm])                                  # registers hashes

    import repro.serve.kv_cache as kv_mod
    calls = []
    real = kv_mod._chain_digest
    monkeypatch.setattr(kv_mod, "_chain_digest",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    prompt = np.arange(1, 12, dtype=np.int32)
    first = eng.kv.probe_prefix(prompt, memo_key=101)
    assert first == 8 and calls                      # cold probe hashes
    n_cold = len(calls)
    assert eng.kv.probe_prefix(prompt, memo_key=101) == first
    assert len(calls) == n_cold                      # memo hit: no hashing
    # registering new content invalidates every memo entry
    eng.run([Request(rid=1, prompt=np.asarray([60, 61, 62, 63, 64],
                                              np.int32), max_new=2)])
    assert eng.kv.probe_prefix(prompt, memo_key=101) == first
    assert len(calls) > n_cold                       # re-probed after gen bump


# ----------------------------------------------------------------------
# load generator
# ----------------------------------------------------------------------
def test_synth_trace_shapes_and_determinism():
    for pattern in PATTERNS:
        a = synth_trace(pattern, seed=3, n=10, rate=5.0, vocab=100)
        b = synth_trace(pattern, seed=3, n=10, rate=5.0, vocab=100)
        assert len(a) == 10
        assert all(ev.t <= nxt.t for ev, nxt in zip(a, a[1:]))
        assert [(ev.t, ev.prompt.tolist()) for ev in a] \
            == [(ev.t, ev.prompt.tolist()) for ev in b]
    fleet = synth_trace("shared_prefix", seed=0, n=8, rate=4.0, vocab=100,
                        prefix_len=6)
    head = fleet[0].prompt[:6].tolist()
    assert all(ev.prompt[:6].tolist() == head for ev in fleet)
    with pytest.raises(ValueError):
        synth_trace("nope", seed=0, n=1, rate=1.0, vocab=10)


def test_replay_deterministic_and_artifact_keys():
    """Same (seed, config) -> identical replay record; the record carries
    every key the CI loadgen smoke asserts on."""
    cfg = dense_cfg()
    params = init_params(cfg, jax.random.key(0))

    def once():
        trace = synth_trace("burst", seed=2, n=8, rate=8.0,
                            vocab=cfg.vocab_size, max_new=4, slo_ttft=0.4,
                            burst_size=4, prompt_hi=24)
        eng, clock = virt_engine(cfg, params, slots=2, capacity=64,
                                 kv_block_size=4, prefill_chunk=4,
                                 admission="slo", metrics=True)
        return replay(eng, trace, clock=clock, step_time=0.05, seed=2,
                      pattern="burst")
    a, b = once(), once()
    assert a == b                                    # virtual-time purity
    for key in ("goodput_rps", "slo_attainment", "preempted", "resumed",
                "ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
                "completed", "dropped", "config", "obs_counters"):
        assert key in a, key
    assert a["completed"] == 8
    assert a["config"]["admission"] == "slo"
    assert a["config"]["seed"] == 2
    for k in ("executor", "quant", "kv_block_size", "prefill_chunk",
              "schedule_policy"):
        assert k in a["config"], k


def test_virtual_clock_monotonic():
    c = VirtualClock(1.0)
    assert c() == 1.0
    c.advance(0.25)
    assert c() == 1.25
