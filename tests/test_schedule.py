"""Property-based tests (hypothesis) for the block-schedule invariants —
the correctness heart of the paper's Algorithm 1 in its tile-aligned TPU
form."""
import jax.numpy as jnp
import numpy as np

from hypothesis_compat import given, settings, st

from repro.core.schedule import build_schedule, schedule_capacity


@st.composite
def assignments(draw):
    T = draw(st.integers(1, 64))
    E = draw(st.sampled_from([2, 4, 8, 16]))
    k = draw(st.integers(1, min(4, E)))
    M = draw(st.sampled_from([4, 8, 16]))
    idx = draw(st.lists(st.lists(st.integers(0, E - 1), min_size=k,
                                 max_size=k), min_size=T, max_size=T))
    return np.asarray(idx, np.int32), E, k, M


@given(assignments())
@settings(max_examples=60, deadline=None)
def test_schedule_invariants(case):
    idx, E, k, M = case
    T = idx.shape[0]
    sched = build_schedule(jnp.asarray(idx), E, M)
    counts = np.asarray(sched.counts)
    pos = np.asarray(sched.pos)
    src = np.asarray(sched.src_tok)
    be = np.asarray(sched.block_expert)
    active = np.asarray(sched.block_active)
    offs = np.asarray(sched.group_offsets)

    # (1) counts match the raw assignment histogram
    np.testing.assert_array_equal(
        counts, np.bincount(idx.reshape(-1), minlength=E))

    # (2) every expanded token has a unique padded row
    assert len(set(pos.reshape(-1).tolist())) == T * k

    # (3) each row sits inside its expert's padded segment
    for t in range(T):
        for j in range(k):
            e = idx[t, j]
            assert offs[e] <= pos[t, j] < offs[e + 1]

    # (4) src_tok inverts pos (padding rows are -1)
    for t in range(T):
        for j in range(k):
            assert src[pos[t, j]] == t
    n_real = (src >= 0).sum()
    assert n_real == T * k

    # (5) tile-alignment: every active block maps to exactly one expert
    capacity = sched.capacity
    assert capacity == schedule_capacity(T, k, E, M)
    for b in range(capacity // M):
        rows = src[b * M:(b + 1) * M]
        owners = {idx.reshape(-1)[r * k:(r + 1) * k].tolist() and None
                  for r in rows if r >= 0}
        if active[b]:
            lo, hi = offs[be[b]], offs[be[b] + 1]
            assert lo <= b * M < hi
        else:
            assert (rows == -1).all()

    # (6) padded segment sizes are multiples of M
    seg = np.diff(offs)
    assert (seg % M == 0).all()
    assert (seg >= counts).all()


@given(assignments())
@settings(max_examples=30, deadline=None)
def test_dispatch_equals_dense_oracle(case):
    """End-to-end xla dispatch == dense oracle under arbitrary routing."""
    idx, E, k, M = case
    import jax
    from repro.kernels import ref
    from repro.core.dispatch import (combine_scale_rows, fused_gate_up_xla,
                                     grouped_gemm_xla)
    T = idx.shape[0]
    d, f = 8, 12
    ks = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(ks[0], (T, d))
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.3
    wu = jax.random.normal(ks[2], (E, d, f)) * 0.3
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.3
    w = jnp.ones((T, k)) / k
    sched = build_schedule(jnp.asarray(idx), E, M)
    xp = ref.permute_ref(x, sched)
    h = fused_gate_up_xla(xp, wg, wu, sched)
    y = grouped_gemm_xla(h, wd, sched,
                         row_scale=combine_scale_rows(sched, w))
    out = ref.unpermute_ref(y, sched, None)
    dense = ref.moe_ffn_dense_ref(x, wg, wu, wd, w, jnp.asarray(idx))
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=5e-4, atol=5e-4)
