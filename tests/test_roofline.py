"""Roofline methodology validation.

1. HLO collective parser unit tests on known synthetic HLO lines.
2. The scan-undercount premise: cost_analysis counts a scan body once.
3. Analytic FLOP model vs an UNROLLED compile of a reduced arch (the
   analytic numbers drive EXPERIMENTS.md §Roofline; this pins them to
   XLA's own counting within tolerance).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import cost_analysis

from repro.analysis.hlo import collective_report, parse_collectives


SYNTH = """
ENTRY %main.1 (p0: f32[16,16]) -> f32[16,16] {
  %ag = bf16[64,128]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}, metadata={op_name="jit(f)/while/body/jvp(layer_stack)/dot"}
  %ar = f32[32,32]{1,0} all-reduce(%y), channel_id=2, replica_groups=[4,4]<=[16], metadata={op_name="jit(f)/opt"}
  %rs = f32[8,8]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[2,8]<=[16], dimensions={0}
  %a2a = bf16[4,16]{1,0} all-to-all(%w), channel_id=4, replica_groups=[1,16]<=[16], dimensions={0}
  %cp = f32[10]{0} collective-permute(%v), channel_id=5, source_target_pairs={{0,1}}
}
"""


def test_parser_link_byte_formulas():
    ops = parse_collectives(SYNTH)
    by = {o.kind: o for o in ops}
    assert by["all-gather"].result_bytes == 64 * 128 * 2
    assert by["all-gather"].group_size == 16
    np.testing.assert_allclose(by["all-gather"].link_bytes,
                               64 * 128 * 2 * 15 / 16)
    np.testing.assert_allclose(by["all-reduce"].link_bytes,
                               2 * 32 * 32 * 4 * 3 / 4)
    np.testing.assert_allclose(by["reduce-scatter"].link_bytes,
                               8 * 8 * 4 * 8 * 7 / 8)
    np.testing.assert_allclose(by["all-to-all"].link_bytes,
                               4 * 16 * 2 * 15 / 16)
    np.testing.assert_allclose(by["collective-permute"].link_bytes, 40)


def test_parser_loop_multipliers():
    rep = collective_report(SYNTH, layer_trips=10, accum_trips=3)
    ops = parse_collectives(SYNTH)
    ag = next(o for o in ops if o.kind == "all-gather")
    # the all-gather is inside layer_stack AND the accum while
    assert rep["by_kind"]["all-gather"] == ag.link_bytes * 30
    # the optimizer all-reduce is outside both loops
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert rep["by_kind"]["all-reduce"] == ar.link_bytes


def test_scan_body_counted_once():
    w = jnp.ones((64, 64), jnp.float32)
    f = lambda x: jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                               length=10)[0]
    ca = cost_analysis(jax.jit(f).lower(jnp.ones((64, 64))).compile())
    one = 2 * 64 ** 3
    assert ca["flops"] == pytest.approx(one, rel=0.01), \
        "premise broken: update §Roofline methodology"


def test_analytic_flops_vs_unrolled_compile():
    """Reduced qwen2 (4 layers), UNROLLED so XLA counts every layer; the
    analytic model must land within 25% (elementwise ops, norms and exact
    causal masking differ — matmuls dominate)."""
    from repro.analysis.flops import cell_cost
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeConfig
    from repro.models import RunConfig, loss_fn, init_params

    cfg = reduced(get_config("qwen2-7b"), layers=4, d_model=128,
                  n_heads=4, vocab=512).replace(tie_embeddings=False)
    rc = RunConfig(q_chunk=0, kv_chunk=64, loss_chunk=64, unroll=True)
    B, S = 4, 128
    params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def step(p, b):
        return jax.grad(lambda p: loss_fn(p, cfg, rc, b)[0])(p)

    ca = cost_analysis(jax.jit(step).lower(params, batch).compile())
    shape = ShapeConfig("t", S, B, "train")
    cost = cell_cost(cfg, shape, chips=1, accum=1, remat=False)
    # analytic dispatch_flops excludes remat here; unrolled grad compile
    # does fwd+bwd (3x fwd matmuls)
    ratio = cost.dispatch_flops / ca["flops"]
    assert 0.75 < ratio < 1.33, (cost.dispatch_flops, ca["flops"], ratio)


def test_roofline_cell_analysis_shape():
    from repro.analysis.roofline import analyze_cell
    rec = {
        "arch": "qwen2-7b", "shape": "train_4k", "mesh": "16x16",
        "meta": {"accum": 4},
        "collectives": {"total_bytes": 500e9},
        "cost": {"flops": 1e12, "bytes accessed": 1e12},
        "memory": {"temp_bytes": 5e9, "argument_bytes": 2e9},
    }
    r = analyze_cell(rec)
    assert r.dominant == "collective"
    assert 0 < r.roofline_fraction() < 1
    assert r.fits_hbm is True
    assert 0 < r.flops_ratio <= 1
