"""Fused Pallas paged-attention decode: parity against the gather oracle.

Kernel level: ``paged_decode_attention`` vs a dense fp32 reference over
``gather_block_kv`` views — masks (kv_limit scalar/vector, causal,
sliding window), logit softcap, the MLA two-term latent score, a block-
size grid, and physical-block-permutation invariance.

Serve level: THE acceptance criterion — greedy tokens are identical
across contiguous / paged-gather / paged-fused engines on dense, MoE and
MLA architectures (the fused kernel must not change a single sampled
token)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.kernels.paged_attention import paged_decode_attention
from repro.models import RunConfig, init_params
from repro.models.attention import gather_block_kv
from repro.serve.engine import Request, ServeEngine

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Dense reference (explicit masks, fp32) over the gathered view
# ---------------------------------------------------------------------------
def ref_paged_decode(q, k_pool, v_pool, tables, kv_limit, *, scale=None,
                     q_pos=None, causal=False, window=None,
                     logit_softcap=None, q2=None, k2_pool=None):
    B, Hkv, G, D = q.shape
    bs = k_pool.shape[1]
    S = tables.shape[1] * bs
    k = gather_block_kv(k_pool, tables)           # (B, S, Hkv, D)
    v = gather_block_kv(v_pool, tables)
    if scale is None:
        scale = D ** -0.5
    qs = (q * jnp.asarray(scale, q.dtype)).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qs, k.astype(jnp.float32))
    if q2 is not None:
        q2s = (q2 * jnp.asarray(scale, q2.dtype)).astype(jnp.float32)
        k2 = gather_block_kv(k2_pool, tables).astype(jnp.float32)
        s = s + jnp.einsum("bhgd,bshd->bhgs", q2s, k2)
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    kpos = jnp.arange(S)[None, None, None, :]
    lim = jnp.broadcast_to(jnp.asarray(kv_limit), (B,))
    ok = kpos <= lim[:, None, None, None]
    if causal:
        ok = ok & (kpos <= q_pos[:, None, None, None])
    if window is not None:
        ok = ok & (kpos > q_pos[:, None, None, None] - window)
    s = jnp.where(ok, s, NEG_INF)
    m = jnp.max(s, -1, keepdims=True)
    p = jnp.where(ok, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, -1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    out = jnp.where(l > 0, out / jnp.maximum(l, 1e-30), 0.0)
    return out.astype(q.dtype)


def _pools(rng, *, n_blocks=8, bs=4, Hkv=2, D=16, Dv=None):
    Dv = D if Dv is None else Dv
    k = jnp.asarray(rng.standard_normal((n_blocks, bs, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n_blocks, bs, Hkv, Dv)),
                    jnp.float32)
    return k, v


def _case(seed=0, *, B=3, nb=2, n_blocks=8, bs=4, Hkv=2, G=2, D=16,
          Dv=None):
    rng = np.random.default_rng(seed)
    k_pool, v_pool = _pools(rng, n_blocks=n_blocks, bs=bs, Hkv=Hkv, D=D,
                            Dv=Dv)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, D)), jnp.float32)
    # distinct physical blocks per row (the engine never aliases rows)
    tables = jnp.asarray(
        rng.permutation(n_blocks)[:B * nb].reshape(B, nb), jnp.int32)
    lim = jnp.asarray(rng.integers(0, nb * bs, B), jnp.int32)
    return q, k_pool, v_pool, tables, lim


def _close(a, b):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


def test_matches_oracle_basic():
    q, kp, vp, t, lim = _case(0)
    out = paged_decode_attention(q, kp, vp, t, lim, interpret=True)
    _close(out, ref_paged_decode(q, kp, vp, t, lim))


def test_scalar_kv_limit_and_scale():
    q, kp, vp, t, _ = _case(1)
    out = paged_decode_attention(q, kp, vp, t, jnp.int32(5), scale=0.3,
                                 interpret=True)
    _close(out, ref_paged_decode(q, kp, vp, t, jnp.int32(5), scale=0.3))


def test_causal_and_window_masks():
    q, kp, vp, t, lim = _case(2)
    qpos = jnp.asarray([1, 4, 7], jnp.int32)
    for win in (None, 3):
        out = paged_decode_attention(q, kp, vp, t, lim, q_pos=qpos,
                                     causal=True, window=win,
                                     interpret=True)
        _close(out, ref_paged_decode(q, kp, vp, t, lim, q_pos=qpos,
                                     causal=True, window=win))


def test_logit_softcap():
    q, kp, vp, t, lim = _case(3)
    out = paged_decode_attention(q, kp, vp, t, lim, logit_softcap=8.0,
                                 interpret=True)
    _close(out, ref_paged_decode(q, kp, vp, t, lim, logit_softcap=8.0))


def test_mla_two_term_latent_score():
    """MLA absorbed decode: s = q_eff @ ckv^T + q_rope @ kr^T with the
    latent ckv doubling as the value (Dv=D of the latent, D2 rope depth)."""
    rng = np.random.default_rng(4)
    B, nb, n_blocks, bs, H, r, dr = 2, 2, 6, 4, 3, 16, 8
    ckv, _ = _pools(rng, n_blocks=n_blocks, bs=bs, Hkv=1, D=r)
    kr, _ = _pools(rng, n_blocks=n_blocks, bs=bs, Hkv=1, D=dr)
    q1 = jnp.asarray(rng.standard_normal((B, 1, H, r)), jnp.float32)
    q2 = jnp.asarray(rng.standard_normal((B, 1, H, dr)), jnp.float32)
    t = jnp.asarray(rng.permutation(n_blocks)[:B * nb].reshape(B, nb),
                    jnp.int32)
    lim = jnp.asarray([3, 6], jnp.int32)
    sc = (r + dr) ** -0.5
    out = paged_decode_attention(q1, ckv, ckv, t, lim, scale=sc, q2=q2,
                                 k2_pool=kr, interpret=True)
    _close(out, ref_paged_decode(q1, ckv, ckv, t, lim, scale=sc, q2=q2,
                                 k2_pool=kr))


@pytest.mark.parametrize("bs,nb", [(2, 5), (4, 3), (8, 2)])
def test_block_size_grid(bs, nb):
    q, kp, vp, t, lim = _case(5 + bs, nb=nb, n_blocks=3 * nb + 2, bs=bs)
    out = paged_decode_attention(q, kp, vp, t, lim, interpret=True)
    _close(out, ref_paged_decode(q, kp, vp, t, lim))


def test_physical_block_permutation_invariance():
    """Relabeling physical blocks (pool rows permuted, tables remapped)
    must reproduce the output BITWISE: the kernel walks blocks in logical
    table order, so the accumulation order never changes."""
    q, kp, vp, t, lim = _case(6)
    out = paged_decode_attention(q, kp, vp, t, lim, interpret=True)
    perm = np.random.default_rng(7).permutation(kp.shape[0])
    inv = np.argsort(perm)
    out_p = paged_decode_attention(q, kp[inv], vp[inv],
                                   jnp.asarray(perm, jnp.int32)[t], lim,
                                   interpret=True)
    assert np.array_equal(np.asarray(out), np.asarray(out_p))


def test_unallocated_entries_masked():
    """Table entries past kv_limit may point at ARBITRARY blocks; poisoning
    them with huge values must not leak into the output."""
    q, kp, vp, t, _ = _case(8)
    lim = jnp.asarray([2, 2, 2], jnp.int32)     # only block 0 attended
    out = paged_decode_attention(q, kp, vp, t, lim, interpret=True)
    poison = kp.at[np.asarray(t[:, 1])].set(1e4)
    poison_v = vp.at[np.asarray(t[:, 1])].set(1e4)
    out_p = paged_decode_attention(q, poison, poison_v, t, lim,
                                   interpret=True)
    assert np.array_equal(np.asarray(out), np.asarray(out_p))


def test_causal_requires_q_pos():
    q, kp, vp, t, lim = _case(9)
    with pytest.raises(ValueError):
        paged_decode_attention(q, kp, vp, t, lim, causal=True,
                               interpret=True)


# ---------------------------------------------------------------------------
# Serve-level greedy token identity: contiguous vs gather vs fused
# ---------------------------------------------------------------------------
def _greedy_outs(cfg, params, reqs, *, kv_block, paged_attn,
                 executor="pallas"):
    rc = RunConfig(q_chunk=16, kv_chunk=16, executor=executor,
                   paged_attn=paged_attn)
    clones = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
              for r in reqs]
    eng = ServeEngine(cfg, params, slots=2, capacity=32, rc=rc,
                      kv_block_size=kv_block, prefill_chunk=3)
    eng.run(clones, max_steps=128)
    assert all(r.done for r in clones)
    return {r.rid: list(r.out) for r in clones}


def _reqs(cfg, n, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        rng.integers(3, 8)).astype(np.int32),
                    max_new=max_new)
            for i in range(n)]


@pytest.mark.parametrize("arch,block", [
    ("smollm-360m", 4),                  # dense GQA
    ("moonshot-v1-16b-a3b", 4),          # MoE
    ("moonshot-v1-16b-a3b", 8),          # MoE, block-size axis
    ("deepseek-v2-236b", 4),             # MLA latent cache
])
def test_fused_decode_token_identity(arch, block):
    """Greedy serving tokens must be identical across the contiguous
    cache, the paged gather path, and the fused paged-attention kernel —
    on dense, MoE and MLA configs and across block sizes."""
    cfg = reduced(get_config(arch), layers=2, d_model=32, vocab=128)
    params = init_params(cfg, jax.random.key(0))
    reqs = _reqs(cfg, 3, seed=block)
    fused = _greedy_outs(cfg, params, reqs, kv_block=block,
                         paged_attn="fused")
    gather = _greedy_outs(cfg, params, reqs, kv_block=block,
                          paged_attn="gather")
    contig = _greedy_outs(cfg, params, reqs, kv_block=0,
                          paged_attn="auto")
    assert fused == gather == contig


def test_rc_paged_attn_validated():
    cfg = reduced(get_config("smollm-360m"), layers=1, d_model=32,
                  vocab=128)
    params = init_params(cfg, jax.random.key(0))
    rc = RunConfig(q_chunk=16, kv_chunk=16, paged_attn="bogus")
    eng = ServeEngine(cfg, params, slots=1, capacity=16, rc=rc,
                      kv_block_size=4)
    with pytest.raises(ValueError, match="paged_attn"):
        eng.run(_reqs(cfg, 1), max_steps=8)
