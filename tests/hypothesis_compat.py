"""Optional-hypothesis shim with a FIXED-EXAMPLES fallback.

When hypothesis is installed (requirements-dev.txt) this re-exports the
real API unchanged.  When it is not, ``@given`` does NOT skip anymore: it
runs the test body over a deterministic set of examples drawn from a
mini-strategy implementation of the subset of the API this repo uses
(integers / sampled_from / booleans / floats / lists / tuples / just /
one_of / permutations / composite, plus .map/.filter).  Draws come from
``random.Random`` seeded by (REPRO_FUZZ_SEED, test name, example index),
so every failure replays exactly and CI/local runs agree.

Knobs (fallback mode only — under real hypothesis use its own settings):

* ``REPRO_FUZZ_SEED``     — base seed (default 0; CI pins it and passes
  ``--hypothesis-seed=0`` to the real library for the same property).
* ``REPRO_FUZZ_EXAMPLES`` — examples per test (default 10).  The real
  library's ``max_examples`` in ``@settings`` is honored as an upper
  bound when smaller.
"""

import os
import random

import pytest  # noqa: F401  (kept: some callers import it via this shim)

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    FALLBACK_SEED = int(os.environ.get("REPRO_FUZZ_SEED", "0"))
    FALLBACK_EXAMPLES = int(os.environ.get("REPRO_FUZZ_EXAMPLES", "10"))

    class _Strategy:
        """A draw function + the combinators the repo's tests use."""

        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def _draw(self, rnd):
            return self._draw_fn(rnd)

        def map(self, f):
            return _Strategy(lambda rnd: f(self._draw(rnd)))

        def filter(self, pred):
            def draw(rnd):
                for _ in range(10_000):
                    v = self._draw(rnd)
                    if pred(v):
                        return v
                raise ValueError("filter predicate rejected 10k draws")
            return _Strategy(draw)

    class _St:
        """Deterministic stand-ins for the strategies this repo uses."""

        @staticmethod
        def integers(min_value=0, max_value=1 << 16):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rnd: seq[rnd.randrange(len(seq))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rnd: rnd.random() < 0.5)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda rnd: rnd.uniform(min_value, max_value))

        @staticmethod
        def just(value):
            return _Strategy(lambda rnd: value)

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            def draw(rnd):
                n = rnd.randint(min_size, max_size)
                return [elements._draw(rnd) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*strats):
            return _Strategy(
                lambda rnd: tuple(s._draw(rnd) for s in strats))

        @staticmethod
        def one_of(*strats):
            return _Strategy(
                lambda rnd: strats[rnd.randrange(len(strats))]._draw(rnd))

        @staticmethod
        def permutations(seq):
            seq = list(seq)

            def draw(rnd):
                out = list(seq)
                rnd.shuffle(out)
                return out
            return _Strategy(draw)

        @staticmethod
        def composite(fn):
            """``@st.composite`` — the wrapped fn's first arg becomes a
            ``draw`` callable resolving sub-strategies."""
            def build(*args, **kwargs):
                return _Strategy(lambda rnd: fn(
                    lambda strat: strat._draw(rnd), *args, **kwargs))
            return build

    st = _St()

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            # honor a cap stashed by an inner @settings (the decorator
            # order `@given` above `@settings` — the common spelling)
            cap = getattr(fn, "_fallback_settings_cap", None)
            max_examples = [FALLBACK_EXAMPLES if cap is None
                            else min(FALLBACK_EXAMPLES, cap)]

            # NOT functools.wraps: the wrapper must expose a paramless
            # signature or pytest resolves the strategy args as fixtures
            def runner(*fargs, **fkwargs):
                n = max_examples[0]
                for i in range(n):
                    rnd = random.Random(
                        repr((FALLBACK_SEED, fn.__name__, i)))
                    drawn = tuple(s._draw(rnd) for s in arg_strats)
                    kw = {k: s._draw(rnd) for k, s in kw_strats.items()}
                    try:
                        fn(*fargs, *drawn, **fkwargs, **kw)
                    except Exception as e:
                        raise AssertionError(
                            f"fixed-examples fallback: {fn.__name__} "
                            f"failed on example {i} "
                            f"(REPRO_FUZZ_SEED={FALLBACK_SEED}; rerun "
                            f"with the same seed to replay): {e}") from e
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner._fallback_max_examples = max_examples
            return runner
        return deco

    def settings(*_a, max_examples=None, **_k):
        """Honor ``max_examples`` as an upper bound in EITHER decorator
        order; everything else (deadline, suppress_health_check, ...) is
        hypothesis-only."""
        def deco(fn):
            if max_examples is not None:
                box = getattr(fn, "_fallback_max_examples", None)
                if box is not None:          # @settings above @given
                    box[0] = min(box[0], max_examples)
                else:                        # @settings below @given:
                    fn._fallback_settings_cap = max_examples
            return fn
        return deco
