"""Optional-hypothesis shim.

When hypothesis is installed (requirements-dev.txt) this re-exports the
real API.  When it is not, ``@given`` replaces the test with a skipped
placeholder and ``st``/``settings`` become inert stand-ins, so the plain
pytest tests sharing a module with property tests still run — instead of
the whole module failing at collection on the import.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                              "(pip install -r requirements-dev.txt)")
            def placeholder():
                pass
            placeholder.__name__ = fn.__name__
            placeholder.__doc__ = fn.__doc__
            return placeholder
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        """Every strategy becomes a callable returning an inert callable
        (so ``@st.composite`` definitions still evaluate at import)."""

        def __getattr__(self, _name):
            return lambda *a, **k: (lambda *a2, **k2: None)

    st = _Strategies()
